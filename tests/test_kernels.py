"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(kernels run in interpret mode on CPU; see DESIGN.md §8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.checksum.ops import checksum_bytes
from repro.kernels.checksum.ref import (bytes_to_words, checksum_bytes_np,
                                        checksum_words_jnp)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba_scan.ops import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref


# ---------------------------------------------------------------- checksum
@pytest.mark.parametrize("size", [0, 1, 3, 4, 7, 100, 4096, 65536,
                                  131072 * 4 + 5, 1_000_003,
                                  # non-word-aligned tails around the lane
                                  # boundary: the scrub path hashes partial
                                  # batches of arbitrary byte length
                                  5, 1021, 65537, 131072 * 4 - 1])
def test_checksum_matches_refs(size):
    data = np.random.default_rng(size).bytes(size)
    ref = checksum_bytes_np(data)
    jref = int(checksum_words_jnp(jnp.asarray(bytes_to_words(data)), size))
    pal = checksum_bytes(data)
    assert ref == jref == pal


def test_checksum_order_sensitive():
    a = b"x" * 100 + b"y" * 100
    b = b"y" * 100 + b"x" * 100
    assert checksum_bytes_np(a) != checksum_bytes_np(b)


def test_checksum_length_sensitive():
    # trailing zero bytes must change the hash (length is mixed in)
    a = b"hello"
    assert checksum_bytes_np(a) != checksum_bytes_np(a + b"\0")


# --------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("shape", [
    (1, 32, 64, 8), (2, 64, 128, 16), (2, 128, 256, 16),
    (1, 96, 300, 8),     # non-aligned D (pad path)
    (3, 100, 128, 4),    # non-aligned T
])
def test_selective_scan_matches_ref(shape):
    B, T, D, N = shape
    rng = np.random.default_rng(42)
    u = jnp.asarray(rng.normal(size=(B, T, D)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, T, D)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (D, N)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, D, N)), jnp.float32)
    y_ref, h_ref = selective_scan_ref(u, dt, Bm, Cm, A, h0)
    y, hT = selective_scan(u, dt, Bm, Cm, A, h0, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_selective_scan_state_continuity():
    """Scanning [0:T] must equal scanning [0:T/2] then [T/2:T] with carried h."""
    rng = np.random.default_rng(7)
    B, T, D, N = 1, 64, 128, 8
    u = jnp.asarray(rng.normal(size=(B, T, D)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, T, D)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (D, N)), jnp.float32)
    h0 = jnp.zeros((B, D, N), jnp.float32)
    y_full, h_full = selective_scan(u, dt, Bm, Cm, A, h0)
    h = h0
    ys = []
    for sl in (slice(0, 32), slice(32, 64)):
        y, h = selective_scan(u[:, sl], dt[:, sl], Bm[:, sl], Cm[:, sl], A, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("cfg", [
    dict(B=2, T=128, H=4, Hkv=2, hd=64, window=None, dtype=jnp.float32),
    dict(B=1, T=256, H=4, Hkv=1, hd=64, window=None, dtype=jnp.bfloat16),
    dict(B=2, T=256, H=8, Hkv=8, hd=32, window=64, dtype=jnp.float32),
    dict(B=1, T=384, H=2, Hkv=2, hd=128, window=128, dtype=jnp.bfloat16),
])
def test_flash_attention_matches_ref(cfg):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(cfg["B"], cfg["T"], cfg["H"], cfg["hd"])),
                    cfg["dtype"])
    k = jnp.asarray(rng.normal(size=(cfg["B"], cfg["T"], cfg["Hkv"], cfg["hd"])),
                    cfg["dtype"])
    v = jnp.asarray(rng.normal(size=(cfg["B"], cfg["T"], cfg["Hkv"], cfg["hd"])),
                    cfg["dtype"])
    ref = flash_attention(q, k, v, window=cfg["window"], use_pallas=False)
    out = flash_attention(q, k, v, window=cfg["window"], use_pallas=True)
    tol = 2.5e-2 if cfg["dtype"] == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_is_causal():
    """Future tokens must not influence earlier outputs."""
    rng = np.random.default_rng(1)
    B, T, H, hd = 1, 128, 2, 64
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    o1 = flash_attention(q, k, v, use_pallas=True)
    k2 = k.at[:, -1].add(10.0)
    v2 = v.at[:, -1].add(10.0)
    o2 = flash_attention(q, k2, v2, use_pallas=True)
    np.testing.assert_allclose(np.asarray(o1[:, :-1]), np.asarray(o2[:, :-1]),
                               atol=1e-5)
