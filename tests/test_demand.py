"""Demand-engine tests: workload determinism and Zipf shape (hypothesis),
read-cache eviction disciplines, the table-fed replica catalog, reader/mover
contention on the site read caps, popular-first scheduler prioritization,
no-demand bit-identity, and crash-resume digest identity with traffic live.
"""
import numpy as np
import pytest

from repro.core.faults import FaultInjector, Notifier, RetryPolicy
from repro.core.pause import DAY, PauseManager
from repro.core.routes import GB, make_catalog, paper_route_graph
from repro.core.scheduler import ReplicationPolicy, ReplicationScheduler
from repro.core.transfer_table import Status, TransferTable
from repro.core.transport import SimClock, SimulatedTransport
from repro.demand.cache import ReadCache
from repro.demand.catalog import ReplicaCatalog
from repro.demand.spec import NO_DEMAND, DemandSpec
from repro.demand.workload import RequestWorkload
from repro.scenarios.crash_resume import (CRASH_RESUME_DEMAND,
                                          run_crash_resume)
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import get_scenario, scenario_tags

SMALL = DemandSpec(users=100_000, requests_per_user_day=0.01,
                   wave_interval_s=6 * 3600.0)


def _workload(n=24, seed=0, spec=SMALL):
    paths = [f"ds{i:04d}" for i in range(n)]
    return RequestWorkload(spec, paths, seed=seed)


# ------------------------------------------------------------ workload (unit)
def test_workload_rejects_empty_catalog():
    with pytest.raises(ValueError):
        RequestWorkload(SMALL, [], seed=0)


def test_workload_rank_roundtrip():
    wl = _workload()
    for r in range(wl.n):
        assert wl.rank_of(wl.path_at_rank(r)) == r
    # unknown paths (mid-run top-ups) rank below the whole catalog
    assert wl.rank_of("not-a-dataset") == wl.n


def test_workload_probabilities_rank_monotone():
    p = _workload(n=50).probabilities()
    assert np.all(np.diff(p) <= 0)          # rank 0 is the hottest
    assert abs(p.sum() - 1.0) < 1e-9


def test_demand_spec_validation():
    with pytest.raises(ValueError):
        DemandSpec(users=-1).validate()
    with pytest.raises(ValueError):
        DemandSpec(users=10, eviction="fifo").validate()
    with pytest.raises(ValueError):
        DemandSpec(users=10, wave_interval_s=0.0).validate()
    NO_DEMAND.validate()                    # disabled spec is always valid
    assert not NO_DEMAND.enabled


# ----------------------------------------------------- workload (hypothesis)
def _hypothesis():
    pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed (see requirements-dev.txt)")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    slow = settings(max_examples=15, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])
    return given, slow, st


def test_workload_bit_deterministic_per_seed():
    """Two workloads with the same (spec, catalog, seed) produce identical
    popularity orders and identical wave samples — the property resume
    correctness is built on."""
    given, slow, st = _hypothesis()

    @given(seed=st.integers(0, 10_000), n=st.integers(2, 64),
           waves=st.integers(1, 6))
    @slow
    def prop(seed, n, waves):
        a, b = _workload(n, seed), _workload(n, seed)
        assert [a.path_at_rank(r) for r in range(n)] == \
               [b.path_at_rank(r) for r in range(n)]
        for w in range(waves):
            t0, t1 = w * 6 * 3600.0, (w + 1) * 6 * 3600.0
            np.testing.assert_array_equal(a.sample_wave(t0, t1),
                                          b.sample_wave(t0, t1))
    prop()


def test_workload_requests_target_existing_datasets():
    """Every sampled request maps to a rank inside the catalog, and the
    count vector is exactly catalog-sized — no request can ever reference a
    dataset the campaign does not replicate."""
    given, slow, st = _hypothesis()

    @given(seed=st.integers(0, 10_000), n=st.integers(4, 48))
    @slow
    def prop(seed, n):
        wl = _workload(n, seed)
        paths = set(wl.paths)
        counts = wl.sample_wave(0.0, DAY)
        assert counts.shape == (n,)
        assert int(counts.sum()) >= 0
        for r in np.flatnonzero(counts):
            assert wl.path_at_rank(int(r)) in paths
    prop()


def test_workload_drift_preserves_permutation():
    """Popularity drift reshuffles ranks but the order stays a permutation
    of the catalog, and drifting is itself bit-deterministic per seed."""
    given, slow, st = _hypothesis()

    @given(seed=st.integers(0, 10_000), n=st.integers(4, 40),
           interval=st.floats(0.5, 5.0))
    @slow
    def prop(seed, n, interval):
        spec = DemandSpec(users=10_000, drift_interval_days=interval)
        a = RequestWorkload(spec, [f"ds{i}" for i in range(n)], seed=seed)
        b = RequestWorkload(spec, [f"ds{i}" for i in range(n)], seed=seed)
        for day in (1, 3, 9):
            assert a.maybe_drift(day * interval * DAY) == \
                   b.maybe_drift(day * interval * DAY)
            assert sorted(a._order) == list(range(n))
            assert [a.path_at_rank(r) for r in range(n)] == \
                   [b.path_at_rank(r) for r in range(n)]
        assert a.drifts == b.drifts > 0
    prop()


# ------------------------------------------------------------------- caches
def test_cache_lru_evicts_least_recently_used():
    c = ReadCache("ALCF", capacity_bytes=3, eviction="lru")
    for i, p in enumerate(("a", "b", "c")):
        assert c.admit(p, 1, rank=i, now=float(i))
    assert c.touch("a", now=10.0)           # refresh a; b is now LRU
    assert c.admit("d", 1, rank=3, now=11.0)
    assert c.contains("a") and not c.contains("b")
    assert c.evictions == 1


def test_cache_popularity_evicts_least_popular():
    c = ReadCache("ALCF", capacity_bytes=3, eviction="popularity")
    c.admit("hot", 1, rank=0, now=0.0)
    c.admit("warm", 1, rank=5, now=1.0)
    c.admit("cold", 1, rank=90, now=2.0)
    c.touch("cold", now=50.0)               # recency must not save rank 90
    assert c.admit("new", 1, rank=2, now=51.0)
    assert not c.contains("cold")
    assert c.contains("hot") and c.contains("warm")


def test_cache_pin_refuses_when_full():
    c = ReadCache("ALCF", capacity_bytes=2, eviction="pin")
    assert c.admit("a", 1, rank=0, now=0.0)
    assert c.admit("b", 1, rank=1, now=0.0)
    assert not c.admit("c", 1, rank=2, now=0.0)   # pinned entries never evict
    assert c.evictions == 0 and len(c) == 2


def test_cache_rejects_oversize_and_roundtrips():
    c = ReadCache("OLCF", capacity_bytes=10, eviction="lru")
    assert not c.admit("huge", 11, rank=0, now=0.0)
    c.admit("a", 4, rank=1, now=1.0)
    c.touch("a", now=2.0)
    c.touch("missing", now=2.0)
    d = ReadCache("OLCF", capacity_bytes=10, eviction="lru")
    d.load_state_dict(c.state_dict())
    assert d.state_dict() == c.state_dict()
    assert d.hits == 1 and d.misses == 1 and d.used == 4


# ----------------------------------------------------------- replica catalog
def test_replica_catalog_follows_table_and_adopts():
    table = TransferTable()
    cat = ReplicaCatalog(table, "LLNL", ("ALCF", "OLCF"))
    table.populate(["d1", "d2"], "LLNL", ["ALCF", "OLCF"])
    assert not cat.materialized("d1") and cat.serving_site("d1") is None
    table.update("d1", "OLCF", status=Status.SUCCEEDED)
    assert cat.serving_site("d1") == "OLCF"
    table.update("d1", "ALCF", status=Status.SUCCEEDED)
    # replica priority order, not arrival order
    assert cat.serving_site("d1") == "ALCF"
    assert cat.holders("d1") == {"ALCF", "OLCF"}
    assert cat.materialized_count() == 1
    # a catalog built over an already-populated table adopts its history
    late = ReplicaCatalog(table, "LLNL", ("ALCF", "OLCF"))
    assert late.serving_site("d1") == "ALCF"
    assert late.serving_site("d2") is None


# ------------------------------------------------------------ read contention
def _transport():
    graph = paper_route_graph()
    clock = SimClock()
    return graph, clock, SimulatedTransport(
        graph, clock, PauseManager(), FaultInjector(seed=0), Notifier(),
        RetryPolicy())


def test_reader_streams_tax_the_site_read_cap():
    graph, clock, transport = _transport()
    solo = transport.user_read_rate("LLNL")
    transport.set_read_load("svc", {"LLNL": 8})
    shared = transport.user_read_rate("LLNL")
    assert shared < solo
    # an empty load withdraws the owner entirely
    transport.set_read_load("svc", {})
    assert transport.user_read_rate("LLNL") == solo
    assert transport._reader_streams() == {}


def test_reader_pseudo_route_contends_with_movers():
    """The fair-share allocator sees reader streams as a pseudo-route on the
    source's read cap: movers sourcing there slow down, and the pseudo-route
    never leaks into the real-route rate dict."""
    graph, clock, transport = _transport()
    movers = {("LLNL", "ALCF"): 2}
    base = graph.effective_rate("LLNL", "ALCF", movers)
    contended = graph.effective_rate(
        "LLNL", "ALCF", {**movers, ("LLNL", transport._READERS): 8})
    assert contended < base
    transport.set_read_load("svc", {"LLNL": 8})
    assert all(transport._READERS not in r
               for r in transport._route_rates([]))


def test_transport_snapshot_omits_empty_read_load():
    """Demand-free snapshots must stay byte-identical to the pre-demand
    format: the read_load key appears only when readers are registered."""
    _, _, transport = _transport()
    assert "read_load" not in transport.state_dict()
    transport.set_read_load("svc", {"LLNL": 3, "ALCF": 1})
    d = transport.state_dict()
    assert d["read_load"] == [["svc", "ALCF", 1], ["svc", "LLNL", 3]]
    _, _, fresh = _transport()
    fresh.load_state_dict(d, catalog={})
    assert fresh._reader_streams() == {"LLNL": 3, "ALCF": 1}


# -------------------------------------------------- popular-first scheduling
def _mini_campaign(n=12, seed=3):
    graph = paper_route_graph()
    catalog = {d.path: d for d in make_catalog(
        n, total_bytes=n * GB, total_files=n * 40, total_dirs=n * 4,
        seed=seed)}
    clock = SimClock()
    transport = SimulatedTransport(graph, clock, PauseManager(),
                                   FaultInjector(seed=seed), Notifier(),
                                   RetryPolicy())
    table = TransferTable()
    sched = ReplicationScheduler(table, transport, catalog,
                                 ReplicationPolicy("LLNL", ("ALCF",)),
                                 RetryPolicy(), Notifier())
    return catalog, clock, table, sched


def test_set_priority_starts_popular_datasets_first():
    catalog, clock, table, sched = _mini_campaign()
    sched.populate()
    order = sorted(catalog)
    rank = {p: len(order) - 1 - i for i, p in enumerate(order)}  # reversed
    sched.set_priority(lambda ds: rank[ds])
    sched.step(clock.now)
    started = {r.dataset for r in table.by_status(Status.ACTIVE,
                                                  destination="ALCF")}
    assert started
    expected = set(sorted(catalog, key=lambda p: rank[p])[:len(started)])
    assert started == expected              # hottest ranks started first


def test_reprioritize_preserves_entry_multiset():
    catalog, clock, table, sched = _mini_campaign()
    sched.populate()
    before = {dst: sorted(e if isinstance(e, str) else e[1] for e in h)
              for dst, h in sched._direct.items()}
    sched.set_priority(lambda ds: hash(ds) % 7)
    sched.reprioritize()
    after = {dst: sorted(e[1] for e in h)
             for dst, h in sched._direct.items()}
    assert before == after
    sched.set_priority(None)                # clearing restores plain entries
    assert {dst: sorted(h) for dst, h in sched._direct.items()} == before


# ------------------------------------------------------ scenario integration
def test_no_demand_build_is_bit_identical_to_baseline():
    """esgf-serving with its traffic stripped replays the paper-2022
    trajectory exactly — the subsystem is invisible until a scenario opts
    in."""
    from repro.core.snapshot import trajectory_summary
    base = get_scenario("paper-2022")
    stripped = get_scenario("esgf-serving").with_demand(NO_DEMAND)
    summaries = []
    for spec in (base, stripped):
        world = spec.build(scale=0.01, seed=0, n_datasets=12)
        assert world.demand is None
        stats = EngineStats()
        rep = run_world(world, engine="events", stats=stats)
        summaries.append(trajectory_summary(rep, stats, world.table))
    assert summaries[0] == summaries[1]


def test_esgf_serving_end_to_end():
    world = get_scenario("esgf-serving").build(scale=0.01, seed=0,
                                               n_datasets=12)
    assert world.demand is not None
    rep = run_world(world, engine="events")
    s = world.demand.summary()
    assert s["waves"] > 0 and s["requests"] > 0
    assert 0.0 < s["hit_rate"] <= 1.0
    assert s["hits"] == s["requests"] - s["source_reads"]
    assert s["p99_s"] >= s["p50_s"] > 0.0
    assert s["day90"] is not None           # the campaign reaches the SLO
    assert set(s["caches"]) == {"ALCF", "OLCF"}
    # the finished campaign withdrew its reader streams from the transport
    assert world.transport._reader_streams() == {}
    assert rep.duration_days > 0


def test_demand_and_bundling_cannot_combine():
    spec = get_scenario("small-file-storm").with_demand(users=50_000)
    with pytest.raises(ValueError, match="bundling"):
        spec.build(scale=0.01, seed=0, n_datasets=20)


def test_scenario_tags():
    assert "demand" in scenario_tags(get_scenario("esgf-serving"))
    assert scenario_tags(get_scenario("crash-resume-demand")) == \
        ["crash-resume", "demand"]
    assert "demand" not in scenario_tags(get_scenario("paper-2022"))


def test_cli_list_shows_demand_tags(capsys):
    from repro.scenarios.run import main
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    line = next(l for l in out.splitlines() if l.startswith("esgf-serving"))
    assert "[demand]" in line
    line = next(l for l in out.splitlines()
                if l.startswith("crash-resume-demand"))
    assert "[crash-resume,demand]" in line


# ------------------------------------------------------------- crash-resume
def test_crash_resume_demand_digest_identical(tmp_path):
    """Kill esgf-serving at ~50% with traffic live; the resumed run's
    trajectory summary (succeeded-set digest included) must equal the
    uninterrupted reference's."""
    res = run_crash_resume(CRASH_RESUME_DEMAND, str(tmp_path),
                           scale=0.01, seed=0, n_datasets=12)
    assert res["kills"], "the kill point was never reached"
    assert res["match"], (res["reference"], res["resumed"])
