"""Flight-recorder tests: the obs-on/obs-off bit-identity contract (single
campaign, federation, scrub), cross-process NDJSON byte identity, snapshot
byte identity, trace ring budgeting, metrics registry semantics, transport
flow-telemetry horizon pruning, dashboard JSON cleanliness, the phase
profiler, and the post-mortem report CLI."""
import dataclasses
import json
import math
import os
import subprocess
import sys

import pytest

from repro.core.snapshot import (federation_trajectory_summary,
                                 trajectory_summary)
from repro.obs import FULL_OBS, NO_OBS, ObsSpec
from repro.obs.metrics import Counter, Histogram, MetricsRegistry
from repro.obs.sink import ObsSink, json_line, sanitize
from repro.obs.trace import TraceRecorder, to_chrome
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import get_scenario, scenario_tags

TINY = dict(n_datasets=12, scale=0.01)


def _cli_env():
    return dict(os.environ, PYTHONPATH="src" + os.pathsep +
                os.environ.get("PYTHONPATH", ""))


def _run_traj(spec, **kw):
    world = spec.build(**kw)
    stats = EngineStats()
    rep = run_world(world, engine="events", stats=stats)
    return world, trajectory_summary(rep, stats, world.table)


# ================================================== bit-identity contract
@pytest.mark.parametrize("name", ["paper-2022", "scrub-and-repair",
                                  "esgf-serving"])
def test_obs_on_off_trajectory_identical(name):
    spec = get_scenario(name)
    _, off = _run_traj(spec.with_obs(NO_OBS), **TINY)
    world, on = _run_traj(spec.with_obs(FULL_OBS), **TINY)
    assert on == off
    # and the recorder actually saw the campaign it did not perturb
    assert world.obs is not None
    assert world.obs.trace.summary()["events"] > 0
    assert len(world.obs.samples) >= 2


def test_obs_on_off_federation_identical():
    fed = get_scenario("federation-paper-twice")
    kw = dict(n_datasets=8, scale=0.004)

    def run(spec):
        world = spec.build(**kw)
        stats = EngineStats()
        rep = run_world(world, engine="events", stats=stats)
        return world, federation_trajectory_summary(rep, stats, world)

    _, off = run(fed)
    world, on = run(fed.with_obs(FULL_OBS))
    assert on == off
    # every member carries its own recorder, labelled by campaign
    labels = [rt.obs.label for rt in world.runtimes]
    assert len(labels) == 2 and len(set(labels)) == 2
    for rt in world.runtimes:
        assert rt.obs.trace.summary()["events"] > 0


def test_strict_cadence_keeps_physical_trajectory():
    spec = get_scenario("paper-2022")
    _, off = _run_traj(spec, **TINY)
    world, on = _run_traj(
        spec.with_obs(ObsSpec(metrics=True, strict_cadence=True,
                              sample_interval_days=1.0)), **TINY)
    # extra sampling iterations are allowed; the physics must not move
    for key in ("faults_total", "quarantined", "bytes_at",
                "succeeded_digest"):
        assert on[key] == off[key]
    assert on["iterations"] >= off["iterations"]
    # strict cadence means samples land on (near-)exact day boundaries
    days = [s["t_day"] for s in world.obs.samples[1:-1]]
    assert days, "no interior samples taken"
    for d in days:
        assert abs(d - round(d)) < 1e-3


# ================================================ cross-process determinism
def test_ndjson_stream_byte_identical_across_processes(tmp_path):
    env = _cli_env()
    base = [sys.executable, "-m", "repro.scenarios.run", "--scenario",
            "paper-2022", "--datasets", "12", "--scale", "0.01"]
    paths = [str(tmp_path / f"run{i}.ndjson") for i in (1, 2)]
    for p in paths:
        r = subprocess.run(base + ["--obs", p], capture_output=True,
                           text=True, timeout=300, env=env, cwd=".")
        assert r.returncode == 0, r.stderr[-2000:]
    b1, b2 = (open(p, "rb").read() for p in paths)
    assert b1 == b2
    assert b1.count(b"\n") > 10


def _strip_uids(obj):
    """In-flight transfer uids are ``uuid4()`` — random per process even
    without obs — so snapshot comparison normalizes them away."""
    if isinstance(obj, dict):
        return {k: ("UID" if k == "uid" else _strip_uids(v))
                for k, v in obj.items()}
    if isinstance(obj, list):
        return [_strip_uids(v) for v in obj]
    return obj


def test_snapshot_identical_obs_on_off(tmp_path):
    """The recorder is excluded from snapshots: a mid-run checkpoint taken
    under observation equals the checkpoint of an unobserved run (modulo
    the process-random transfer uids, which differ between any two runs)."""
    env = _cli_env()
    base = [sys.executable, "-m", "repro.scenarios.run", "--scenario",
            "paper-2022", "--datasets", "12", "--scale", "0.01",
            "--kill-after", "40"]
    snaps = {}
    for arm, extra in (("off", []),
                       ("on", ["--obs", str(tmp_path / "run.ndjson")])):
        ck = str(tmp_path / f"ck-{arm}")
        r = subprocess.run(base + ["--checkpoint-dir", ck] + extra,
                           capture_output=True, text=True, timeout=300,
                           env=env, cwd=".")
        assert r.returncode == 3, (r.returncode, r.stderr[-2000:])
        latest = open(os.path.join(ck, "LATEST")).read().strip()
        assert latest == "snapshot-00000040.json"   # same kill iteration
        with open(os.path.join(ck, latest)) as f:
            snaps[arm] = _strip_uids(json.load(f))
    assert snaps["on"] == snaps["off"]


def test_obs_flag_refused_on_resume(tmp_path):
    env = _cli_env()
    r = subprocess.run([sys.executable, "-m", "repro.scenarios.run",
                        "--resume", str(tmp_path / "nope"), "--obs",
                        str(tmp_path / "x.ndjson")],
                       capture_output=True, text=True, timeout=60, env=env,
                       cwd=".")
    assert r.returncode != 0
    assert "--obs" in (r.stderr + r.stdout)


# ====================================================== trace ring + sink
def test_trace_ring_budget_evicts_oldest_but_sink_sees_all(tmp_path):
    p = str(tmp_path / "t.ndjson")
    sink = ObsSink(p)
    tr = TraceRecorder(budget_bytes=600, campaign="c", sink=sink)
    for i in range(50):
        tr.record(float(i), "dispatched", dataset=f"ds{i:04d}", dest="X")
    sink.close()
    s = tr.summary()
    assert s["events"] == 50
    assert s["dropped"] > 0 and s["retained"] < 50
    assert s["ring_bytes"] <= 600
    # ring keeps the newest records
    kept = tr.records()
    assert kept[-1]["dataset"] == "ds0049"
    # the streaming sink is unbounded: every event landed
    lines = open(p).read().splitlines()
    assert sum(1 for ln in lines if json.loads(ln)["k"] == "trace") == 50


def test_json_line_deterministic_and_nan_clean():
    obj = {"b": float("nan"), "a": float("inf"), "c": [1.0, -float("inf")],
           "d": {"y": 2, "x": 1}}
    line = json_line(obj)
    assert line == json_line(dict(reversed(list(obj.items()))))
    assert "NaN" not in line and "Infinity" not in line
    assert sanitize(float("nan")) is None


def test_to_chrome_spans_and_metadata():
    tr = TraceRecorder(budget_bytes=1 << 20, campaign="c")
    tr.record(0.0, "queued", dataset="d", dest="A")
    tr.record(10.0, "dispatched", dataset="d", dest="A")
    tr.record(25.0, "succeeded", dataset="d", dest="A")
    tr.record(30.0, "scrub-pass", scanned=4, detected=0)
    doc = to_chrome(tr.records())
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert "X" in phases and "i" in phases and "M" in phases
    span = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    # 1 trace microsecond == 1 sim second
    assert span["ts"] == pytest.approx(10.0)
    assert span["dur"] == pytest.approx(15.0)
    assert span["name"] == "succeeded"


# ========================================================= metrics registry
def test_metrics_primitives():
    c = Counter()
    c.inc(); c.inc(3)
    assert c.value == 4
    h = Histogram()
    for v in (30.0, 90.0, 5000.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["sum"] == pytest.approx(5120.0)
    assert s["p50"] >= 30.0
    reg = MetricsRegistry()
    reg.counter("a.b").inc()
    assert reg.counter("a.b") is reg.counter("a.b")
    snap = reg.snapshot()
    assert snap["counters"]["a.b"] == 1


def test_obs_spec_validation():
    with pytest.raises(ValueError):
        ObsSpec(metrics=True, sample_interval_days=0.0).validate()
    with pytest.raises(ValueError):
        ObsSpec(trace=True, trace_budget_bytes=0).validate()
    NO_OBS.validate()   # disabled spec never validates its knobs


# ================================================= flow-telemetry horizon
def test_flow_horizon_bounds_flow_totals():
    spec = get_scenario("paper-2022")
    bounded = dataclasses.replace(spec, flow_horizon_days=3.0)
    w1, t1 = _run_traj(spec, **TINY)
    w2, t2 = _run_traj(bounded, **TINY)
    # pruning is pure telemetry hygiene: the trajectory cannot move
    assert t1 == t2
    tr1 = w1.runtime.sched.transport
    tr2 = w2.runtime.sched.transport
    days1 = {k[0] for k in tr1.flow_totals}
    days2 = {k[0] for k in tr2.flow_totals}
    assert max(days1) - min(days1) > 3      # unbounded run spans the campaign
    assert max(days2) - min(days2) <= 3     # bounded run kept the horizon
    assert len(tr2.flow_totals) < len(tr1.flow_totals)


def test_federation_members_must_agree_on_flow_horizon():
    fed = get_scenario("federation-paper-twice")
    members = list(fed.members)
    members[0] = dataclasses.replace(
        members[0], scenario=dataclasses.replace(
            members[0].scenario, flow_horizon_days=5.0))
    bad = dataclasses.replace(fed, members=tuple(members))
    with pytest.raises(ValueError, match="flow_horizon_days"):
        bad.build(n_datasets=8, scale=0.004)


# ======================================================== dashboard rows
def test_dashboard_row_dict_json_clean():
    from repro.core.dashboard import row_dict
    world, _ = _run_traj(get_scenario("paper-2022").with_obs(FULL_OBS),
                         **TINY)
    rows = [row_dict(r) for r in world.table.all()]
    assert rows
    text = json.dumps(rows, allow_nan=False)     # raises on NaN/inf
    assert "NaN" not in text
    # obs rows render without touching world state
    from repro.core.dashboard import obs_rows, render_obs_text
    kinds = {r["kind"] for r in obs_rows(world.obs)}
    assert kinds == {"trace", "metrics"}
    assert "trace" in render_obs_text(world.obs, 0.0)


# ========================================================= phase profiler
def test_phase_profiler_wrap_and_restore():
    from repro.core.scheduler import ReplicationScheduler
    from repro.obs.profile import PhaseProfiler
    orig_step = ReplicationScheduler.step
    with PhaseProfiler() as prof:
        prof.instrument_standard()
        assert ReplicationScheduler.step is not orig_step
        world = get_scenario("paper-2022").build(**TINY)
        run_world(world, engine="events")
    assert ReplicationScheduler.step is orig_step
    rep = prof.report(wall_s=1.0)
    assert rep["wall_s"] == 1.0
    assert rep["phases_s"]["sched"] > 0
    assert rep["phases_s"]["driver"] >= 0
    assert sum(rep["phases_pct"].values()) == pytest.approx(100.0, abs=0.5)


# ===================================================== post-mortem report
def test_report_cli_and_perfetto_export(tmp_path):
    env = _cli_env()
    nd = str(tmp_path / "run.ndjson")
    r = subprocess.run([sys.executable, "-m", "repro.scenarios.run",
                        "--scenario", "paper-2022", "--datasets", "12",
                        "--scale", "0.01", "--obs", nd],
                       capture_output=True, text=True, timeout=300, env=env,
                       cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    from repro.obs.report import load_stream, main, render
    stream = load_stream(nd)
    assert stream["trace"] and stream["metrics"] and stream["meta"]
    text = render(stream, top=5)
    for section in ("post-mortem", "days vs bytes", "fault / outage",
                    "slowest routes", "most-retried"):
        assert section in text.lower(), f"missing section {section!r}"
    pf = str(tmp_path / "trace.json")
    assert main([nd, "--perfetto", pf, "--json"]) == 0
    doc = json.load(open(pf))
    assert doc["traceEvents"]
    assert all(set(e) >= {"ph", "ts", "pid", "tid"}
               for e in doc["traceEvents"] if e["ph"] != "M")


# ======================================================= registry + lanes
def test_harsh_faults_scenario_registered_with_obs():
    spec = get_scenario("harsh-faults")
    assert spec.obs.enabled and spec.obs.trace and spec.obs.metrics
    assert "obs" in scenario_tags(spec)
    assert any(not o.planned for o in spec.outages)


def test_lane_engine_refuses_observed_specs():
    from repro.ensemble.lanes import lane_capable
    spec = get_scenario("paper-2022")
    ok, _ = lane_capable(spec)
    assert ok
    ok, reason = lane_capable(spec.with_obs(FULL_OBS))
    assert not ok and "recorder" in reason
