import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 CPU device.
# Multi-device / x64 tests spawn subprocesses whose environment comes from
# jax_subprocess_env below, the one place that composes jax env policy.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def jax_subprocess_env(devices=None, x64=False):
    """Environment for a jax subprocess: the XLA host-device count and the
    x64 policy, set before the child imports jax (both are read at import).
    Replaces per-test ``os.environ`` twiddling inside ``python -c`` bodies;
    the parent pytest process keeps its own single-device, x32 default."""
    env = dict(os.environ)
    if devices is not None:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={devices}"
                            ).strip()
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    return env
