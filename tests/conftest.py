import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 CPU device.
# Multi-device tests (relay collectives) spawn subprocesses that set the flag.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
