"""Training loop (fault injection, restart, loss decrease) and serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.replicate import CheckpointReplicator
from repro.configs import get_config
from repro.models.model import LM
from repro.serve.engine import Engine
from repro.train.loop import TrainConfig, train


def test_train_loss_decreases(tmp_path):
    cfg = get_config("smollm-135m").smoke()
    tc = TrainConfig(steps=40, batch_size=8, seq_len=64, peak_lr=1e-3,
                     warmup=5, ckpt_dir=None, log_every=0)
    res = train(cfg, tc)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.05, (first, last)


def test_train_restart_resumes_and_completes(tmp_path):
    cfg = get_config("smollm-135m").smoke()
    ckpt = str(tmp_path / "ckpts")
    tc = TrainConfig(steps=24, batch_size=4, seq_len=32, ckpt_every=8,
                     ckpt_dir=ckpt, fail_at_step=13, log_every=0)
    res = train(cfg, tc)
    assert res.restarts == 1
    assert res.final_step == 24
    assert res.restored_from is not None and "step-000008" in res.restored_from
    # checkpoint at final step exists? last save at 24
    assert os.path.isdir(os.path.join(ckpt, "step-000024"))


def test_train_with_replication_protects_against_pod_loss(tmp_path):
    cfg = get_config("smollm-135m").smoke()
    rep = CheckpointReplicator(str(tmp_path), primary="POD0",
                               replicas=("POD1",))
    ckpt = os.path.join(rep.site_dir("POD0"), "ckpts")
    tc = TrainConfig(steps=10, batch_size=4, seq_len=32, ckpt_every=5,
                     ckpt_dir=ckpt, replicator=rep, log_every=0)
    train(cfg, tc)
    pod1 = os.path.join(rep.site_dir("POD1"), "ckpts")
    assert sorted(os.listdir(pod1)) == ["step-000005", "step-000010"]


def test_engine_matches_manual_decode():
    """Wave engine (same-length prompts) must equal manual prefill+decode."""
    cfg = get_config("smollm-135m").smoke()
    model = LM(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(2)]
    eng = Engine(cfg, params, max_batch=2, max_seq=64)
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    done = sorted(eng.run_to_completion(), key=lambda r: r.rid)

    # manual: batched prefill + greedy decode
    toks = np.stack(prompts)
    cache = model.init_cache(2, 64)
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(toks)}, cache)
    cur = np.asarray(jnp.argmax(logits[:, 0], -1))
    outs = [[int(c)] for c in cur]
    t = 16
    for _ in range(4):
        lg, cache = model.decode_step(params, cache,
                                      jnp.asarray(cur[:, None], jnp.int32),
                                      jnp.int32(t))
        cur = np.asarray(jnp.argmax(lg[:, 0], -1))
        for i, c in enumerate(cur):
            outs[i].append(int(c))
        t += 1
    for r, manual in zip(done, outs):
        assert r.out_tokens == manual


def test_engine_handles_more_requests_than_slots():
    cfg = get_config("smollm-135m").smoke()
    model = LM(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_seq=48)
    rng = np.random.default_rng(1)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                       max_new_tokens=3) for _ in range(5)]
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert all(len(r.out_tokens) == 3 for r in done)
    assert eng.waves == 3


def test_straggler_requeue(tmp_path):
    """A shard read exceeding the deadline is requeued, training never stalls."""
    from repro.data.sharded import ShardedDataset, write_shards
    root = str(tmp_path / "shards")
    toks = np.arange(2048, dtype=np.int32)
    write_shards(root, toks, shard_len=256)
    ds = ShardedDataset(root, straggler_deadline_s=0.2)
    slow = {"shard-00001.npy"}
    import time

    def hook(name):
        if name in slow:
            slow.discard(name)      # slow exactly once
            time.sleep(0.5)

    ds.load_hook = hook
    it = ds.batches(batch=1, seq=255)
    seen = [next(it)[0]["tokens"][0, 0] for _ in range(8)]
    assert "shard-00001.npy" in ds.slow_shards
    # shard 1 was requeued, not dropped: its first token appears eventually
    assert any(int(s) == 256 for s in seen)
