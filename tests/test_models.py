"""Per-architecture model tests: smoke (reduced config, one forward/train
step, shape + finiteness), and prefill/decode vs full-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.config import param_count
from repro.models.frontends import train_batch_stub
from repro.models.model import LM


@pytest.fixture(scope="module")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng_key):
    cfg = get_config(arch).smoke()
    model = LM(cfg, remat=False)
    params = model.init(rng_key)
    batch = train_batch_stub(cfg, batch=2, seq=64)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0
    # gradient step produces finite grads for every leaf
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), (arch, path)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_logits_shape(arch, rng_key):
    cfg = get_config(arch).smoke()
    model = LM(cfg, remat=False)
    params = model.init(rng_key)
    B, T = 2, 32
    batch = train_batch_stub(cfg, batch=B, seq=T)
    x = model.embed(params, batch)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    xf, _, _ = model.backbone(params, x, pos,
                              positions3=batch.get("positions3"), mode="train")
    logits = model.unembed(params, xf)
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, T, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, T, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, rng_key):
    """prefill(T-k) + k decode steps must reproduce the full forward."""
    cfg = get_config(arch).smoke()
    dtype = jnp.bfloat16
    if cfg.moe:
        # drop-free capacity: routing drops depend on co-batch size, which
        # legitimately differs between the two code paths; f32 params because
        # top-k routing is discontinuous — bf16 rounding differences between
        # the scanned and unrolled paths can flip near-tied expert choices
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        dtype = jnp.float32
    model = LM(cfg, dtype=dtype, remat=False)
    params = model.init(rng_key)
    B, T, k = 2, 32, 8
    batch = train_batch_stub(cfg, batch=B, seq=T)
    x = model.embed(params, batch)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    xf, _, _ = model.backbone(params, x, pos,
                              positions3=batch.get("positions3"), mode="train")
    full = np.asarray(model.unembed(params, xf), np.float32)

    cache = model.init_cache(B, T + 8)
    if dtype == jnp.float32:
        cache = jax.tree_util.tree_map(
            lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
            cache)
    Tp = T - k
    pre = {kk: (v[:, :Tp] if kk != "positions3" else v[:, :, :Tp])
           for kk, v in batch.items() if kk != "labels"}
    logits_p, cache = jax.jit(model.prefill)(params, pre, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32), full[:, Tp - 1],
        atol=0.12, rtol=0.05)
    if not cfg.embed_inputs:
        return  # vlm stub: decode path uses the token table, not embeds
    dec = jax.jit(model.decode_step)
    for t in range(Tp, T):
        tok = batch["tokens"][:, t:t + 1]
        lg, cache = dec(params, cache, tok, jnp.int32(t))
        # atol covers bf16 rounding: the unrolled decode path and the scanned
        # train forward fuse (and therefore round) differently; in f32 the
        # two paths agree to 2e-5 (verified), and musicgen's summed-codebook
        # logits are O(20), where K summed codebooks amplify per-term
        # rounding — 0.5 abs is ~2% relative at that scale
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32), full[:, t],
            atol=0.5, rtol=0.03, err_msg=f"{arch} decode t={t}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_instantiated(arch, rng_key):
    """Analytic param_count (used for roofline MODEL_FLOPS) must match the
    actually instantiated smoke model within 2%."""
    cfg = get_config(arch).smoke()
    model = LM(cfg, remat=False)
    params = model.init(rng_key)
    actual = sum(int(np.prod(x.shape))
                 for x in jax.tree_util.tree_leaves(params))
    predicted, _ = param_count(cfg)
    assert abs(actual - predicted) / actual < 0.02, (arch, actual, predicted)


def test_sliding_window_masks_history(rng_key):
    """gemma-family local attention must not see beyond its window."""
    cfg = get_config("gemma3-27b").smoke().with_(
        n_layers=1, local_global_ratio=0, sliding_window=4)
    # single local layer via pattern: force all-local by ratio=0 ->
    # uniform_attn with sliding_window applied in serving path only; instead
    # test the layer directly
    from repro.models import layers as L
    p = L.init_attention(rng_key, cfg)
    B, T = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                          jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    out1, _ = L.attention(p, cfg, x, pos, window=4)
    # perturb a token >window in the past of the last query
    x2 = x.at[:, 2].set(x[:, 2] + 5.0)
    out2, _ = L.attention(p, cfg, x2, pos, window=4)
    # last position (15) must be identical: token 2 is outside its window
    np.testing.assert_allclose(np.asarray(out1[:, -1], np.float32),
                               np.asarray(out2[:, -1], np.float32),
                               atol=1e-2)
    # but position 3 must differ (token 2 is within ITS window)
    assert not np.allclose(np.asarray(out1[:, 3], np.float32),
                           np.asarray(out2[:, 3], np.float32), atol=1e-2)


def test_musicgen_multicodebook_loss_counts_all_books(rng_key):
    cfg = get_config("musicgen-large").smoke()
    model = LM(cfg, remat=False)
    params = model.init(rng_key)
    batch = train_batch_stub(cfg, batch=2, seq=16)
    loss, _ = model.loss_fn(params, batch)
    # perturbing only codebook 3's labels must change the loss
    batch2 = dict(batch)
    batch2["labels"] = batch["labels"].at[..., 3].set(
        (batch["labels"][..., 3] + 7) % cfg.vocab_size)
    loss2, _ = model.loss_fn(params, batch2)
    assert float(loss) != float(loss2)
