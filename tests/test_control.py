"""Control-plane tests: bundle composition invariants (hypothesis), packer
determinism, online controllers, per-route live caps, static-policy
bit-identity, the adaptive-beats-static acceptance property, kill/resume
digest-identity with controller/composer state, and the dashboard's
policy view + ETA guards."""
import dataclasses
import json

import pytest

from repro.control import (STATIC_POLICY, BundleComposer, BundleSizeTuner,
                           ConcurrencyTuner, ControlPlane, TransferPolicySpec)
from repro.control.policy import GB, TB
from repro.core.routes import Dataset, Route, RouteGraph, Site
from repro.core.snapshot import (SNAPSHOT_VERSION, CampaignKilled,
                                 Checkpointer, load_snapshot, resume_world,
                                 trajectory_summary)
from repro.core.transfer_table import Status
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import get_scenario, list_scenarios, register
from repro.scenarios.spec import FederationSpec


def _toy_catalog(sizes, files_each=10):
    return {f"/toy/ds-{i:03d}": Dataset(f"/toy/ds-{i:03d}", int(b),
                                        files_each, 2)
            for i, b in enumerate(sizes)}


# ------------------------------------------------------- composer invariants
@pytest.mark.parametrize("bundling", ("greedy", "balanced"))
def test_composer_partition_and_caps(bundling):
    catalog = _toy_catalog([5 * GB, 1 * GB, 30 * GB, 2 * GB, 2 * GB,
                            40 * GB, 1 * GB, 9 * GB])
    pol = TransferPolicySpec(bundling=bundling, target_bytes=10 * GB,
                             target_files=1000, max_bytes=10 * GB,
                             max_files=1000)
    comp = BundleComposer(catalog, pol, seed=0)
    bundles = comp.compose_all()
    assert comp.done
    # exactly-once partition, byte/file conservation
    seen = [k for b in bundles for k in comp.members[b.path]]
    assert sorted(seen) == sorted(catalog)
    assert sum(b.bytes for b in bundles) == sum(d.bytes
                                                for d in catalog.values())
    assert sum(b.files for b in bundles) == sum(d.files
                                                for d in catalog.values())
    # caps hold unless a single item already exceeds them
    for b in bundles:
        if len(comp.members[b.path]) > 1:
            assert b.bytes <= pol.max_bytes
            assert b.files <= pol.max_files


def test_composer_file_granularity_conserves_bytes():
    catalog = _toy_catalog([7 * GB, 3 * GB, 11 * GB], files_each=50)
    pol = TransferPolicySpec(bundling="balanced", granularity="file",
                             target_bytes=2 * GB, max_bytes=4 * GB,
                             target_files=40, max_files=80, balance_batch=3)
    comp = BundleComposer(catalog, pol, seed=3)
    bundles = comp.compose_all()
    assert sum(b.bytes for b in bundles) == sum(d.bytes
                                                for d in catalog.values())
    assert sum(b.files for b in bundles) == sum(d.files
                                                for d in catalog.values())
    # file items are "<path>#<a>:<b>" manifest runs; one dataset may span
    # bundles, and expanding every run must cover each file exactly once
    seen = sorted((path, i)
                  for b in bundles for k in comp.members[b.path]
                  for path, rng in [k.split("#")]
                  for i in range(*map(int, rng.split(":"))))
    want = sorted((p, i) for p, d in catalog.items()
                  for i in range(d.files))
    assert seen == want
    # a bundle holds several runs (runs are cut at 1/4 of the caps)
    assert any(len(comp.members[b.path]) > 1 for b in bundles)


def test_composer_deterministic_and_resumable():
    catalog = _toy_catalog([5 * GB, 1 * GB, 30 * GB, 2 * GB, 8 * GB,
                            40 * GB], files_each=20)
    pol = TransferPolicySpec(bundling="greedy", target_bytes=9 * GB,
                             max_bytes=9 * GB)
    a = BundleComposer(catalog, pol, seed=1)
    ref = [dataclasses.astuple(b) for b in a.compose_all()]
    b = BundleComposer(catalog, pol, seed=1)
    assert [dataclasses.astuple(x) for x in b.compose_all()] == ref
    # cut half, serialize, restore into a fresh composer, finish: identical
    c = BundleComposer(catalog, pol, seed=1)
    got = [dataclasses.astuple(x) for x in c.cut_next()]
    state = json.loads(json.dumps(c.state_dict()))     # through JSON
    d = BundleComposer(catalog, pol, seed=1)
    d.load_state_dict(state)
    while not d.done:
        got.extend(dataclasses.astuple(x) for x in d.cut_next())
    assert got == ref


def test_composer_property_hypothesis():
    pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed (see requirements-dev.txt)")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(st.lists(st.integers(min_value=1, max_value=64 * GB),
                    min_size=1, max_size=24),
           st.integers(min_value=1 * GB, max_value=16 * GB),
           st.integers(min_value=1, max_value=200),
           st.sampled_from(("greedy", "balanced")),
           st.integers(min_value=0, max_value=3))
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def run(sizes, max_bytes, max_files, bundling, seed):
        catalog = _toy_catalog(sizes, files_each=7)
        pol = TransferPolicySpec(bundling=bundling, max_bytes=max_bytes,
                                 target_bytes=max_bytes,
                                 max_files=max_files, target_files=max_files)
        comp = BundleComposer(catalog, pol, seed=seed)
        bundles = comp.compose_all()
        # every dataset in exactly one bundle
        seen = sorted(k for b in bundles for k in comp.members[b.path])
        assert seen == sorted(catalog)
        # caps hold unless a single item already exceeds them
        for b in bundles:
            members = comp.members[b.path]
            if len(members) > 1:
                assert b.bytes <= max_bytes
                assert b.files <= max_files
        # packing is deterministic for a fixed seed
        again = BundleComposer(catalog, pol, seed=seed)
        assert ([dataclasses.astuple(b) for b in again.compose_all()]
                == [dataclasses.astuple(b) for b in bundles])

    run()


def test_policy_validation():
    with pytest.raises(ValueError, match="granularity"):
        TransferPolicySpec(granularity="file").validate()
    with pytest.raises(ValueError, match="bundling"):
        TransferPolicySpec(bundling="magic").validate()
    with pytest.raises(ValueError, match="controller"):
        TransferPolicySpec(controller="aimd+nope").validate()
    TransferPolicySpec(bundling="greedy", controller="aimd+gradient") \
        .validate()
    # bundling + incremental top-ups is rejected at build time
    spec = get_scenario("incremental-top-up").with_policy(bundling="greedy")
    with pytest.raises(ValueError, match="top-ups"):
        spec.build(scale=0.004, n_datasets=8)


# ------------------------------------------------------------- controllers
class _FakePlane:
    def __init__(self, composer=None, default=2):
        self.caps = {}
        self.default = default
        self.composer = composer

    def route_cap(self, route):
        return self.caps.get(route, self.default)

    def set_route_cap(self, route, cap):
        self.caps[route] = cap


def test_aimd_increase_then_backoff():
    pol = TransferPolicySpec(controller="aimd", min_active_per_route=1,
                             max_active_per_route=6, fault_budget=8,
                             drop_fraction=0.15)
    tuner = ConcurrencyTuner(pol)
    plane = _FakePlane()
    r = ("LLNL", "ALCF")
    # steady throughput: additive increase, one slot per interval
    assert tuner.act(0.0, 3600.0, {r: (100 * GB, 0)}, plane)
    assert plane.route_cap(r) == 3
    tuner.act(3600.0, 3600.0, {r: (200 * GB, 0)}, plane)
    assert plane.route_cap(r) == 4
    # fault spike: multiplicative decrease
    tuner.act(7200.0, 3600.0, {r: (300 * GB, 20)}, plane)
    assert plane.route_cap(r) == 2
    # throughput collapse: halve again toward the floor
    tuner.act(10800.0, 3600.0, {r: (310 * GB, 20)}, plane)
    assert plane.route_cap(r) == 1
    # state round-trips through JSON
    back = ConcurrencyTuner(pol)
    back.load_state_dict(json.loads(json.dumps(tuner.state_dict())))
    assert back._last == tuner._last and back._last_tput == tuner._last_tput


def test_gradient_tuner_reverses_on_drop():
    catalog = _toy_catalog([50 * GB] * 20)
    pol = TransferPolicySpec(bundling="greedy", controller="gradient",
                             target_bytes=10 * GB, max_bytes=1 * TB,
                             min_target_bytes=1 * GB,
                             target_files=1000, max_files=100_000,
                             min_target_files=10, bundle_growth=1.5)
    comp = BundleComposer(catalog, pol, seed=0)
    tuner = BundleSizeTuner(pol)
    plane = _FakePlane(composer=comp)
    r = ("LLNL", "ALCF")
    assert tuner.act(0.0, 3600.0, {r: (0 * GB, 0)}, plane) == []  # anchor
    t0 = comp.target_bytes
    tuner.act(3600.0, 3600.0, {r: (100 * GB, 0)}, plane)
    assert comp.target_bytes > t0                    # growing
    grown = comp.target_bytes
    tuner.act(7200.0, 3600.0, {r: (120 * GB, 0)}, plane)  # tput fell 100->20
    assert comp.target_bytes < grown                 # direction reversed
    # floors/ceilings hold under repeated reversals
    for k in range(20):
        tuner.act(10800.0 + k, 3600.0, {r: (120 * GB + k, 0)}, plane)
        assert pol.min_target_bytes <= comp.target_bytes <= pol.max_bytes
        assert pol.min_target_files <= comp.target_files <= pol.max_files


def test_scheduler_honors_live_route_caps():
    spec = get_scenario("paper-2022")
    world = spec.build(scale=0.05, seed=0, n_datasets=12)
    r = ("LLNL", "ALCF")
    world.sched.policy.route_caps[r] = 5
    world.sched.step(0.0)
    assert world.table.count_route(*r, Status.ACTIVE) == 5
    assert world.table.count_route("LLNL", "OLCF", Status.ACTIVE) <= 2


# ------------------------------------------------- static-policy bit-identity
def test_default_policy_builds_no_control_plane():
    world = get_scenario("paper-2022").build(scale=0.004, n_datasets=8)
    assert world.control is None and world.runtime.control is None
    # an explicit STATIC_POLICY is the same declaration as the default
    assert get_scenario("paper-2022").with_policy(STATIC_POLICY) \
        == get_scenario("paper-2022")


@pytest.mark.parametrize("engine", ("events", "step"))
def test_static_policy_run_is_bit_identical(engine):
    """Acceptance: forcing STATIC_POLICY onto a policy scenario replays the
    same trajectory as building the identical workload with no policy
    machinery at all (both engines, digest included)."""
    spec = get_scenario("small-file-storm")
    naive = spec.with_policy(STATIC_POLICY)
    assert not naive.policy.enabled
    results = []
    for s in (naive, dataclasses.replace(naive)):
        world = s.build(scale=0.05, seed=0, n_datasets=48)
        assert world.control is None
        stats = EngineStats()
        rep = run_world(world, engine=engine, stats=stats)
        results.append(trajectory_summary(rep, stats, world.table))
    assert results[0] == results[1]
    assert results[0]["succeeded_digest"]


# -------------------------------------------------- adaptive beats static
def test_adaptive_beats_static_on_small_file_storm():
    """Acceptance: bundling + AIMD must finish the small-file catalog in no
    more simulated campaign days than naive per-dataset scheduling."""
    days = {}
    for label in ("adaptive", "static"):
        spec = get_scenario("small-file-storm")
        if label == "static":
            spec = spec.with_policy(STATIC_POLICY)
        rep = run_world(spec.build(scale=0.1, seed=0, n_datasets=96),
                        engine="events", stats=EngineStats())
        days[label] = rep.duration_days
        for got in rep.bytes_at.values():
            assert got >= rep.total_bytes * 0.999
    assert days["adaptive"] < days["static"]


def test_lossy_route_tuning_backs_off_concurrency():
    """Over-parallel start past the DTN knee: the AIMD tuner must act (the
    ledger records decisions) and must not lose to the static baseline."""
    spec = get_scenario("lossy-route-tuning")
    world = spec.build(scale=0.1, seed=0, n_datasets=32)
    assert world.control is not None
    rep = run_world(world, engine="events", stats=EngineStats())
    decisions = [e for e in world.control.ledger.entries
                 if e["controller"] == "aimd"]
    assert decisions, "AIMD never acted"
    assert any(e["cap"] < e["prev_cap"] for e in decisions), \
        "AIMD never backed off despite the contention knee"
    static = run_world(
        spec.with_policy(STATIC_POLICY).build(scale=0.1, seed=0,
                                              n_datasets=32),
        engine="events", stats=EngineStats())
    assert rep.duration_days <= static.duration_days


# ---------------------------------------------------------- kill/resume
@pytest.mark.parametrize("name,overrides", [
    ("small-file-storm", dict(scale=0.2, n_datasets=200)),
    ("lossy-route-tuning", dict(scale=0.1, n_datasets=32)),
    ("mixed-bundle-paper", dict(scale=0.01, n_datasets=16)),
])
def test_kill_resume_under_adaptive_policy(tmp_path, name, overrides):
    """Acceptance: kill at ~50% under ANY policy and resume digest-identical
    — including restored composer cursor, controller state, and caps."""
    spec = get_scenario(name)
    world = spec.build(seed=0, **overrides)
    stats = EngineStats()
    rep = run_world(world, stats=stats)
    ref = trajectory_summary(rep, stats, world.table)
    ref_ledger = (world.control.ledger.entries
                  if world.control is not None else [])

    world2 = spec.build(seed=0, **overrides)
    ck = Checkpointer(str(tmp_path), kill_after=max(1, stats.iterations // 2))
    with pytest.raises(CampaignKilled):
        run_world(world2, stats=EngineStats(), checkpointer=ck)
    snap = load_snapshot(str(tmp_path))
    assert snap.version == SNAPSHOT_VERSION and snap.control is not None
    w3, snap2, loop = resume_world(str(tmp_path))
    assert w3.control is not None
    stats3 = EngineStats()
    rep3 = run_world(w3, engine=snap2.engine, stats=stats3, resume=loop)
    assert trajectory_summary(rep3, stats3, w3.table) == ref
    assert (w3.control.ledger.entries
            if w3.control is not None else []) == ref_ledger


def test_static_forced_run_resumes(tmp_path):
    """A checkpoint written under the forced static baseline of an
    adaptive-by-default scenario must resume (the snapshot records the
    override; rebuilding with the registry's declared policy would fail)."""
    spec = get_scenario("small-file-storm").with_policy(STATIC_POLICY)
    world = spec.build(scale=0.05, seed=0, n_datasets=64)
    stats = EngineStats()
    rep = run_world(world, stats=stats)
    ref = trajectory_summary(rep, stats, world.table)

    world2 = spec.build(scale=0.05, seed=0, n_datasets=64)
    ck = Checkpointer(str(tmp_path), kill_after=max(1, stats.iterations // 2))
    with pytest.raises(CampaignKilled):
        run_world(world2, stats=EngineStats(), checkpointer=ck)
    assert load_snapshot(str(tmp_path)).policy_static
    # registry lookup path — NOT passing spec= — must re-apply the override
    w3, snap, loop = resume_world(str(tmp_path))
    assert w3.control is None
    stats3 = EngineStats()
    rep3 = run_world(w3, engine=snap.engine, stats=stats3, resume=loop)
    assert trajectory_summary(rep3, stats3, w3.table) == ref


def test_federation_tuner_only_touches_own_routes():
    """Per-member AIMD over a shared transport: a member must never write
    caps or ledger entries for routes its own scheduler cannot start."""
    base = get_scenario("federation-paper-twice")
    fed = dataclasses.replace(
        base.with_policy(TransferPolicySpec(
            controller="aimd", control_interval_s=6 * 3600.0)),
        name="federation-aimd-routes-test")
    register(fed)
    world = fed.build(scale=0.05, seed=0, n_datasets=10)
    run_world(world, engine="events", stats=EngineStats())
    for rt in world.runtimes:
        own = {rt.spec.source, *rt.spec.replicas}
        for (src, dst) in rt.sched.policy.route_caps:
            assert dst in rt.spec.replicas and src in own, (rt.label, src,
                                                           dst)
        for e in rt.control.ledger.entries:
            if "route" in e:
                assert tuple(e["route"])[1] in rt.spec.replicas, (rt.label, e)


def test_crash_resume_policy_scenario(tmp_path):
    from repro.scenarios.crash_resume import run_crash_resume
    spec = get_scenario("crash-resume-policy")
    res = run_crash_resume(spec, str(tmp_path), seed=0, scale=0.2,
                           n_datasets=200)
    assert res["kills"]
    assert res["match"], (res["reference"], res["resumed"])


def test_federation_with_policy_override_and_resume(tmp_path):
    """A federation forcing one adaptive policy onto every member: bundles
    are namespaced per member, both members complete, and kill/resume is
    digest-identical (per-member control blocks restored)."""
    from repro.scenarios.crash_resume import run_crash_resume
    base = get_scenario("federation-paper-twice")
    fed = dataclasses.replace(
        base.with_policy(TransferPolicySpec(
            bundling="greedy", controller="aimd",
            target_bytes=5 * TB, max_bytes=20 * TB,
            target_files=200_000, max_files=1_500_000,
            control_interval_s=12 * 3600.0)),
        name="federation-policy-test")
    register(fed)
    world = fed.build(scale=0.01, seed=0, n_datasets=10)
    for rt in world.runtimes:
        assert rt.control is not None and rt.control.composer is not None
    paths = [p for rt in world.runtimes
             for p in rt.control.composer.bundle_catalog]
    assert any(p.startswith("/bundle/alcf/") for p in paths)
    assert any(p.startswith("/bundle/olcf/") for p in paths)
    from repro.scenarios.crash_resume import CrashResumeSpec
    res = run_crash_resume(
        CrashResumeSpec(name="crash-fed-policy", description="",
                        base="federation-policy-test", kill_fracs=(0.5,)),
        str(tmp_path), seed=0, scale=0.01, n_datasets=10)
    assert res["kills"]
    assert res["match"], (res["reference"], res["resumed"])


# ------------------------------------------------------- transport plumbing
def test_task_setup_delays_scan():
    from repro.core.faults import FaultInjector, Notifier
    from repro.core.pause import PauseManager
    from repro.core.transport import SimClock, SimulatedTransport

    graph = RouteGraph(
        [Site("A", read_bw=GB, write_bw=GB, scan_files_per_s=100.0),
         Site("B", read_bw=GB, write_bw=GB)],
        [])
    clock = SimClock(0.0)
    tr = SimulatedTransport(graph, clock, PauseManager(), FaultInjector(),
                            Notifier(), task_setup_s=50.0)
    uid = tr.submit(Dataset("/d", 10, 10_000, 1), "A", "B")
    clock.advance(30.0)
    tr.tick()                       # 30 s: still inside the dispatch window
    x = tr._live[uid]
    assert x.phase == "scan" and x.setup_left == pytest.approx(20.0)
    assert x.scan_files_left == 10_000.0
    clock.advance(30.0)
    tr.tick()                       # 20 s of setup + 10 s of scanning
    assert x.setup_left == 0.0
    assert x.scan_files_left == pytest.approx(10_000.0 - 10.0 * 100.0)
    # the hint accounts for remaining setup (none) + scan time
    assert tr.next_event_hint() == pytest.approx(9_000.0 / 100.0)


def test_route_telemetry_accumulates():
    spec = get_scenario("paper-2022")
    world = spec.build(scale=0.02, seed=0, n_datasets=8)
    run_world(world, engine="events", stats=EngineStats())
    tel = world.transport.route_telemetry()
    assert tel
    total = sum(b for b, _ in tel.values())
    moved = sum(v for v in world.transport.flow_totals.values())
    assert total == pytest.approx(moved)


def test_contention_knee_degrades_effective_rate():
    g = RouteGraph(
        [Site("A", read_bw=10 * GB, write_bw=10 * GB, concurrency_knee=2),
         Site("B", read_bw=10 * GB, write_bw=10 * GB)],
        [Route("A", "B", 100 * GB)])
    r = ("A", "B")
    at2 = g.effective_rate(*r, {r: 2})
    at4 = g.effective_rate(*r, {r: 4})
    assert at2 == pytest.approx(10 * GB / 2)
    # beyond the knee the *aggregate* shrinks: 10 GB/s * (2/4) over 4 movers
    assert at4 == pytest.approx(10 * GB * (2 / 4) / 4)
    assert 4 * at4 < 2 * at2


# ------------------------------------------------------------- dashboard
def test_progress_rows_never_emit_inf_nan():
    from repro.core.dashboard import progress_rows
    from repro.core.transfer_table import TransferTable
    t = TransferTable()
    t.populate(["a", "b"], "LLNL", ["ALCF"])
    # a freshly resumed first tick: ACTIVE rows, zero rate, zero progress
    t.update("a", "ALCF", status=Status.ACTIVE, uuid="u1", rate=0.0)
    t.update("b", "ALCF", status=Status.ACTIVE, uuid="u2",
             rate=float("inf"))          # pathological per-row rate
    rows = progress_rows([("c", t, ["ALCF"], 100)])
    json.dumps(rows, allow_nan=False)    # must be JSON-clean
    (row,) = rows
    assert row["eta_days"] is None and row["rate"] == 0.0
    # zero-byte campaign: no division blowups either
    rows0 = progress_rows([("c", t, ["ALCF"], 0)])
    json.dumps(rows0, allow_nan=False)
    assert rows0[0]["complete_fraction"] == 0.0


def test_policy_dashboard_rows_and_render():
    from repro.core.dashboard import policy_rows, render_policy_text
    spec = get_scenario("lossy-route-tuning")
    world = spec.build(scale=0.1, seed=0, n_datasets=32)
    run_world(world, engine="events", stats=EngineStats())
    rows = policy_rows(world.control)
    kinds = {r["kind"] for r in rows}
    assert "caps" in kinds and "decision" in kinds
    txt = render_policy_text(world.control, world.clock.now)
    assert "caps" in txt and "aimd" in txt
    json.dumps(rows, allow_nan=False)


def test_new_scenarios_registered():
    names = list_scenarios()
    for required in ("small-file-storm", "mixed-bundle-paper",
                     "lossy-route-tuning"):
        assert required in names
    from repro.scenarios.registry import list_crash_scenarios
    assert "crash-resume-policy" in list_crash_scenarios()
