"""Checkpoint/restart, integrity fallback, replication, elastic reshard."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.checkpoint.replicate import CheckpointReplicator


def tree_example():
    return {
        "w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
        "b": jnp.ones((7,), jnp.bfloat16) * 1.5,
        "step_scale": jnp.float32(3.0),
        "nested": {"m": jnp.zeros((8, 2), jnp.float32)},
    }


def test_roundtrip_exact(tmp_path):
    t = tree_example()
    save_checkpoint(str(tmp_path), 5, t)
    got = restore_checkpoint(str(tmp_path), t)
    assert got is not None
    step, tree, d = got
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keeps_last_k_and_latest_wins(tmp_path):
    t = tree_example()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep=3)
    steps = sorted(int(n.split("-")[1]) for n in os.listdir(tmp_path))
    assert steps == [3, 4, 5]
    assert latest_step(str(tmp_path)) == 5


def test_corrupt_checkpoint_falls_back(tmp_path):
    t = tree_example()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t)
    # corrupt the newest
    d2 = os.path.join(tmp_path, "step-000002")
    victim = [f for f in os.listdir(d2) if f.startswith("leaf-")][0]
    with open(os.path.join(d2, victim), "r+b") as f:
        f.seek(100)
        f.write(b"\xff\xff\xff")
    got = restore_checkpoint(str(tmp_path), t)
    assert got is not None and got[0] == 1     # fell back to step 1


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = tree_example()
    save_checkpoint(str(tmp_path), 1, t)
    d = save_checkpoint(str(tmp_path), 2, t)
    os.remove(os.path.join(d, "COMMITTED"))    # simulate crash mid-commit
    got = restore_checkpoint(str(tmp_path), t)
    assert got is not None and got[0] == 1


def test_replicator_restores_from_replica_when_primary_lost(tmp_path):
    rep = CheckpointReplicator(str(tmp_path), primary="POD0",
                               replicas=("POD1", "STORE"))
    t = tree_example()
    ckpt_root = os.path.join(rep.site_dir("POD0"), "ckpts")
    d = save_checkpoint(ckpt_root, 7, t)
    rel = os.path.relpath(d, rep.site_dir("POD0"))
    assert rep.replicate(rel)
    # destroy the primary copy entirely (pod loss)
    shutil.rmtree(ckpt_root)
    got = rep.restore_anywhere("ckpts", t)
    assert got is not None
    step, tree, _, site = got
    assert step == 7 and site in ("POD1", "STORE")
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(t["w"]))


def test_elastic_reshard_plan():
    from repro.checkpoint.elastic import plan_reshard
    from jax.sharding import PartitionSpec as P
    tree = {"w": np.zeros((64, 64), np.float32)}
    specs = {"w": P("data", "model")}
    plan = plan_reshard(tree, {"data": 4, "model": 4},
                        {"data": 8, "model": 4}, specs)
    assert plan["total_bytes"] == 64 * 64 * 4
    assert plan["approx_bytes_moved_per_device"] > 0
