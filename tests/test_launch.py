"""Launcher CLIs, sharding rules, and the HLO collective parser."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import shardings as SH
from repro.launch.analytic import analytic_cost
from repro.models.config import param_count
from repro.models.model import LM


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def test_param_specs_cover_every_leaf():
    """Every param leaf gets a spec; non-divisible axes are dropped."""
    mesh = _mesh11()
    for arch in ("smollm-135m", "deepseek-v2-lite-16b", "falcon-mamba-7b",
                 "zamba2-1.2b", "gemma3-27b"):
        cfg = get_config(arch).smoke()
        model = LM(cfg, remat=False)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = SH.param_specs(shapes, cfg, mesh)
        n_leaves = len(jax.tree_util.tree_leaves(
            shapes, is_leaf=lambda x: hasattr(x, "shape")))
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_leaves, arch


def test_logical_rules_divisibility_gate():
    """Head sharding enabled only when KV heads divide the TP axis."""
    class FakeMesh:                           # emulate tp=16 on 1-device CPU
        shape = {"data": 16, "model": 16}
    cfg_div = get_config("gemma3-27b")        # kv=16 -> divisible
    cfg_odd = get_config("qwen3-14b")         # kv=8, H=40 -> not divisible
    rules_div = SH.logical_rules(FakeMesh(), 256, cfg_div)
    rules_odd = SH.logical_rules(FakeMesh(), 256, cfg_odd)
    assert rules_div["heads"] == "model"
    assert rules_odd["heads"] is None         # 8 kv heads % 16 != 0


def test_analytic_cost_sane():
    """Analytic FLOPs must dominate MODEL_FLOPS (waste >= 0) and train must
    cost more than prefill per token."""
    cfg = get_config("qwen3-14b")
    train = analytic_cost(cfg, 256, 4096, "train")
    prefill = analytic_cost(cfg, 32, 32768, "prefill")
    decode = analytic_cost(cfg, 128, 32768, "decode")
    assert train["flops"] > train["model_flops"]
    assert prefill["flops"] > prefill["model_flops"] * 0.5
    # decode reads the whole cache per step
    assert decode["bytes"] > 0 and decode["flops"] > 0
    tot, act = param_count(cfg)
    assert tot == act  # dense


def test_collective_parser_trip_counts():
    """Synthetic HLO (XLA-style op naming): an all-reduce inside a while body
    whose xs have leading dim 6 must be counted 6x; nested whiles multiply
    (trip counts recovered from each body's dynamic-slice over its xs)."""
    from repro.launch.dryrun import parse_collectives
    hlo = """
%inner_body (p: (s32[], f32[4,2])) -> (s32[], f32[4,2]) {
  %gte.0 = f32[4,2] get-tuple-element(%p), index=1
  %ds.0 = f32[1,2] dynamic-slice(%gte.0, %i, %z), dynamic_slice_sizes={1,2}
  %all-reduce.0 = f32[2,2] all-reduce(%x), channel_id=1, replica_groups=[4,4]<=[16], to_apply=%add
}
%outer_body (q: (s32[], f32[6,8])) -> (s32[], f32[6,8]) {
  %gte.1 = f32[6,8] get-tuple-element(%q), index=1
  %ds.1 = f32[1,8] dynamic-slice(%gte.1, %j, %z2), dynamic_slice_sizes={1,8}
  %w.0 = (s32[], f32[4,2]) while(%t0), condition=%c1, body=%inner_body
  %all-reduce.1 = f32[8] all-reduce(%y), channel_id=2, replica_groups=[4,4]<=[16], to_apply=%add
}
ENTRY %main (a: f32[6,8]) -> f32[8] {
  %w.1 = (s32[], f32[6,8]) while(%t1), condition=%c2, body=%outer_body
  %all-reduce.2 = f32[16] all-reduce(%a2), channel_id=3, replica_groups=[4,4]<=[16], to_apply=%add
}
"""
    out = parse_collectives(hlo, scan_lengths=(6, 4))
    # entry: 1; outer (x6): 6; inner (x6x4): 24 -> total 31 all-reduces
    assert out["counts"]["all-reduce"] == 31, out["counts"]


def test_cache_specs_shard_batch_and_heads():
    mesh = _mesh11()
    cfg = get_config("musicgen-large").smoke()
    model = LM(cfg, remat=False)
    shapes = jax.eval_shape(lambda: model.init_cache(8, 64))
    specs = SH.cache_specs(shapes, 8, 64, mesh, "data")
    for leaf in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(leaf, P)


def test_train_cli_smoke(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "smollm-135m", "--steps", "6", "--batch", "2",
               "--seq", "32", "--ckpt-dir", str(tmp_path / "ck"),
               "--ckpt-every", "3"])
    assert rc == 0


def test_serve_cli_smoke():
    from repro.launch.serve import main
    rc = main(["--arch", "smollm-135m", "--requests", "2", "--max-new", "3",
               "--max-batch", "2", "--max-seq", "64"])
    assert rc == 0
