"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.faults import FaultInjector, Notifier, RetryPolicy
from repro.core.pause import DAY, PauseManager
from repro.core.routes import GB, make_catalog, paper_route_graph
from repro.core.scheduler import ReplicationPolicy, ReplicationScheduler
from repro.core.transfer_table import Status, TransferTable
from repro.core.transport import SimClock, SimulatedTransport
from repro.kernels.checksum.ref import checksum_bytes_np
from repro.optim.grad_compress import dequantize_int8, quantize_int8

SLOW = settings(max_examples=15, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------- checksum
@given(st.binary(min_size=1, max_size=4096),
       st.integers(min_value=0, max_value=32767))
@settings(max_examples=60, deadline=None)
def test_checksum_detects_single_bit_flip(data, pos_seed):
    pos = pos_seed % len(data)
    bit = 1 << (pos_seed % 8)
    mutated = bytearray(data)
    mutated[pos] ^= bit
    assert checksum_bytes_np(data) != checksum_bytes_np(bytes(mutated))


@given(st.binary(min_size=0, max_size=2048))
@settings(max_examples=40, deadline=None)
def test_checksum_deterministic(data):
    assert checksum_bytes_np(data) == checksum_bytes_np(data)


@given(st.binary(min_size=2, max_size=512), st.integers(1, 7))
@settings(max_examples=40, deadline=None)
def test_checksum_detects_truncation(data, k):
    k = k % len(data) or 1
    assert checksum_bytes_np(data) != checksum_bytes_np(data[:-k])


# ------------------------------------------------------------ quantization
@given(st.lists(st.floats(min_value=-1e3, max_value=1e3,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=256))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_error_bound(vals):
    x = np.asarray(vals, np.float32)
    q, s = quantize_int8(x)
    err = np.max(np.abs(dequantize_int8(q, s) - x))
    # half-step rounding bound
    assert err <= float(s) * 0.5 + 1e-6


# ------------------------------------------------- bandwidth conservation
@st.composite
def _mover_populations(draw):
    """An arbitrary topology plus an arbitrary live-mover population: sites
    with random read/write caps, a random subset of directed routes, and
    0..6 concurrent transfers per route — one campaign's movers or the
    union of many federated campaigns' movers (the allocator cannot tell
    the difference: it sees one shared population)."""
    n_sites = draw(st.integers(2, 5))
    sites = [f"S{i}" for i in range(n_sites)]
    caps = {s: (draw(st.floats(0.05, 10.0)) * GB,
                draw(st.floats(0.05, 10.0)) * GB) for s in sites}
    pairs = [(a, b) for a in sites for b in sites if a != b]
    chosen = draw(st.lists(st.sampled_from(pairs), min_size=1, max_size=8,
                           unique=True))
    routes = {p: draw(st.floats(0.01, 8.0)) * GB for p in chosen}
    actives = {p: draw(st.integers(0, 6)) for p in chosen}
    return caps, routes, actives


@given(_mover_populations())
@settings(max_examples=80, deadline=None)
def test_fair_share_never_exceeds_site_or_route_caps(pop):
    """The fair-share allocator conserves capacity for ANY mover population:
    per route, rate x actives <= route bandwidth; per site, aggregate egress
    <= read_bw and aggregate ingress <= write_bw.  This is the invariant
    that makes federated campaigns contend correctly — N campaigns' movers
    are just a bigger population on the same shared caps."""
    from repro.core.routes import Route, RouteGraph, Site
    caps, routes, actives = pop
    graph = RouteGraph(
        [Site(s, read_bw=r, write_bw=w) for s, (r, w) in caps.items()],
        [Route(a, b, bw) for (a, b), bw in routes.items()])
    population = {r: n for r, n in actives.items() if n > 0}
    rates = {r: graph.effective_rate(r[0], r[1], population)
             for r in population}
    eps = 1e-6
    for r, n in population.items():
        assert rates[r] * n <= routes[r] * (1 + eps)
    for s, (read_bw, write_bw) in caps.items():
        egress = sum(rates[r] * n for r, n in population.items()
                     if r[0] == s)
        ingress = sum(rates[r] * n for r, n in population.items()
                      if r[1] == s)
        assert egress <= read_bw * (1 + eps)
        assert ingress <= write_bw * (1 + eps)


# --------------------------------------------------- scheduler invariants
@given(seed=st.integers(0, 10_000),
       n=st.integers(4, 14),
       maint_start=st.floats(0.1, 5.0),
       maint_days=st.floats(0.1, 3.0))
@SLOW
def test_campaign_always_converges_and_loses_nothing(seed, n, maint_start,
                                                     maint_days):
    """For random catalogs, fault seeds, and maintenance windows: the Figure-4
    machine terminates with every dataset SUCCEEDED (or QUARANTINED with a
    notification) at every replica, and no table row is ever lost."""
    graph = paper_route_graph()
    catalog = {d.path: d for d in make_catalog(
        n, total_bytes=n * GB, total_files=n * 50, total_dirs=n * 5,
        seed=seed)}
    clock = SimClock()
    pause = PauseManager()
    pause.add_window("ALCF", maint_start * DAY,
                     (maint_start + maint_days) * DAY)
    injector = FaultInjector(seed=seed)
    notifier = Notifier()
    retry = RetryPolicy(max_retries=3, backoff_s=60.0)
    transport = SimulatedTransport(graph, clock, pause, injector, notifier,
                                   retry)
    table = TransferTable()
    sched = ReplicationScheduler(table, transport, catalog,
                                 ReplicationPolicy("LLNL", ("ALCF", "OLCF")),
                                 retry, notifier)
    sched.populate()
    assert table.count_status(*list(Status)) == 2 * len(catalog)
    while clock.now < 100 * DAY and not sched.done():
        sched.step(clock.now)
        clock.advance(1800.0)
        transport.tick()
    assert sched.done(), "campaign did not converge"
    rows = table.all()
    assert len(rows) == 2 * len(catalog)          # no row lost
    for r in rows:
        assert r.status in (Status.SUCCEEDED, Status.QUARANTINED)
        if r.status == Status.QUARANTINED:
            assert any(r.dataset in m for m in notifier.notifications)
    # concurrency cap was never breached is enforced structurally; check the
    # relay property: LLNL read each dataset at most (1 + retries) times
    for ds in catalog:
        llnl_reads = sum(1 for r in rows
                         if r.dataset == ds and r.source == "LLNL"
                         and r.status == Status.SUCCEEDED)
        assert llnl_reads <= 2


# ----------------------------------------------------- data pipeline resume
@given(seed=st.integers(0, 1000), cut=st.integers(1, 12))
@settings(max_examples=20, deadline=None)
def test_sharded_dataset_exact_resume(tmp_path_factory, seed, cut):
    from repro.data.sharded import IterState, ShardedDataset, write_shards
    root = str(tmp_path_factory.mktemp(f"ds{seed}_{cut}"))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 1000, 4096, dtype=np.int32)
    write_shards(root, toks, shard_len=256)
    ds = ShardedDataset(root)
    it = ds.batches(batch=2, seq=33)
    ref, states = [], []
    for _ in range(cut + 4):
        b, s = next(it)
        ref.append(b["tokens"].copy())
        states.append(s)
    # resume from the state after batch `cut`
    it2 = ds.batches(batch=2, seq=33, state=states[cut])
    for i in range(cut + 1, cut + 4):
        b, _ = next(it2)
        np.testing.assert_array_equal(b["tokens"], ref[i])


# ----------------------------------------------------- streaming checksum
@given(st.binary(min_size=0, max_size=2048),
       st.lists(st.integers(0, 3), min_size=0, max_size=64),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=60, deadline=None)
def test_streaming_checksum_any_chunking(data, small_chunks, seed):
    """StreamingChecksum folded over ANY split of the buffer — including
    <=3-byte chunks (smaller than one 4-byte word) and zero-length updates —
    is bit-identical to hashing the whole buffer at once.  This is the
    contract scrub re-verification and the LocalFS transport both lean on."""
    from repro.core.integrity import StreamingChecksum
    s = StreamingChecksum()
    i = 0
    # lead with the adversarial tiny chunks, then random-sized remainder
    for step in small_chunks:
        s.update(data[i:i + step])
        i += step
    rng = np.random.default_rng(seed)
    while i < len(data):
        step = int(rng.integers(0, 64))
        s.update(data[i:i + step])
        i += step
    s.update(b"")
    assert s.digest() == checksum_bytes_np(data)


# ---------------------------------------------- batched fair-share pricer
_FS_ROUTES = (("A", "B"), ("A", "C"), ("B", "C"), ("C", "B"),
              ("B", "D"), ("D", "C"), ("D", "A"))   # D->A absent from graph


def _fs_graph():
    from repro.core.routes import Route, RouteGraph, Site
    sites = [Site("A", read_bw=1.5 * GB, write_bw=1.5 * GB,
                  concurrency_knee=3),
             Site("B", read_bw=10 * GB, write_bw=10 * GB,
                  concurrency_knee=6),
             Site("C", read_bw=10 * GB, write_bw=10 * GB),
             Site("D", read_bw=2 * GB, write_bw=2 * GB)]
    routes = [Route(s, d, (1.3 + 0.7 * i) * GB)
              for i, (s, d) in enumerate(_FS_ROUTES[:-1])]
    return RouteGraph(sites, routes)


@given(st.lists(st.integers(0, 5), min_size=len(_FS_ROUTES),
                max_size=len(_FS_ROUTES)),
       st.lists(st.integers(0, 8), min_size=4, max_size=4))
@settings(max_examples=60, deadline=None)
def test_batch_fair_share_matches_scalar_for_any_population(counts, readers):
    """The one-shot array fair-share pricer must agree bit-for-bit with the
    scalar ``effective_rate`` walk for ANY mover population — including
    routes the graph doesn't know (0.0) and reader pseudo-routes from the
    demand engine — and the allocation must conserve the per-route and
    per-site read/write caps."""
    graph = _fs_graph()
    transport = SimulatedTransport(graph, SimClock(), PauseManager(),
                                   FaultInjector(seed=0), Notifier())

    class Mover:
        def __init__(self, src, dst):
            self.source, self.destination = src, dst

    movers = [Mover(*r) for r, c in zip(_FS_ROUTES, counts)
              for _ in range(c)]
    transport.set_read_load("users", {
        site: n for site, n in zip("ABCD", readers)})
    rates = transport._route_rates(movers)

    pop = {}
    for x in movers:
        r = (x.source, x.destination)
        pop[r] = pop.get(r, 0) + 1
    assert set(rates) == set(pop)
    full = dict(pop)
    for site, n in transport._reader_streams().items():
        full[(site, "__readers__")] = n
    for (src, dst), rate in rates.items():
        assert rate == graph.effective_rate(src, dst, full)

    eps = 1e-6
    egress, ingress = {}, {}
    for (src, dst), n in pop.items():
        r = graph.route(src, dst)
        assert rates[(src, dst)] * n <= (
            (r.bandwidth if r else 0.0) * (1 + eps))
        egress[src] = egress.get(src, 0.0) + rates[(src, dst)] * n
        ingress[dst] = ingress.get(dst, 0.0) + rates[(src, dst)] * n
    for site, tot in egress.items():
        assert tot <= graph.sites[site].read_bw * (1 + eps)
    for site, tot in ingress.items():
        assert tot <= graph.sites[site].write_bw * (1 + eps)
