"""The O(active) performance contract.

Per-iteration work in the simulation hot path must scale with *live*
transfers (bounded by max_active_per_route × routes), never with catalog
size: transport polls, table rows materialized per step, the live transfer
pool, and telemetry growth are all asserted here, plus the behavioral
guarantees the optimizations must preserve (vectorized == scalar mover,
cache == database, streamed == whole-buffer checksums).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.integrity import StreamingChecksum, file_checksum
from repro.core.pause import DAY
from repro.core.transfer_table import Status, TransferTable
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import get_scenario


def _instrumented_run(n_datasets, scale=0.02, seed=0):
    """Run paper-2022 under the event engine counting, per iteration, the
    transport polls issued and the table rows materialized by ``by_status``."""
    world = get_scenario("paper-2022").build(scale=scale, seed=seed,
                                             n_datasets=n_datasets)
    counts = {"polls": 0, "rows": 0, "live_max": 0}
    orig_poll = world.transport.poll

    def poll(uid):
        counts["polls"] += 1
        return orig_poll(uid)

    orig_by_status = world.table.by_status

    def by_status(*a, **kw):
        rows = orig_by_status(*a, **kw)
        counts["rows"] += len(rows)
        return rows

    world.transport.poll = poll
    world.table.by_status = by_status

    def observer(world, now):
        counts["live_max"] = max(counts["live_max"],
                                 world.transport.live_count)

    stats = EngineStats()
    run_world(world, engine="events", stats=stats, on_iteration=observer)
    return counts, stats, world


# --------------------------------------------------------- O(active) contract
def test_per_iteration_work_scales_with_live_not_catalog():
    """4x the catalog must not change per-iteration poll counts or row
    volume: both are bounded by the live-transfer pool (≤ 2 per route)."""
    small_counts, small_stats, small_world = _instrumented_run(60)
    big_counts, big_stats, big_world = _instrumented_run(240)
    max_live = (small_world.spec.max_active_per_route
                * len(small_world.graph.routes))

    for counts, stats in ((small_counts, small_stats),
                          (big_counts, big_stats)):
        assert counts["live_max"] <= max_live
        polls_per_iter = counts["polls"] / stats.iterations
        rows_per_iter = counts["rows"] / stats.iterations
        # _poll touches each live row once; re-admission & pause checks may
        # re-materialize a handful more — but never the catalog
        assert polls_per_iter <= max_live
        assert rows_per_iter <= 4 * max_live
    small_rate = small_counts["rows"] / small_stats.iterations
    big_rate = big_counts["rows"] / big_stats.iterations
    assert big_rate <= 1.5 * small_rate + 5.0


def test_terminal_transfers_evicted_from_live_pool():
    """Finished transfers leave the live pool (tick/poll/next_event_hint
    never touch them again) but their final state stays pollable."""
    counts, stats, world = _instrumented_run(24)
    assert world.sched.done()
    assert world.transport.live_count == 0
    rec = world.table.by_status(Status.SUCCEEDED)[0]
    st = world.transport.poll(rec.uuid)        # archived, still answers
    assert st.status == Status.SUCCEEDED
    assert st.bytes_done > 0
    assert world.transport.next_event_hint() == float("inf")


def test_flow_telemetry_bounded_by_days_times_routes():
    """Satellite: flow telemetry aggregates per (day, route) — its size is
    bounded by the calendar, not by movers × ticks."""
    _, _, world = _instrumented_run(60)
    flows = world.transport.flow_totals
    assert flows
    days = world.clock.now / DAY
    assert len(flows) <= (int(days) + 1) * len(world.graph.routes)
    for (day, route), nbytes in flows.items():
        assert isinstance(day, int)
        assert route in world.graph.routes
        assert nbytes > 0
    # every byte that landed anywhere is accounted for in the flow telemetry
    total_flow = sum(flows.values())
    total_landed = sum(world.table.bytes_at(r)
                       for r in world.spec.replicas)
    assert total_flow == pytest.approx(total_landed, rel=1e-6)


# ------------------------------------------------- vectorized mover fidelity
def test_vectorized_mover_matches_scalar_exactly():
    """The SoA fast path mirrors the segment-exact scalar walk operation-for-
    operation: trajectories must be identical, not merely close."""
    reports = {}
    for vectorized in (True, False):
        world = get_scenario("paper-2022").build(scale=0.02, seed=0,
                                                 n_datasets=24)
        world.transport.vectorized = vectorized
        stats = EngineStats()
        reports[vectorized] = (run_world(world, engine="events",
                                         stats=stats), stats)
    vec, vec_stats = reports[True]
    sca, sca_stats = reports[False]
    assert vec.duration_days == pytest.approx(sca.duration_days, rel=1e-12)
    assert vec_stats.iterations == sca_stats.iterations
    assert vec.bytes_at == sca.bytes_at
    assert vec.faults_total == sca.faults_total
    assert vec.fault_histogram == sca.fault_histogram


# ----------------------------------------------------- cache == durable store
def test_table_cache_consistent_with_sqlite_after_campaign():
    """The write-through cache and the sqlite store must agree row for row
    after a full campaign (every mutation path exercised: populate, update,
    update_many, re-admission, re-routing)."""
    _, _, world = _instrumented_run(30, seed=3)
    table = world.table
    cached = {(r.dataset, r.destination): r for r in table.all()}
    stored = {(r.dataset, r.destination): r for r in table._select_db("", ())}
    assert cached.keys() == stored.keys()
    for key, rec in cached.items():
        assert rec == stored[key], key
    # derived indexes agree with ground truth
    for st in Status:
        want = sum(1 for r in stored.values() if r.status == st)
        assert table.count_status(st) == want, st
    for dst in world.spec.replicas:
        want_bytes = sum(r.bytes_transferred for r in stored.values()
                         if r.destination == dst
                         and r.status == Status.SUCCEEDED)
        assert table.bytes_at(dst) == want_bytes
        want_ds = {r.dataset for r in stored.values()
                   if r.destination == dst and r.status == Status.SUCCEEDED}
        assert set(table.succeeded_datasets(dst)) == want_ds


def test_table_update_missing_row_is_noop():
    t = TransferTable()
    t.populate(["a"], "LLNL", ["ALCF"])
    t.update("nope", "ALCF", status=Status.SUCCEEDED)   # matches no row
    assert t.get("nope", "ALCF") is None
    assert t.count_status(Status.SUCCEEDED) == 0
    assert not t.done()


def test_by_status_limit_and_source_filter():
    t = TransferTable()
    t.populate(["a", "b", "c", "d"], "LLNL", ["ALCF"])
    t.update("b", "ALCF", source="OLCF")
    rows = t.by_status(Status.NULL, destination="ALCF", source="LLNL")
    assert [r.dataset for r in rows] == ["a", "c", "d"]
    rows = t.by_status(Status.NULL, destination="ALCF", limit=2)
    assert [r.dataset for r in rows] == ["a", "b"]


# ----------------------------------------------------- streaming checksumming
def test_streaming_checksum_matches_whole_buffer():
    rng = np.random.default_rng(0)
    data = rng.bytes(3 * 4096 + 3)            # deliberately word-misaligned
    want = file_checksum(data)
    for sizes in ([len(data)], [1, 2, 3, 5, 7, len(data)], [4096] * 4,
                  [1] * 64 + [len(data)]):
        s = StreamingChecksum()
        off = 0
        for sz in sizes:
            s.update(data[off:off + sz])
            off += sz
            if off >= len(data):
                break
        s.update(data[off:])
        assert s.digest() == want
    assert StreamingChecksum().digest() == file_checksum(b"")


def test_streaming_checksum_order_sensitive():
    a, b = b"chunk-one!", b"chunk-two?"
    h1 = StreamingChecksum().update(a).update(b).digest()
    h2 = StreamingChecksum().update(b).update(a).digest()
    assert h1 == file_checksum(a + b)
    assert h2 == file_checksum(b + a)
    assert h1 != h2
