"""Tests for the paper's replication state machine (Figure 4), transports,
pause handling, faults, integrity, dashboard, and incremental replication."""
import os

import numpy as np
import pytest

from repro.core.campaign import CampaignConfig, run_campaign
from repro.core.dashboard import render_text, snapshot
from repro.core.faults import FaultInjector, Notifier, RetryPolicy
from repro.core.incremental import IncrementalReplicator, PublishFeed
from repro.core.pause import DAY, PauseManager
from repro.core.routes import (GB, Dataset, Route, RouteGraph, Site,
                               make_catalog, paper_route_graph,
                               split_oversized)
from repro.core.scheduler import ReplicationPolicy, ReplicationScheduler
from repro.core.transfer_table import Status, TransferTable, TransferRecord
from repro.core.transport import (LocalFSTransport, SimClock,
                                  SimulatedTransport)


def small_world(n_datasets=12, seed=0, unreadable=()):
    graph = paper_route_graph()
    catalog = {}
    for i, ds in enumerate(make_catalog(n_datasets, total_bytes=n_datasets * GB,
                                        total_files=n_datasets * 100,
                                        total_dirs=n_datasets * 10, seed=seed)):
        ds.unreadable = i in unreadable
        catalog[ds.path] = ds
    clock = SimClock()
    pause = PauseManager()
    injector = FaultInjector(seed=seed)
    notifier = Notifier()
    retry = RetryPolicy(max_retries=3, backoff_s=60.0)
    transport = SimulatedTransport(graph, clock, pause, injector, notifier, retry)
    table = TransferTable()
    sched = ReplicationScheduler(table, transport, catalog,
                                 ReplicationPolicy("LLNL", ("ALCF", "OLCF")),
                                 retry, notifier)
    sched.populate()
    return graph, catalog, clock, pause, transport, table, sched, notifier


def drive(clock, transport, sched, days=30.0, dt=600.0):
    while clock.now < days * DAY:
        sched.step(clock.now)
        clock.advance(dt)
        transport.tick()
        if sched.done():
            return True
    return sched.done()


# ------------------------------------------------------------- table basics
def test_table_populate_two_rows_per_dataset():
    t = TransferTable()
    n = t.populate(["a", "b", "c"], "LLNL", ["ALCF", "OLCF"])
    assert n == 6
    assert t.count_status(Status.NULL) == 6
    assert not t.done()


def test_table_update_and_done():
    t = TransferTable()
    t.populate(["a"], "LLNL", ["ALCF"])
    t.update("a", "ALCF", status=Status.SUCCEEDED, bytes_transferred=10)
    assert t.done()
    rec = t.get("a", "ALCF")
    assert rec.status == Status.SUCCEEDED and rec.bytes_transferred == 10


# --------------------------------------------------------- scheduler basics
def test_concurrency_cap_two_per_route():
    _, _, clock, _, transport, table, sched, _ = small_world(10)
    sched.step(clock.now)
    assert table.count_route("LLNL", "ALCF", Status.ACTIVE) == 2
    # OLCF direct transfers only start when ALCF is paused
    assert table.count_route("LLNL", "OLCF", Status.ACTIVE) == 0


def test_full_replication_completes_everywhere():
    _, catalog, clock, _, transport, table, sched, _ = small_world(10)
    assert drive(clock, transport, sched, days=40)
    for ds in catalog:
        for dst in ("ALCF", "OLCF"):
            assert table.get(ds, dst).status == Status.SUCCEEDED


def test_relay_preferred_over_slow_source():
    """Most OLCF copies must arrive via the ALCF relay, not from LLNL
    (the paper's C2: read the slow source once)."""
    _, _, clock, _, transport, table, sched, _ = small_world(16)
    assert drive(clock, transport, sched, days=60)
    via_relay = sum(1 for r in table.all()
                    if r.destination == "OLCF" and r.source == "ALCF")
    via_llnl = sum(1 for r in table.all()
                   if r.destination == "OLCF" and r.source == "LLNL")
    assert via_relay > via_llnl


def test_pause_reroutes_to_secondary():
    """While ALCF is in maintenance, LLNL->OLCF transfers must start (2c)."""
    _, _, clock, pause, transport, table, sched, _ = small_world(10)
    # get some ALCF transfers running, then pause ALCF
    sched.step(clock.now)
    clock.advance(600)
    transport.tick()
    pause.add_window("ALCF", clock.now, clock.now + 2 * DAY)
    for _ in range(10):
        sched.step(clock.now)
        clock.advance(600)
        transport.tick()
    assert table.count_route("LLNL", "OLCF",
                             Status.ACTIVE, Status.SUCCEEDED) > 0
    # paused transfers were not lost
    assert table.count_status(Status.PAUSED) >= 0
    assert drive(clock, transport, sched, days=40)


def test_persistent_fault_quarantines_then_recovers_after_fix():
    _, catalog, clock, _, transport, table, sched, notifier = small_world(
        6, unreadable=(1,))
    bad = [p for p, d in catalog.items() if d.unreadable][0]
    # run a while: the unreadable dataset should fail and notify
    drive(clock, transport, sched, days=10)
    assert any(bad in n for n in notifier.notifications)
    # human fixes it; replication completes
    notifier.fix(bad)
    assert drive(clock, transport, sched, days=60)
    assert table.get(bad, "ALCF").status == Status.SUCCEEDED


def test_oversized_scan_split():
    ds = Dataset("/big", bytes=10 * GB, files=10_000_000, directories=100)
    parts = split_oversized(ds, scan_limit_files=3_000_000)
    assert len(parts) == 4
    assert sum(p.files for p in parts) <= ds.files
    assert all(p.files <= 3_000_000 for p in parts)


# ------------------------------------------------------------- local FS
def test_localfs_transport_moves_and_verifies(tmp_path):
    root = str(tmp_path)
    src = os.path.join(root, "A", "data", "set1")
    os.makedirs(os.path.join(src, "sub"))
    rng = np.random.default_rng(0)
    for i, p in enumerate(["f0.bin", "sub/f1.bin"]):
        with open(os.path.join(src, p), "wb") as f:
            f.write(rng.bytes(1000 + i))
    tr = LocalFSTransport(root)
    uid = tr.submit(Dataset("data/set1", 2001, 2, 2), "A", "B")
    st = tr.poll(uid)
    assert st.status == Status.SUCCEEDED
    assert st.files_done == 2 and st.faults == 0
    with open(os.path.join(root, "B", "data", "set1", "f0.bin"), "rb") as f:
        got = f.read()
    with open(os.path.join(src, "f0.bin"), "rb") as f:
        want = f.read()
    assert got == want


def test_localfs_transport_detects_and_retransmits_corruption(tmp_path):
    root = str(tmp_path)
    src = os.path.join(root, "A", "ds")
    os.makedirs(src)
    with open(os.path.join(src, "f.bin"), "wb") as f:
        f.write(b"payload" * 100)
    flips = {"n": 0}

    def corruptor(path, data):
        if flips["n"] == 0:          # corrupt only the first attempt
            flips["n"] += 1
            return data[:-1] + bytes([data[-1] ^ 1])
        return data

    tr = LocalFSTransport(root, corruptor=corruptor)
    uid = tr.submit(Dataset("ds", 700, 1, 1), "A", "B")
    st = tr.poll(uid)
    assert st.status == Status.SUCCEEDED
    assert st.faults == 1            # one integrity fault, then retransmit
    with open(os.path.join(root, "B", "ds", "f.bin"), "rb") as f:
        assert f.read() == b"payload" * 100


# -------------------------------------------------------------- incremental
def test_incremental_replication_picks_up_new_datasets():
    _, catalog, clock, _, transport, table, sched, _ = small_world(4)
    feed = PublishFeed()
    inc = IncrementalReplicator(feed, sched, check_interval=DAY)
    drive(clock, transport, sched, days=20)
    assert sched.done()
    new = Dataset("/css03_data/CMIP6/NEW/late-dataset", 2 * GB, 100, 10)
    feed.publish(clock.now + 1, new)
    clock.advance(2 * DAY)
    added = inc.maybe_check(clock.now)
    assert new.path in added
    assert not sched.done()
    assert drive(clock, transport, sched, days=60)
    assert table.get(new.path, "OLCF").status == Status.SUCCEEDED


# ---------------------------------------------------------------- dashboard
def test_dashboard_renders():
    _, catalog, clock, _, transport, table, sched, _ = small_world(6)
    for _ in range(5):
        sched.step(clock.now)
        clock.advance(600)
        transport.tick()
    total = sum(d.bytes for d in catalog.values())
    txt = render_text(table, ["ALCF", "OLCF"], total, clock.now)
    assert "Replication to ALCF" in txt and "Replication to OLCF" in txt
    snap = snapshot(table, ["ALCF", "OLCF"], total, clock.now)
    assert set(snap["destinations"]) == {"ALCF", "OLCF"}


# ----------------------------------------------------------------- campaign
def test_reduced_campaign_completes_and_relays():
    cfg = CampaignConfig(n_datasets=60, scale=0.02, step_s=3600.0,
                         max_days=200, seed=1)
    rep = run_campaign(cfg)
    assert rep.bytes_at["ALCF"] == rep.total_bytes
    assert rep.bytes_at["OLCF"] == rep.total_bytes
    assert rep.duration_days < 200
    assert rep.duration_days > rep.floor_days   # physics: can't beat the floor
    # relay route carried traffic
    assert ("ALCF", "OLCF") in rep.per_route_transfers
    # fault skew: max >> mean (paper Fig. 6)
    if rep.faults_total:
        assert rep.faults_per_transfer_max >= rep.faults_per_transfer_mean
