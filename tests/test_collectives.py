"""Relay collectives: correctness on 8 simulated devices (subprocess so the
main test process keeps its single CPU device), plus the analytic model."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import jax_subprocess_env
from repro.core.relay_collectives import (estimate_naive_time,
                                          estimate_relay_time)


def test_relay_beats_naive_fanout_analytically():
    """The paper's argument: relaying beats 2× reads of the slow source.
    In-mesh: pipelined chain vs source fan-out over P destinations."""
    bw = 50e9
    for p in (2, 4, 8):
        relay = estimate_relay_time(1e9, bw, p, n_chunks=8)
        naive = estimate_naive_time(1e9, bw, p)
        assert relay <= naive + 1e-9
    # pipelining: more chunks -> closer to single-transfer time
    t2 = estimate_relay_time(1e9, bw, 8, n_chunks=2)
    t16 = estimate_relay_time(1e9, bw, 8, n_chunks=16)
    assert t16 < t2


_SUBPROC = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.relay_collectives import (relay_broadcast_inner,
                                              naive_broadcast_inner,
                                              ring_all_gather_inner)
    import functools

    mesh = jax.make_mesh((8,), ("pod",))
    x = jnp.arange(8 * 16 * 4, dtype=jnp.float32).reshape(8 * 16, 4)
    # stacked along pod: slice p holds rows [16p, 16p+16); src slice = 0

    from repro.compat import shard_map
    fn = jax.jit(shard_map(
        functools.partial(relay_broadcast_inner, axis_name="pod",
                          axis_size=8, src=0, n_chunks=4),
        mesh=mesh, in_specs=(P("pod"),), out_specs=P("pod")))
    out = np.asarray(fn(x)).reshape(8, 16, 4)
    src_block = np.asarray(x[:16])
    for p in range(8):
        np.testing.assert_array_equal(out[p], src_block)
    print("RELAY_OK")

    fn2 = jax.jit(shard_map(
        functools.partial(naive_broadcast_inner, axis_name="pod",
                          axis_size=8, src=0),
        mesh=mesh, in_specs=(P("pod"),), out_specs=P("pod")))
    out2 = np.asarray(fn2(x)).reshape(8, 16, 4)
    for p in range(8):
        np.testing.assert_array_equal(out2[p], src_block)
    print("NAIVE_OK")

    y = jnp.arange(8 * 4.0, dtype=jnp.float32).reshape(8, 4)
    fn3 = jax.jit(shard_map(
        functools.partial(ring_all_gather_inner, axis_name="pod", axis_size=8),
        mesh=mesh, in_specs=(P("pod"),), out_specs=P("pod")))
    out3 = np.asarray(fn3(y)).reshape(8, 8, 4)
    for p in range(8):
        np.testing.assert_array_equal(out3[p], np.asarray(y))
    print("RING_OK")

    # HLO structure: relay lowers to collective-permutes only
    txt = fn.lower(x).compile().as_text()
    assert "collective-permute" in txt
    print("HLO_OK")
""")


def test_relay_collectives_on_8_devices():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=".",
                       env=jax_subprocess_env(devices=8),
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    for marker in ("RELAY_OK", "NAIVE_OK", "RING_OK", "HLO_OK"):
        assert marker in r.stdout, (marker, r.stdout, r.stderr[-2000:])


def test_compressed_psum_on_4_devices():
    code = textwrap.dedent("""
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        import functools
        from jax.sharding import PartitionSpec as P
        from repro.optim.grad_compress import psum_compressed
        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
        from repro.compat import shard_map
        fn = jax.jit(shard_map(
            functools.partial(psum_compressed, axis_name="pod"),
            mesh=mesh, in_specs=(P("pod"),), out_specs=P("pod")))
        out = np.asarray(fn(g)).reshape(4, 32)
        want = np.mean(np.asarray(g).reshape(4, 32), axis=0)
        for p in range(4):
            err = np.max(np.abs(out[p] - want))
            assert err < np.max(np.abs(g)) / 127 + 1e-6, err
        print("COMPRESS_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], cwd=".",
                       env=jax_subprocess_env(devices=4),
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPRESS_OK" in r.stdout
