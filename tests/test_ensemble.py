"""Ensemble engine: lane-0 (and every-lane) bit-identity vs the scalar
event engine, backend elementwise agreement, scalar fallback, the search
driver's checkpoint/resume protocol, and the registry family.  The
hypothesis property tests (batched fault draws, band permutation
invariance) skip cleanly when hypothesis isn't installed."""
import dataclasses
import json

import numpy as np
import pytest

from repro.ensemble import (AxisSpec, EnsembleSpec, lane_capable,
                            quantile_bands, run_ensemble, run_search)
from repro.ensemble.batch import BatchedFaultInjector, make_segment_fn
from repro.ensemble.engine import scalar_lane
from repro.ensemble.run import GATE_FIELDS, check_lane0
from repro.ensemble.search import SearchDriver
from repro.scenarios.registry import get_scenario, list_ensembles

SCALE, ND = 0.01, 8


def _diff(ref, got):
    return {f: (getattr(ref, f), getattr(got, f))
            for f in GATE_FIELDS if getattr(ref, f) != getattr(got, f)}


# ------------------------------------------------------------- bit identity
def test_every_lane_matches_scalar_engine():
    """The determinism contract, on all lanes of a 4-seed sweep: the lanes
    engine must replay the scalar event engine's trajectory bit-for-bit
    (iterations, float-exact sim days, fault counters, digest)."""
    espec = EnsembleSpec("t-sweep", get_scenario("paper-2022"), n_lanes=4)
    res = run_ensemble(espec, scale=SCALE, n_datasets=ND)
    assert res.engine == "lanes"
    for i, (spec, seed, label) in enumerate(espec.lane_specs()):
        ref = scalar_lane(spec, seed, label, SCALE, ND)
        assert not _diff(ref, res.lane(i)), _diff(ref, res.lane(i))


def test_lane0_gate_on_registered_ensembles():
    """The CI gate function itself, on the registered families."""
    for name in ("ensemble-paper-bands", "aimd-search"):
        espec = dataclasses.replace(get_scenario(name), n_lanes=2)
        out = check_lane0(espec, SCALE, ND, "numpy")
        assert out["match"], (name, out["mismatches"])


def test_axes_perturb_trajectories():
    """Perturbation axes must actually reach the world build: a harsher
    fault rate changes the trajectory, and labels record the axis values."""
    espec = EnsembleSpec(
        "t-axes", get_scenario("paper-2022"),
        axes=(AxisSpec("faults.transient_per_tb", (0.15, 6.0)),),
        n_lanes=2)
    res = run_ensemble(espec, scale=SCALE, n_datasets=ND)
    assert res.lane(0).label["faults.transient_per_tb"] == 0.15
    assert res.lane(1).label["faults.transient_per_tb"] == 6.0
    assert res.lane(0).faults_total < res.lane(1).faults_total
    # and each perturbed lane still replays its own scalar world exactly
    for i, (spec, seed, label) in enumerate(espec.lane_specs()):
        ref = scalar_lane(spec, seed, label, SCALE, ND)
        assert not _diff(ref, res.lane(i))


# ---------------------------------------------------------------- fallbacks
def test_federation_base_falls_back_to_scalar():
    espec = dataclasses.replace(get_scenario("seed-sweep-federation"),
                                n_lanes=2)
    ok, reason = lane_capable(espec.base)
    assert not ok and reason
    res = run_ensemble(espec, scale=0.004, n_datasets=8)
    assert res.engine == "scalar"
    assert res.lane(0).sim_days > 0
    assert res.lane(0).succeeded_digest != res.lane(1).succeeded_digest


def test_force_scalar_equals_lanes():
    espec = EnsembleSpec("t-force", get_scenario("paper-2022"), n_lanes=3)
    fast = run_ensemble(espec, scale=SCALE, n_datasets=ND)
    slow = run_ensemble(espec, scale=SCALE, n_datasets=ND,
                        force_scalar=True)
    assert fast.engine == "lanes" and slow.engine == "scalar"
    for i in range(3):
        assert not _diff(slow.lane(i), fast.lane(i))
    assert fast.bands == slow.bands


# ----------------------------------------------------------------- backends
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_segment_backends_match_reference(backend):
    """jax/Pallas segment kernels agree with the numpy reference
    elementwise (float64 round-off only — XLA may fuse an FMA)."""
    ref_fn = make_segment_fn("numpy")
    alt_fn = make_segment_fn(backend)
    rng = np.random.default_rng(7)
    t = rng.uniform(0.0, 3600.0, size=(16, 8))
    bd = rng.uniform(0.0, 1e12, size=(16, 8))
    rate = np.where(rng.random((16, 8)) < 0.2, 0.0,
                    rng.uniform(1e6, 1e9, size=(16, 8)))
    bound = bd + rng.uniform(0.0, 1e11, size=(16, 8))
    ref = ref_fn(t, bd, rate, bound)
    alt = alt_fn(t, bd, rate, bound)
    for r, a, name in zip(ref, alt,
                          ("t_left", "new_bytes", "adv", "moved", "hit")):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(r, np.float64),
                                   rtol=1e-12, atol=1e-6, err_msg=name)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_lanes_engine_runs_on_accelerated_backends(backend):
    """Whole-trajectory check: accelerated backends complete the campaign
    with the same terminal replica state as the reference (byte counts are
    integers — immune to FMA contraction — while iteration counts and
    float sim-days may drift)."""
    espec = EnsembleSpec("t-backend", get_scenario("paper-2022"), n_lanes=2)
    ref = run_ensemble(espec, scale=SCALE, n_datasets=ND)
    alt = run_ensemble(espec, scale=SCALE, n_datasets=ND, backend=backend)
    assert alt.engine == "lanes" and alt.backend == backend
    for i in range(2):
        assert alt.lane(i).bytes_at == ref.lane(i).bytes_at
        assert alt.lane(i).quarantined == ref.lane(i).quarantined
        assert not alt.lane(i).timed_out


# ------------------------------------------------------------------- search
def test_search_checkpoint_resume(tmp_path):
    ckpt = str(tmp_path / "search.json")
    espec = EnsembleSpec("t-search", get_scenario("paper-2022"), n_lanes=6)
    kw = dict(scale=SCALE, n_datasets=ND, chunk=2)
    full = run_search(espec, **kw)

    driver = SearchDriver(espec, checkpoint=ckpt, **kw)
    partial = driver.run()
    assert partial.rows == full.rows
    # truncate the checkpoint to 3 lanes and resume: lanes 0-2 come from
    # the file, 3-5 re-run, and the outcome is identical
    state = json.load(open(ckpt))
    state["done"] = state["done"][:3]
    json.dump(state, open(ckpt, "w"))
    resumed = SearchDriver(espec, checkpoint=ckpt, **kw).run()
    assert resumed.rows == full.rows
    assert resumed.winner == full.winner
    assert resumed.bands == full.bands
    # a stale checkpoint (different ensemble) is ignored, not merged
    state["name"] = "something-else"
    json.dump(state, open(ckpt, "w"))
    fresh = SearchDriver(espec, checkpoint=ckpt, **kw).run()
    assert fresh.rows == full.rows


def test_search_winner_and_bench_entry():
    espec = EnsembleSpec(
        "t-objective", get_scenario("paper-2022"),
        axes=(AxisSpec("faults.transient_per_tb", (0.15, 6.0)),),
        n_lanes=2)
    out = run_search(espec, scale=SCALE, n_datasets=ND,
                     objective="faults_total")
    assert out.winner["lane"] == 0          # fewer faults at the low rate
    entry = out.bench_entry()
    assert entry["ensemble_t-objective_faults_total"] == float(
        out.winner["faults_total"])
    ranked = out.ranking()
    assert ranked[0] == out.winner


# ----------------------------------------------------------------- registry
def test_registry_family():
    names = list_ensembles()
    for name in ("ensemble-paper-bands", "aimd-search",
                 "seed-sweep-federation"):
        assert name in names
        spec = get_scenario(name)
        assert isinstance(spec, EnsembleSpec)
    assert get_scenario("ensemble-paper-bands").n_lanes == 256
    assert get_scenario("aimd-search").n_lanes == 27


# ------------------------------------------------------- property (hypothesis)
def test_batched_fault_draws_match_solo_streams_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seeds=st.lists(st.integers(0, 2**31 - 1), min_size=1,
                          max_size=8),
           nbytes=st.lists(st.integers(1, 10**13), min_size=1, max_size=8),
           rate=st.floats(0.1, 20.0))
    def prop(seeds, nbytes, rate):
        from repro.core.faults import FaultInjector
        n = min(len(seeds), len(nbytes))
        seeds, nbytes = seeds[:n], nbytes[:n]
        paths = [f"/css/ds-{i}" for i in range(n)]
        batched = BatchedFaultInjector(seeds, transient_per_tb=rate)
        marks, lens = batched.transient_marks(paths, nbytes)
        solo = [FaultInjector(s, transient_per_tb=rate)
                .transient_marks(p, b)
                for s, p, b in zip(seeds, paths, nbytes)]
        for l in range(n):
            assert lens[l] == len(solo[l])
            assert list(marks[l, :lens[l]]) == solo[l]
            assert np.all(np.isinf(marks[l, lens[l]:]))

    prop()


def test_quantile_bands_permutation_invariant_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(vals=st.lists(st.floats(0.0, 1e4, allow_nan=False),
                         min_size=1, max_size=40),
           seed=st.integers(0, 2**16))
    def prop(vals, seed):
        rows = [{"sim_days": v, "faults_total": i}
                for i, v in enumerate(vals)]
        perm = list(rows)
        np.random.default_rng(seed).shuffle(perm)
        assert quantile_bands(rows) == quantile_bands(perm)

    prop()
