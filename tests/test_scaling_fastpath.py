"""Regression tests for the array-native hot path.

Pins the fast paths against their scalar models the way the vectorized
mover pool already is:

  * the batched fair-share pricer (``SimulatedTransport._price_routes``)
    against per-route scalar ``RouteGraph.effective_rate`` calls, including
    reader pseudo-routes, contention knees, and routes absent from the
    graph — plus cap conservation;
  * the rates memo contract: an unchanged mover population returns the
    cached dict without repricing, and any population or reader-load change
    invalidates it (while the monkeypatch seam the federation bench relies
    on keeps working);
  * scheduler heap-key hygiene: drained per-destination dispatch heaps are
    dropped, never left behind as empty lists for every dispatch pass to
    iterate forever;
  * scrub scan accounting at run granularity: the cumsum/searchsorted batch
    cut and the corrupt-file localization must match a naive scalar
    walk exactly (same pass count — hence same scan-completion days — same
    scanned bytes, same corrupt files/bytes), with the file-partition cache
    bounded so memory stays O(active), not O(catalog files);
  * the ``paper-29m-twice`` registry scenario: buildable, deterministic.
"""
import numpy as np
import pytest

from repro.core.faults import FaultInjector, Notifier, RetryPolicy, \
    stable_digest
from repro.core.pause import DAY, PauseManager
from repro.core.routes import GB, Dataset, Route, RouteGraph, Site
from repro.core.scrub import ScrubEngine, ScrubSpec
from repro.core.transport import SimClock, SimulatedTransport
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import get_scenario


def _kneed_graph() -> RouteGraph:
    """Four sites (two with contention knees) and a partial route mesh, so
    batch pricing sees knees, shared sources, and missing routes."""
    sites = [
        Site("A", read_bw=1.5 * GB, write_bw=1.5 * GB, concurrency_knee=3),
        Site("B", read_bw=10 * GB, write_bw=10 * GB, concurrency_knee=6),
        Site("C", read_bw=10 * GB, write_bw=10 * GB),
        Site("D", read_bw=2 * GB, write_bw=2 * GB),
    ]
    routes = [
        Route("A", "B", 1.3 * GB), Route("A", "C", 1.3 * GB),
        Route("B", "C", 3.4 * GB), Route("C", "B", 4.7 * GB),
        Route("B", "D", 3.6 * GB), Route("D", "C", 4.0 * GB),
    ]
    return RouteGraph(sites, routes)


def _transport(graph=None) -> SimulatedTransport:
    graph = graph or _kneed_graph()
    return SimulatedTransport(graph, SimClock(), PauseManager(),
                              FaultInjector(seed=0), Notifier(),
                              RetryPolicy())


class _Mover:
    def __init__(self, src, dst):
        self.source, self.destination = src, dst


def _random_population(rng, graph):
    """A random mover population: mostly real routes, sometimes a route the
    graph doesn't know (quarantine edge cases price to 0.0)."""
    candidates = list(graph.routes) + [("D", "A")]
    movers = []
    for r in candidates:
        movers.extend([_Mover(*r)] * int(rng.integers(0, 5)))
    return movers


# ------------------------------------------------- batch pricer vs scalar
def test_batch_fair_share_matches_scalar_exactly():
    graph = _kneed_graph()
    tr = _transport(graph)
    rng = np.random.default_rng(7)
    for trial in range(200):
        movers = _random_population(rng, graph)
        if trial % 3 == 0:      # fold in reader pseudo-routes sometimes
            tr.set_read_load("users", {
                s: int(rng.integers(0, 9)) for s in ("A", "B", "C")})
        else:
            tr.set_read_load("users", {})
        rates = tr._route_rates(movers)
        pop = {}
        for x in movers:
            r = (x.source, x.destination)
            pop[r] = pop.get(r, 0) + 1
        assert set(rates) == set(pop)
        full = dict(pop)
        for site, n in tr._reader_streams().items():
            full[(site, "__readers__")] = n
        for (src, dst), rate in rates.items():
            want = graph.effective_rate(src, dst, full)
            assert rate == want, (src, dst, rate, want)   # bit-identical


def test_batch_fair_share_conserves_caps():
    graph = _kneed_graph()
    tr = _transport(graph)
    rng = np.random.default_rng(11)
    eps = 1e-6
    for _ in range(100):
        movers = _random_population(rng, graph)
        if not movers:
            continue
        rates = tr._route_rates(movers)
        pop = {}
        for x in movers:
            r = (x.source, x.destination)
            pop[r] = pop.get(r, 0) + 1
        egress, ingress = {}, {}
        for (src, dst), n in pop.items():
            egress[src] = egress.get(src, 0.0) + rates[(src, dst)] * n
            ingress[dst] = ingress.get(dst, 0.0) + rates[(src, dst)] * n
            r = graph.route(src, dst)
            cap = r.bandwidth if r is not None else 0.0
            assert rates[(src, dst)] * n <= cap * (1 + eps)
        for site, total in egress.items():
            assert total <= graph.sites[site].read_bw * (1 + eps)
        for site, total in ingress.items():
            assert total <= graph.sites[site].write_bw * (1 + eps)


def test_route_rates_memo_and_invalidation():
    tr = _transport()
    movers = [_Mover("A", "B"), _Mover("A", "B"), _Mover("B", "C")]
    first = tr._route_rates(movers)
    # unchanged population: the SAME dict comes back, unpriced
    assert tr._route_rates(list(movers)) is first
    # a mover joining a route invalidates the memo
    second = tr._route_rates(movers + [_Mover("A", "C")])
    assert second is not first
    assert ("A", "C") in second
    # reader load shifting invalidates it too, without new movers
    tr.set_read_load("users", {"A": 4})
    third = tr._route_rates(movers + [_Mover("A", "C")])
    assert third is not second
    assert third[("A", "B")] < second[("A", "B")]


def test_route_rates_monkeypatch_seam_still_works():
    """The federation bench wraps ``transport._route_rates`` with a closure
    that calls the original; the memo lives inside the original method, so
    the wrapper must keep observing every call."""
    tr = _transport()
    calls = []
    orig = tr._route_rates

    def wrapped(movers, _orig=orig):
        rates = _orig(movers)
        calls.append(len(movers))
        return rates

    tr._route_rates = wrapped
    movers = [_Mover("A", "B")]
    r1 = tr._route_rates(movers)
    r2 = tr._route_rates(movers)
    assert calls == [1, 1] and r1 is r2


# ------------------------------------------------- scheduler heap hygiene
def test_scheduler_drops_drained_heap_keys():
    spec = get_scenario("paper-2022")
    world = spec.build(seed=0, n_datasets=48)
    run_world(world, stats=EngineStats())
    sched = world.sched
    # every queue key left behind must hold live work (a quarantined row can
    # legitimately stay queued forever); what may never survive is an EMPTY
    # heap — the leak that made dispatch passes iterate dead destinations
    assert all(heap for heap in sched._direct.values())
    assert all(heap for heap in sched._relay.values())
    assert set(sched._direct_member) == set(sched._direct)
    assert set(sched._relay_donor) <= {d for d, _ in sched._relay}


def test_scheduler_key_count_tracks_live_destinations():
    """Mid-campaign, the number of direct-dispatch keys never exceeds the
    number of destinations that still have queued retryable work."""
    spec = get_scenario("paper-2022")
    world = spec.build(seed=0, n_datasets=48)
    sched = world.sched
    seen = []
    orig = sched.step

    def step(now, _orig=orig):
        out = _orig(now)
        seen.append((len(sched._direct), len(sched._direct_member)))
        for dst, heap in sched._direct.items():
            assert heap, f"empty heap left behind for {dst!r}"
        return out

    sched.step = step
    run_world(world, stats=EngineStats())
    assert seen
    n_dest = len(spec.replicas)
    assert max(n for n, _ in seen) <= n_dest
    assert all(n == m for n, m in seen)   # member sets track the heaps


# ---------------------------------------------- scrub scan accounting
SCRUB_SHAPE = dict(n_datasets=32, scale=0.02)


def _scrubbed_world():
    world = get_scenario("scrub-and-repair").build(seed=0, **SCRUB_SHAPE)
    run_world(world, stats=EngineStats())
    return world


def test_scrub_pass_cut_matches_scalar_model():
    """The cumsum/searchsorted batch cut — hence the scan-completion days —
    must match a naive scalar walk over the same rotating replica order."""
    world = _scrubbed_world()
    eng = world.scrub
    spec = ScrubSpec(latent_per_pb=eng.spec.latent_per_pb,
                     interval_days=4.0, scan_tb_per_pass=120.0)
    fresh = ScrubEngine(spec, eng.catalog, world.table, eng.injector,
                        eng.source, eng.replicas)
    # pin the pure batch-cut arithmetic: with nothing at risk, no pass flips
    # rows to FAILED, so the replica universe is stable across passes
    fresh._at_risk.clear()
    keys, sizes = fresh._scan_order()
    n = len(keys)
    assert n > 8, "scenario must land enough replicas to batch over"
    budget = spec.scan_tb_per_pass * 1024 ** 4

    # scalar model: accumulate replica sizes in the same rotating order,
    # taking whole replicas while the budget holds (always at least one)
    def scalar_pass(cursor):
        total = k = 0
        for i in range(n):
            s = int(sizes[(cursor + i) % n])
            if total + s <= budget:
                total += s
                k += 1
            else:
                break
        if k == 0:
            k, total = 1, int(sizes[cursor % n])
        return k, total

    cursor = 0
    expect_passes = 0
    expect_bytes = 0
    covered = 0
    while covered < n:
        k, total = scalar_pass(cursor)
        cursor = (cursor + k) % n
        covered += k
        expect_passes += 1
        expect_bytes += total

    now = fresh._now
    passes = 0
    while fresh.scanned_replicas < n:
        fresh._run_pass(now)
        passes += 1
        assert passes <= n, "scan never completes"
    assert passes == expect_passes
    assert fresh.scanned_bytes == expect_bytes
    # identical pass count at a fixed cadence == identical completion days
    assert passes * spec.interval_days == expect_passes * spec.interval_days


def test_scrub_localize_matches_scalar_file_walk():
    world = _scrubbed_world()
    eng = world.scrub
    # replay a detection on a dataset that actually drew corruption
    assert eng.detected > 0
    name = sorted(eng.catalog)[3]
    ds = eng.catalog[name]
    nf = max(1, int(ds.files))
    csum = eng._file_csum(name, nf, ds.bytes)

    # scalar reference: full per-file partition, then a linear walk
    rng = np.random.default_rng([eng.injector.seed, stable_digest(name)])
    w = rng.lognormal(mean=0.0, sigma=1.2, size=nf)
    w /= w.sum()
    sizes = np.floor(w * ds.bytes).astype(np.int64)
    sizes[0] += ds.bytes - int(sizes.sum())
    assert int(csum[-1]) == ds.bytes
    np.testing.assert_array_equal(np.asarray(csum), np.cumsum(sizes))

    offs = np.asarray([0, 17, int(ds.bytes * 0.4), ds.bytes - 1],
                      dtype=np.int64)
    idx = np.unique(np.searchsorted(csum, offs, side="right"))
    idx = idx[idx < len(csum)]
    lo = np.where(idx > 0, csum[idx - 1], 0)
    got = (int(len(idx)), int((csum[idx] - lo).sum()))

    hit = set()
    for off in offs.tolist():
        acc = 0
        for i, s in enumerate(sizes.tolist()):       # scalar file walk
            acc += s
            if off < acc:                # first file whose cumsum exceeds off
                hit.add(i)
                break
    want = (len(hit), int(sum(int(sizes[i]) for i in hit)))
    assert got == want


def test_scrub_file_partition_cache_is_bounded():
    world = _scrubbed_world()
    eng = world.scrub
    eng._file_parts.clear()
    eng._file_part_entries = 0
    eng.FILE_PART_BUDGET = 100          # shrink the budget for the test
    names = sorted(eng.catalog)
    # an oversized manifest is computed transiently, never cached
    big = eng._file_csum(names[0], 80, eng.catalog[names[0]].bytes)
    assert len(big) == 80 and not eng._file_parts
    # small manifests are cached until the budget would overflow...
    eng._file_csum(names[1], 20, eng.catalog[names[1]].bytes)
    eng._file_csum(names[2], 20, eng.catalog[names[2]].bytes)
    assert set(eng._file_parts) == {names[1], names[2]}
    # ...then the pool is recycled rather than growing without bound
    for name in names[3:8]:
        eng._file_csum(name, 20, eng.catalog[name].bytes)
    assert eng._file_part_entries <= 100
    assert len(eng._file_parts) <= 5
    # recomputation after eviction is bit-identical to the cached value
    again = eng._file_csum(names[1], 20, eng.catalog[names[1]].bytes)
    rng = np.random.default_rng([eng.injector.seed,
                                 stable_digest(names[1])])
    w = rng.lognormal(mean=0.0, sigma=1.2, size=20)
    w /= w.sum()
    sizes = np.floor(w * eng.catalog[names[1]].bytes).astype(np.int64)
    sizes[0] += eng.catalog[names[1]].bytes - int(sizes.sum())
    np.testing.assert_array_equal(np.asarray(again), np.cumsum(sizes))


# --------------------------------------------------- paper-29m-twice spec
def test_paper_29m_twice_registered_and_deterministic():
    spec = get_scenario("paper-29m-twice")
    assert spec.policy is not None and spec.policy.granularity == "file"
    digests = []
    for _ in range(2):
        world = spec.build(seed=0, n_datasets=48, scale=0.02)
        stats = EngineStats()
        rep = run_world(world, stats=stats)
        digests.append((stats.iterations, rep.span_days, tuple(
            (label, m.faults_total, tuple(sorted(m.bytes_at.items())))
            for label, m in sorted(rep.members.items()))))
    assert digests[0] == digests[1]
