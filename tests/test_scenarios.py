"""Scenario engine tests: spec compilation, the named-scenario registry,
event-driven vs step-driven equivalence, pause-boundary semantics, batched
table updates, and the sweep runner."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.campaign import CampaignConfig, run_campaign
from repro.core.pause import DAY, PauseManager
from repro.core.routes import GB
from repro.core.transfer_table import Status, TransferTable
from repro.scenarios.events import EngineStats, run_scenario, run_world
from repro.scenarios.registry import get_scenario, list_scenarios
from repro.scenarios.spec import (CatalogSpec, OutageSpec, RouteSpec,
                                  ScenarioSpec, SiteSpec)

# tiny-but-complete build overrides used to run every scenario to completion
TINY = dict(n_datasets=8, scale=0.004)


# ---------------------------------------------------------- pause semantics
def test_pause_window_inclusive_start_exclusive_end():
    pm = PauseManager()
    pm.add_window("A", 10.0, 20.0)
    assert not pm.paused("A", 9.999)
    assert pm.paused("A", 10.0)          # inclusive start
    assert pm.paused("A", 19.999)
    assert not pm.paused("A", 20.0)      # exclusive end
    assert not pm.paused("B", 15.0)      # other sites unaffected


def test_pause_overlapping_windows_union():
    pm = PauseManager()
    pm.add_window("A", 0.0, 10.0)
    pm.add_window("A", 5.0, 15.0)
    for t in (0.0, 4.0, 5.0, 9.0, 12.0):
        assert pm.paused("A", t)
    assert not pm.paused("A", 15.0)
    # next_boundary walks every open/close edge after `now`
    assert pm.next_boundary("A", 0.0) == 5.0
    assert pm.next_boundary("A", 5.0) == 10.0
    assert pm.next_boundary("A", 10.0) == 15.0
    assert pm.next_boundary("A", 15.0) == float("inf")
    assert pm.next_boundary("nosuch", 0.0) == float("inf")


def test_add_weekly_clips_last_window():
    pm = PauseManager()
    until = 15 * DAY
    pm.add_weekly("A", 6 * DAY, 48.0 * 3600.0, until)   # 2-day windows
    ws = pm.windows("A")
    assert len(ws) == 2                   # starts at day 6 and day 13
    assert ws[0].start == 6 * DAY and ws[0].end == 8 * DAY
    # the day-13 window would run to day 15+? no: clipped at `until`
    assert ws[1].start == 13 * DAY and ws[1].end == until
    assert all(w.end <= until for w in ws)


# --------------------------------------------------------- batched updates
def test_update_many_single_transaction_matches_update():
    t = TransferTable()
    t.populate(["a", "b", "c"], "LLNL", ["ALCF", "OLCF"])
    t.update_many([
        ("a", "ALCF", dict(status=Status.SUCCEEDED, bytes_transferred=7)),
        ("b", "ALCF", dict(status=Status.FAILED, retries=2)),
        ("c", "OLCF", dict(bytes_transferred=9, rate=1.5)),
    ])
    assert t.get("a", "ALCF").status == Status.SUCCEEDED
    assert t.get("a", "ALCF").bytes_transferred == 7
    assert t.get("b", "ALCF").retries == 2
    assert t.get("c", "OLCF").rate == 1.5
    assert t.get("c", "OLCF").status == Status.NULL     # untouched column
    t.update_many([])                                    # no-op is fine


# ----------------------------------------------------------------- registry
def test_registry_has_required_scenarios():
    names = list_scenarios()
    assert len(names) >= 6
    for required in ("paper-2022", "four-site-mesh", "degraded-source",
                     "fault-storm", "flaky-network", "incremental-top-up",
                     "cold-start-relay", "mega-campaign"):
        assert required in names
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_every_scenario_runs_tiny_campaign_to_completion(name):
    spec = get_scenario(name)
    rep = run_scenario(spec, engine="events", seed=2, **TINY)
    assert rep.duration_days < spec.max_days
    assert rep.duration_days > rep.floor_days
    # every replica holds (almost) everything; quarantined rows are the only
    # permitted shortfall and must carry a notification
    for replica, got in rep.bytes_at.items():
        if rep.quarantined == 0:
            assert got >= rep.total_bytes * 0.999, replica
    if rep.quarantined:
        assert rep.notifications


def test_spec_compilation_matches_paper_wiring():
    """paper-2022 must compile to exactly the topology/calendar that
    ``build_campaign`` hard-codes."""
    from repro.core.campaign import build_campaign
    from repro.core.routes import paper_route_graph

    spec = get_scenario("paper-2022")
    graph = spec.build_graph()
    want = paper_route_graph()
    assert set(graph.sites) == set(want.sites)
    for name, site in want.sites.items():
        got = graph.sites[name]
        assert got.read_bw == site.read_bw
        assert got.write_bw == site.write_bw
        assert got.scan_files_per_s == site.scan_files_per_s
        assert got.scan_mem_limit_files == site.scan_mem_limit_files
    assert set(graph.routes) == set(want.routes)
    for key, route in want.routes.items():
        assert abs(graph.routes[key].bandwidth - route.bandwidth) < 1e-6

    cfg = spec.to_campaign_config(scale=0.01, seed=5, n_datasets=12)
    pause = spec.build_pause()
    _, _, _, want_pause, _, _, _, _ = build_campaign(cfg)
    for site in ("ALCF", "OLCF"):
        got_w = sorted((w.start, w.end) for w in pause.windows(site))
        want_w = sorted((w.start, w.end) for w in want_pause.windows(site))
        assert got_w == want_w, site


def test_four_site_mesh_relays_to_new_site():
    rep = run_scenario("four-site-mesh", engine="events", seed=0,
                       n_datasets=10, scale=0.004)
    assert "NERSC" in rep.bytes_at
    relay_in = sum(n for (src, dst), n in rep.per_route_transfers.items()
                   if dst == "NERSC" and src != "LLNL")
    direct_in = rep.per_route_transfers.get(("LLNL", "NERSC"), 0)
    assert relay_in > direct_in


def test_cold_start_relay_is_relay_dominated():
    rep = run_scenario("cold-start-relay", engine="events", seed=1,
                       n_datasets=10, scale=0.004)
    relays = sum(n for (src, _), n in rep.per_route_transfers.items()
                 if src != "LLNL")
    direct_secondary = sum(
        n for (src, dst), n in rep.per_route_transfers.items()
        if src == "LLNL" and dst != "ALCF")
    assert relays > direct_secondary


def test_incremental_top_up_absorbs_new_datasets():
    spec = get_scenario("incremental-top-up")
    world = spec.build(scale=0.004, seed=0, n_datasets=8)
    n_initial = len(world.catalog)
    rep = run_world(world, engine="events")
    assert len(world.catalog) > n_initial          # top-ups were folded in
    topups = [p for p in world.catalog if "TOPUP" in p]
    assert topups
    for p in topups:
        for dst in spec.replicas:
            assert world.table.get(p, dst).status == Status.SUCCEEDED
    # the campaign necessarily outlives the last publication
    assert rep.duration_days * DAY > max(world.top_up_times)


def test_mid_run_publication_keeps_campaign_alive():
    """A dataset published to the feed *after* run_world starts (e.g. from
    the observer hook) must still be admitted and replicated — the driver's
    outstanding-top-up set picks up feed growth, it is not a one-shot
    snapshot."""
    from repro.core.routes import Dataset
    spec = get_scenario("incremental-top-up")
    world = spec.build(scale=0.004, seed=0, n_datasets=8)
    late = "/css03_data/CMIP6/LATE/ds-mid-run"
    state = {"published": False}

    def observer(w, now):
        if not state["published"] and now > 5 * DAY:
            state["published"] = True
            w.incremental.feed.publish(now + DAY,
                                       Dataset(late, 1 * GB, 50, 5))

    run_world(world, engine="events", on_iteration=observer)
    assert state["published"]
    for dst in spec.replicas:
        assert world.table.get(late, dst).status == Status.SUCCEEDED


def test_degraded_source_slower_than_baseline():
    # enough bytes (0.73 PB) that the source bandwidth, not the maintenance
    # calendar, bounds the campaign
    base = run_scenario("paper-2022", engine="events", seed=0,
                        n_datasets=12, scale=0.1)
    slow = run_scenario("degraded-source", engine="events", seed=0,
                        n_datasets=12, scale=0.1)
    assert slow.floor_days > base.floor_days * 1.8
    assert slow.duration_days > base.duration_days * 1.3


def test_fault_storm_produces_heavier_fault_load():
    base = run_scenario("paper-2022", engine="events", seed=0,
                        n_datasets=12, scale=0.01)
    storm = run_scenario("fault-storm", engine="events", seed=0,
                         n_datasets=12, scale=0.01)
    assert storm.faults_total > 3 * max(1, base.faults_total)


# ------------------------------------------------- event/step equivalence
@pytest.mark.parametrize("vectorized", (True, False),
                         ids=("vectorized", "scalar"))
def test_event_engine_equivalent_to_step_driver(vectorized):
    """Acceptance: paper-2022 under events matches the step-driven
    ``run_campaign`` duration within 5% and reproduces the fault-histogram
    shape, at far fewer driver iterations — with the vectorized mover pool
    AND the scalar segment walk."""
    n, scale, seed = 24, 0.02, 0
    step_rep = run_campaign(CampaignConfig(n_datasets=n, scale=scale,
                                           seed=seed))
    stats = EngineStats()
    world = get_scenario("paper-2022").build(scale=scale, seed=seed,
                                             n_datasets=n)
    world.transport.vectorized = vectorized
    ev_rep = run_world(world, engine="events", stats=stats)
    assert abs(ev_rep.duration_days - step_rep.duration_days) \
        <= 0.05 * step_rep.duration_days
    # completion equivalence
    for r in ("ALCF", "OLCF"):
        assert ev_rep.bytes_at[r] == step_rep.bytes_at[r]
    # fault histogram shape: same zero-fault mass and heavy tail
    def zero_frac(rep):
        total = sum(rep.fault_histogram.values())
        return rep.fault_histogram.get(0, 0) / max(1, total)
    assert abs(zero_frac(ev_rep) - zero_frac(step_rep)) <= 0.2
    if step_rep.faults_total:
        assert 0.3 <= ev_rep.faults_total / step_rep.faults_total <= 3.0
        assert ev_rep.faults_per_transfer_max >= \
            ev_rep.faults_per_transfer_mean
    # the event core must do meaningfully fewer iterations than the
    # fixed-step driver (duration_days of 1800 s steps)
    step_iters = step_rep.duration_days * DAY / 1800.0
    assert stats.iterations < 0.6 * step_iters


def test_step_engine_in_run_world_matches_run_campaign():
    """run_world(engine='step') reproduces the seed driver on the same
    wiring (same catalog, calendar, fault seeds)."""
    n, scale, seed = 16, 0.01, 4
    a = run_campaign(CampaignConfig(n_datasets=n, scale=scale, seed=seed))
    spec = get_scenario("paper-2022")
    b = run_world(spec.build(scale=scale, seed=seed, n_datasets=n),
                  engine="step")
    assert a.duration_days == pytest.approx(b.duration_days, rel=1e-9)
    assert a.faults_total == b.faults_total
    assert a.bytes_at == b.bytes_at


# ------------------------------------------------------------------ sweep
def test_sweep_aggregates_comparison_rows(tmp_path):
    from repro.scenarios.sweep import Variant, emit_bench, sweep, to_frame
    variants = [Variant("paper-2022", n_datasets=8, scale=0.004, seed=s)
                for s in (0, 1)]
    rows = sweep(variants, processes=2)
    assert len(rows) == 2
    assert [r["seed"] for r in rows] == [0, 1]
    for row in rows:
        assert row["scenario"] == "paper-2022"
        assert row["duration_days"] > 0
        assert row["wall_s"] >= 0
    frame = to_frame(rows)
    assert frame["seed"] == [0, 1]
    assert len(frame["duration_days"]) == 2
    out = str(tmp_path / "BENCH_scenarios.json")
    emit_bench(rows, path=out, extra={"note": "test"})
    emit_bench([], path=out, extra={"engine_comparison": {"speedup": 9.9}})
    with open(out) as f:
        doc = json.load(f)
    assert len(doc["sweep"]) == 2                # merge preserved the rows
    assert doc["note"] == "test"
    assert doc["engine_comparison"]["speedup"] == 9.9


# -------------------------------------------------------------------- CLI
def test_scenario_cli_runs_named_scenario(tmp_path):
    out = str(tmp_path / "report.json")
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "repro.scenarios.run", "--scenario",
         "paper-2022", "--datasets", "8", "--scale", "0.004",
         "--json", out],
        capture_output=True, text=True, timeout=300, env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    doc = json.loads(r.stdout)
    assert doc["scenario"] == "paper-2022"
    assert doc["complete_at_all"] or doc["quarantined"] > 0
    assert os.path.exists(out)
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.scenarios.run", "--list"],
        capture_output=True, text=True, timeout=120, env=env, cwd=".")
    assert r2.returncode == 0
    for name in list_scenarios():
        assert name in r2.stdout
