"""Silent-corruption / scrub-repair tests: seeded latent draws (subprocess
determinism included), detection -> ordinary-retry repair -> convergence to
the corruption-free end state, the bit-rot ablation, serveability dips in
the replica catalog, mid-scrub kill/resume, and the batched
``Manifest.verify_many`` / ``LocalFSTransport.audit`` integrity API."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.faults import FaultInjector, stable_digest
from repro.core.integrity import Manifest
from repro.core.routes import Dataset
from repro.core.scrub import NO_SCRUB, ScrubSpec
from repro.core.snapshot import replica_set_digest
from repro.core.transfer_table import Status
from repro.core.transport import LocalFSTransport
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import get_scenario, scenario_tags

SHAPE = dict(n_datasets=16, scale=0.02)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(spec, engine="events", seed=0):
    world = spec.build(seed=seed, **SHAPE)
    stats = EngineStats()
    rep = run_world(world, engine=engine, stats=stats)
    return world, rep, stats


# ---------------------------------------------------------- seeded injection
def test_stable_digest_is_checksum_based():
    # a pure function of the text: no PYTHONHASHSEED, no process identity
    assert stable_digest("v1.0/abc") == stable_digest("v1.0/abc")
    assert stable_digest("v1.0/abc") != stable_digest("v1.0/abd")


def test_persistent_unreadable_and_latent_draws_cross_process():
    """The fraction-based unreadable draw and the latent-corruption offsets
    must be identical in a subprocess with a different hash seed — the old
    ``hash()``-based draw was per-process-randomized."""
    inj = FaultInjector(seed=7)
    names = [f"ds{i:04d}" for i in range(64)]
    unread = [n for n in names if inj.is_persistent_unreadable(n)]
    offs = inj.latent_corrupt_offsets("ds0001", "ALCF", 10 * 1024 ** 4,
                                      rate_per_pb=2000.0, incarnation=3)
    prog = (
        "from repro.core.faults import FaultInjector\n"
        "inj = FaultInjector(seed=7)\n"
        "names = [f'ds{i:04d}' for i in range(64)]\n"
        "print([n for n in names if inj.is_persistent_unreadable(n)])\n"
        "print([int(o) for o in inj.latent_corrupt_offsets('ds0001',\n"
        "      'ALCF', 10 * 1024 ** 4, rate_per_pb=2000.0, incarnation=3)])\n")
    env = dict(os.environ, PYTHONPATH=SRC, PYTHONHASHSEED="12345")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, check=True)
    got_unread, got_offs = out.stdout.strip().splitlines()
    assert got_unread == repr(unread)
    assert got_offs == repr([int(o) for o in offs])


def test_latent_draws_keyed_by_replica_and_incarnation():
    inj = FaultInjector(seed=0)
    a = inj.latent_corrupt_offsets("ds", "ALCF", 1024 ** 5, 50.0)
    b = inj.latent_corrupt_offsets("ds", "ALCF", 1024 ** 5, 50.0)
    np.testing.assert_array_equal(a, b)     # pure function of the key
    c = inj.latent_corrupt_offsets("ds", "OLCF", 1024 ** 5, 50.0)
    d = inj.latent_corrupt_offsets("ds", "ALCF", 1024 ** 5, 50.0,
                                   incarnation=2)
    assert list(a) != list(c) or list(a) != list(d)
    assert len(inj.latent_corrupt_offsets("ds", "ALCF", 1024 ** 5, 0.0)) == 0
    assert (a < 1024 ** 5).all() and (a >= 0).all()


def test_scrub_spec_validation_and_tags():
    with pytest.raises(ValueError):
        ScrubSpec(latent_per_pb=-1.0).validate()
    with pytest.raises(ValueError):
        ScrubSpec(latent_per_pb=1.0, interval_days=-1.0).validate()
    NO_SCRUB.validate()
    assert not NO_SCRUB.enabled
    assert ScrubSpec(latent_per_pb=1.0, interval_days=0.0).enabled
    assert not ScrubSpec(latent_per_pb=1.0, interval_days=0.0).scrubbing
    assert "scrub" in scenario_tags(get_scenario("scrub-and-repair"))
    assert "scrub" not in scenario_tags(get_scenario("paper-2022"))


def test_scrub_rejects_bundling_policies():
    from repro.control.policy import TransferPolicySpec
    spec = get_scenario("scrub-and-repair").vary(
        policy=TransferPolicySpec(bundling="greedy"))
    with pytest.raises(ValueError):
        spec.build(seed=0, **SHAPE)


# ------------------------------------------------- campaign-level properties
def test_scrub_campaign_ends_clean_and_converges():
    """The acceptance property: a completed scrub-and-repair campaign has
    detected and repaired every latent corruption, and its final SUCCEEDED
    replica set is identical to a corruption-free run's end state."""
    world, _, _ = _run(get_scenario("scrub-and-repair"))
    s = world.scrub.summary()
    assert s["detected"] > 0, "shape drew no corruption: weaken the test"
    assert s["repaired"] == s["detected"]
    assert s["clean"] and s["at_risk_replicas"] == 0
    assert s["data_at_risk_bytes"] == 0
    assert s["exposure_days"] > 0
    assert s["corrupt_files"] > 0 and s["corrupt_bytes"] > 0

    clean_world, _, _ = _run(
        get_scenario("scrub-and-repair").with_scrub(NO_SCRUB))
    assert clean_world.scrub is None
    assert replica_set_digest(world.table) == \
        replica_set_digest(clean_world.table)


def test_scrub_deterministic_across_engines_and_runs():
    w1, r1, s1 = _run(get_scenario("scrub-and-repair"))
    w2, r2, s2 = _run(get_scenario("scrub-and-repair"))
    assert s1.iterations == s2.iterations
    assert r1.duration_days == r2.duration_days
    assert w1.scrub.summary() == w2.scrub.summary()
    w3, _, _ = _run(get_scenario("scrub-and-repair"), engine="step")
    step_s = w3.scrub.summary()
    assert step_s["clean"]
    assert replica_set_digest(w3.table) == replica_set_digest(w1.table)


def test_bit_rot_ablation_preserves_trajectory_and_surfaces_risk():
    """With scrubbing disabled the same draws must (a) leave the campaign
    trajectory byte-identical to a corruption-free run — draws are pure
    functions, never consuming shared RNG — and (b) survive to the end as
    measurable at-risk data."""
    rot, rep_rot, st_rot = _run(get_scenario("bit-rot-paper"))
    clean, rep_clean, st_clean = _run(get_scenario("paper-2022"))
    assert st_rot.iterations == st_clean.iterations
    assert rep_rot.duration_days == rep_clean.duration_days
    assert rep_rot.faults_total == rep_clean.faults_total
    s = rot.scrub.summary()
    assert not s["clean"]
    assert s["at_risk_replicas"] > 0 and s["data_at_risk_bytes"] > 0
    assert s["scans"] == 0 and s["detected"] == 0


def test_repairs_drop_replica_from_serving_until_relanded():
    """ReplicaCatalog marks a scrub-flipped replica unserveable: holders
    lose the destination on SUCCEEDED->FAILED and regain it on re-landing —
    the mechanism behind the hit-rate dip-and-recover."""
    from repro.demand.catalog import ReplicaCatalog
    world = get_scenario("paper-2022").build(seed=0, **SHAPE)
    run_world(world, stats=EngineStats())
    cat = ReplicaCatalog(world.table, "LLNL", ("ALCF", "OLCF"))
    name = sorted(world.catalog)[0]
    assert cat.holders(name) == {"ALCF", "OLCF"}
    world.table.update(name, "ALCF", status=Status.FAILED, retries=0)
    assert cat.holders(name) == {"OLCF"}
    world.table.update(name, "OLCF", status=Status.FAILED, retries=0)
    assert not cat.materialized(name)
    world.table.update(name, "ALCF", status=Status.SUCCEEDED)
    assert cat.holders(name) == {"ALCF"}


def test_corrupt_under_demand_serves_and_ends_clean():
    world, _, _ = _run(get_scenario("corrupt-under-demand"))
    s = world.scrub.summary()
    assert s["clean"]
    d = world.demand.summary()
    assert d["requests"] > 0 and d["hit_rate"] > 0


# ------------------------------------------------------------- kill / resume
def test_mid_scrub_kill_resume_digest_identical(tmp_path):
    from repro.scenarios.crash_resume import (CRASH_RESUME_SCENARIOS,
                                              run_crash_resume)
    spec = CRASH_RESUME_SCENARIOS["crash-resume-scrub"]
    res = run_crash_resume(spec, str(tmp_path), seed=0, scale=SHAPE["scale"],
                           n_datasets=SHAPE["n_datasets"])
    assert res["kills"], "kill point never fired"
    assert res["match"], (res["reference"], res["resumed"])


def test_scrub_state_dict_roundtrip():
    world = get_scenario("scrub-and-repair").build(seed=0, **SHAPE)
    # drive a few steps so the ledger is non-trivial, then snapshot-cycle it
    run_world(world, stats=EngineStats())
    eng = world.scrub
    d = eng.state_dict()
    world2 = get_scenario("scrub-and-repair").build(seed=0, **SHAPE)
    world2.scrub.load_state_dict(d)
    assert world2.scrub.state_dict() == d
    assert world2.scrub.summary() == eng.summary()


# --------------------------------------------- batched verify / localfs audit
def _tree(root, files):
    for rel, payload in files.items():
        p = os.path.join(root, rel)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)


def test_verify_many_reports_both_size_and_checksum(tmp_path):
    rng = np.random.default_rng(0)
    files = {"a.bin": rng.bytes(1000), "sub/b.bin": rng.bytes(2000),
             "c.bin": rng.bytes(10)}
    src = str(tmp_path / "src")
    _tree(src, files)
    m = Manifest.scan(src)

    dst = str(tmp_path / "dst")
    _tree(dst, files)
    # same-size bit flip: only the checksum can catch it
    flipped = bytearray(files["a.bin"])
    flipped[100] ^= 0x40
    _tree(dst, {"a.bin": bytes(flipped)})
    # truncation: size AND checksum both wrong
    _tree(dst, {"sub/b.bin": files["sub/b.bin"][:-3]})
    os.remove(os.path.join(dst, "c.bin"))

    rep = m.verify_many(dst)
    assert rep["a.bin"] == {"ok": False, "size_ok": True,
                            "checksum_ok": False,
                            "problem": "checksum mismatch"}
    assert not rep["sub/b.bin"]["ok"]
    assert not rep["sub/b.bin"]["size_ok"]
    assert not rep["sub/b.bin"]["checksum_ok"]
    assert rep["c.bin"]["problem"] == "missing"
    # the partial-scrub path: only the requested batch is read
    part = m.verify_many(dst, rels=["a.bin"])
    assert set(part) == {"a.bin"} and not part["a.bin"]["ok"]
    # verify() stays the thin wrapper over verify_many
    assert set(m.verify(dst)) == {"a.bin", "sub/b.bin", "c.bin"}
    clean = str(tmp_path / "clean")
    _tree(clean, files)
    assert m.verify(clean) == {}
    assert all(r["ok"] for r in m.verify_many(clean).values())


def test_localfs_audit_shares_verify_many(tmp_path):
    root = str(tmp_path)
    rng = np.random.default_rng(1)
    files = {"f0.bin": rng.bytes(4096), "sub/f1.bin": rng.bytes(512)}
    _tree(os.path.join(root, "A", "data", "set1"), files)
    tr = LocalFSTransport(root)
    ds = Dataset("data/set1", sum(len(v) for v in files.values()), 2, 2)
    assert tr.poll(tr.submit(ds, "A", "B")).status is Status.SUCCEEDED
    assert all(r["ok"] for r in tr.audit(ds, "A", "B").values())
    # rot one landed byte: the audit's checksum pass catches it
    p = os.path.join(root, "B", "data", "set1", "f0.bin")
    bad = bytearray(open(p, "rb").read())
    bad[7] ^= 0x01
    with open(p, "wb") as f:
        f.write(bytes(bad))
    rep = tr.audit(ds, "A", "B")
    assert not rep["f0.bin"]["ok"] and rep["f0.bin"]["size_ok"]
    assert rep["sub/f1.bin"]["ok"]
    batch = tr.audit(ds, "A", "B", rels=["sub/f1.bin"])
    assert set(batch) == {"sub/f1.bin"}


# ------------------------------------------------------- streaming checksum
def test_streaming_checksum_random_chunking_matches_whole_buffer():
    """Deterministic chunking sweep (the hypothesis variant lives in
    test_property.py): tiny <=3-byte chunks, empty updates, and odd tails
    must all fold to the whole-buffer hash."""
    from repro.core.integrity import StreamingChecksum
    from repro.kernels.checksum.ref import checksum_bytes_np
    rng = np.random.default_rng(0)
    for size in (0, 1, 2, 3, 4, 5, 7, 63, 257, 4096, 10_001):
        data = rng.bytes(size)
        want = checksum_bytes_np(data)
        for trial in range(4):
            s = StreamingChecksum()
            i = 0
            while i < len(data):
                step = int(rng.integers(0, 4))  # 0 = empty update
                s.update(data[i:i + step])
                i += step
            s.update(b"")
            assert s.digest() == want, (size, trial)
