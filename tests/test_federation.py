"""Federation engine acceptance tests.

The contract (ISSUE 4): N campaigns share one simulated world — one clock,
one route graph, one transport whose fair-share allocator is where they
contend — while keeping private tables/schedulers/notifiers.  Pinned here:

  * a 1-element federation replays the member scenario BIT-identically
    (iterations, float-exact sim days, fault totals, succeeded-set digest),
    both engines — the regression anchor for the driver refactor;
  * ``federation-paper-twice`` completes, saturates but never exceeds the
    shared LLNL read cap, and beats the serial back-to-back variant;
  * kill-and-resume of a federation at ~50% reproduces identical per-member
    digests (snapshot layout, GC, and the crash-resume family entry);
  * ``SimulatedTransport.cancel`` / ``ReplicationScheduler.teardown``
    release a finished (or timed-out) campaign's fair-share slots;
  * the CLI and dashboard handle federation names transparently.
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

from repro.core.dashboard import (progress_rows, render_federation_text,
                                  render_progress)
from repro.core.pause import DAY
from repro.core.snapshot import (CampaignKilled, Checkpointer,
                                 FederationSnapshot, SnapshotError,
                                 SnapshotVersionError,
                                 federation_trajectory_summary, load_snapshot,
                                 resume_world, trajectory_summary)
from repro.core.transfer_table import Status
from repro.scenarios.crash_resume import run_crash_resume
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import (FEDERATION_PAPER_TWICE, get_scenario,
                                      list_federations)
from repro.scenarios.spec import (FederationMemberSpec, FederationSpec,
                                  FederationWorld)

TINY = dict(scale=0.004, seed=2, n_datasets=8)
SMALL = dict(scale=0.01, seed=0, n_datasets=12)


def _solo_federation(name="paper-2022"):
    spec = get_scenario(name)
    return FederationSpec(
        name=f"solo-{name}", description="1-element federation",
        members=(FederationMemberSpec(spec, start_day=0.0, label="solo"),))


# ------------------------------------------------------------- bit-identity
@pytest.mark.parametrize("engine", ("events", "step"))
def test_one_element_federation_bit_identical(engine):
    """Acceptance: a single-campaign scenario run as a 1-element federation
    reproduces the standalone trajectory exactly — same driver iterations,
    float-equal sim days, same fault totals and succeeded-set digest."""
    kw = dict(SMALL) if engine == "events" else dict(
        scale=0.005, seed=0, n_datasets=10)
    spec = get_scenario("paper-2022")
    world = spec.build(**kw)
    stats = EngineStats()
    rep = run_world(world, engine=engine, stats=stats)
    ref = trajectory_summary(rep, stats, world.table)

    fed = _solo_federation().build(**kw)
    fstats = EngineStats()
    frep = run_world(fed, engine=engine, stats=fstats)
    fsum = federation_trajectory_summary(frep, fstats, fed)
    member = fsum["members"]["solo"]
    assert fstats.iterations == stats.iterations
    assert member["sim_days"] == ref["sim_days"]          # float-exact
    assert member["faults_total"] == ref["faults_total"]
    assert member["quarantined"] == ref["quarantined"]
    assert member["bytes_at"] == ref["bytes_at"]
    assert member["succeeded_digest"] == ref["succeeded_digest"]


def test_one_element_federation_with_top_ups_bit_identical():
    """The per-runtime feed cursors and pending-top-up sets survive the
    extraction into CampaignRuntime."""
    kw = dict(scale=0.004, seed=0, n_datasets=8)
    spec = get_scenario("incremental-top-up")
    world = spec.build(**kw)
    stats = EngineStats()
    rep = run_world(world, stats=stats)
    ref = trajectory_summary(rep, stats, world.table)

    fed = _solo_federation("incremental-top-up").build(**kw)
    fstats = EngineStats()
    frep = run_world(fed, stats=fstats)
    member = federation_trajectory_summary(frep, fstats,
                                           fed)["members"]["solo"]
    assert fstats.iterations == stats.iterations
    assert member["sim_days"] == ref["sim_days"]
    assert member["succeeded_digest"] == ref["succeeded_digest"]


# -------------------------------------------------------------- paper twice
def _watch_llnl_egress(world):
    """Wrap the shared allocator: record aggregate LLNL egress (rate x
    actives) at every tick, relative to the LLNL read cap."""
    transport = world.shared.transport
    read_bw = world.shared.graph.sites["LLNL"].read_bw
    seen = {"max_frac": 0.0, "max_llnl_movers": 0}
    orig = transport._route_rates

    def route_rates(movers):
        rates = orig(movers)
        active = {}
        for x in movers:
            r = (x.source, x.destination)
            active[r] = active.get(r, 0) + 1
        llnl = {r: n for r, n in active.items() if r[0] == "LLNL"}
        egress = sum(rates[r] * n for r, n in llnl.items())
        seen["max_frac"] = max(seen["max_frac"], egress / read_bw)
        seen["max_llnl_movers"] = max(seen["max_llnl_movers"],
                                      sum(llnl.values()))
        return rates

    transport._route_rates = route_rates
    return seen


def test_paper_twice_completes_with_source_cap_contention():
    """Acceptance: both overlapped campaigns complete; aggregate LLNL egress
    never exceeds read_bw; and the two campaigns genuinely overlap (more
    LLNL movers at once than one campaign alone can start)."""
    fed = get_scenario("federation-paper-twice")
    # scale chosen so the ALCF pull outlives OLCF's day-5 DTN start: both
    # campaigns then drive the source at once
    world = fed.build(scale=0.2, seed=0, n_datasets=12)
    seen = _watch_llnl_egress(world)
    rep = run_world(world, engine="events")
    for label, m in rep.members.items():
        assert (all(v >= m.total_bytes * 0.999 for v in m.bytes_at.values())
                or m.quarantined), label
    assert seen["max_frac"] <= 1.0 + 1e-9          # conservation
    assert seen["max_frac"] > 0.9                  # ...and truly contended
    # both campaigns on the source at once: 2 per route x 2 routes
    assert seen["max_llnl_movers"] > fed.members[0].scenario.max_active_per_route
    assert rep.span_days == max(rep.finished_day.values())


def test_overlap_beats_serial():
    """Acceptance: total campaign days — overlapped federation < serial
    back-to-back variant (same two member campaigns)."""
    kw = dict(scale=0.2, seed=0, n_datasets=12)
    over = run_world(get_scenario("federation-paper-twice").build(**kw))
    serial = run_world(get_scenario("federation-paper-serial").build(**kw))
    assert over.span_days < serial.span_days
    # the serial variant's second member really did start late
    assert serial.started_day["olcf"] == 100.0
    assert serial.finished_day["olcf"] > 100.0


def test_mixed_federation_runs():
    """paper-2022 + incremental-top-up share every site and route (declared
    in shared_sites) and still both complete."""
    rep = run_world(get_scenario("federation-paper-and-topup").build(**TINY))
    assert set(rep.members) == {"paper", "topup"}
    for label, m in rep.members.items():
        assert (all(v >= m.total_bytes * 0.999 for v in m.bytes_at.values())
                or m.quarantined), label


def test_staggered_member_starts_late():
    kw = dict(scale=0.01, seed=0, n_datasets=8)
    world = get_scenario("federation-paper-serial").build(**kw)
    olcf = world.runtime_by_label("olcf")
    rep = run_world(world, engine="events")
    # no OLCF row was even requested before the stagger
    first_request = min(r.requested for r in olcf.table.all()
                        if r.requested is not None)
    assert first_request >= 100.0 * DAY
    assert rep.finished_day["alcf"] < 100.0        # done before olcf starts


# ----------------------------------------------------- federation validation
def test_federation_validation_rejects_conflicts():
    paper = get_scenario("paper-2022")
    degraded = get_scenario("degraded-source")     # different LLNL caps
    bad = FederationSpec(
        name="bad", description="conflicting shared site",
        members=(FederationMemberSpec(paper, label="a"),
                 FederationMemberSpec(degraded, label="b")),
        shared_sites=("LLNL", "ALCF", "OLCF"))
    with pytest.raises(ValueError, match="different capabilities"):
        bad.build(**TINY)
    undeclared = FederationSpec(
        name="undeclared", description="shared site not declared",
        members=(FederationMemberSpec(paper, label="a"),
                 FederationMemberSpec(paper, label="b")))
    with pytest.raises(ValueError, match="shared_sites"):
        undeclared.build(**TINY)
    storm = get_scenario("fault-storm")            # different fault profile
    mixed_faults = FederationSpec(
        name="mixed-faults", description="one injector, two profiles",
        members=(FederationMemberSpec(paper, label="a"),
                 FederationMemberSpec(storm, label="b")),
        shared_sites=("LLNL", "ALCF", "OLCF"))
    with pytest.raises(ValueError, match="fault"):
        mixed_faults.build(**TINY)
    with pytest.raises(ValueError, match="no members"):
        FederationSpec(name="empty", description="",
                       members=()).build(**TINY)


# ----------------------------------------------------------- cancel/teardown
def test_transport_cancel_releases_slot_and_stays_pollable():
    from repro.core.routes import Dataset
    world = get_scenario("paper-2022").build(**TINY)
    tr = world.transport
    ds = Dataset("/x/cancel-me", bytes=10 * 1024 ** 3, files=100,
                 directories=10)
    uid = tr.submit(ds, "LLNL", "ALCF")
    assert tr.live_count == 1
    tr.cancel(uid)
    assert tr.live_count == 0
    st = tr.poll(uid)
    assert st.status == Status.FAILED and st.detail == "cancelled"
    assert tr.next_event_hint() == float("inf")
    tr.cancel(uid)                                 # terminal: no-op
    assert tr.poll(uid).detail == "cancelled"
    tr.cancel("no-such-uid")                       # unknown: no-op


def test_scheduler_teardown_cancels_outstanding():
    world = get_scenario("paper-2022").build(**SMALL)
    clock, sched, tr = world.clock, world.sched, world.transport
    for _ in range(12):
        sched.step(clock.now)
        clock.advance(1800.0)
        tr.tick()
    assert tr.live_count > 0
    occupying = world.table.count_status(Status.ACTIVE, Status.QUEUED,
                                         Status.PAUSED)
    n = sched.teardown()
    assert n == occupying
    assert tr.live_count == 0                      # slots released
    # table rows untouched: the report shows how far the campaign got
    assert world.table.count_status(Status.ACTIVE, Status.QUEUED,
                                    Status.PAUSED) == occupying


def test_timed_out_member_releases_capacity_to_survivor():
    """A member hitting its own max_days mid-federation is torn down: its
    movers leave the shared pool and the survivor finishes."""
    alcf = dataclasses.replace(get_scenario("paper-to-alcf"), max_days=3.0)
    fed = FederationSpec(
        name="timeout-fed", description="",
        members=(FederationMemberSpec(alcf, label="doomed"),
                 FederationMemberSpec(get_scenario("paper-to-olcf"),
                                      label="survivor")),
        shared_sites=("LLNL",))
    world = fed.build(scale=0.05, seed=0, n_datasets=10)
    state = {"alcf_movers_after_deadline": 0}

    def observer(w, now):
        if now > 3.0 * DAY + 1.0:
            state["alcf_movers_after_deadline"] = max(
                state["alcf_movers_after_deadline"],
                sum(1 for x in w.shared.transport._live.values()
                    if x.destination == "ALCF"))

    rep = run_world(world, engine="events", on_iteration=observer)
    doomed, survivor = rep.members["doomed"], rep.members["survivor"]
    assert state["alcf_movers_after_deadline"] == 0
    assert rep.finished_day["doomed"] == pytest.approx(3.0, abs=0.5)
    assert not all(v >= doomed.total_bytes * 0.999
                   for v in doomed.bytes_at.values())
    assert all(v >= survivor.total_bytes * 0.999
               for v in survivor.bytes_at.values())


# -------------------------------------------------------- checkpoint/resume
def _fed_reference(**kw):
    world = get_scenario("federation-paper-twice").build(**kw)
    stats = EngineStats()
    rep = run_world(world, stats=stats)
    return federation_trajectory_summary(rep, stats, world), stats.iterations


@pytest.mark.parametrize("engine", ("events", "step"))
def test_federation_kill_resume_bit_identical(tmp_path, engine):
    """Acceptance: kill the overlapped federation at ~50%, resume from the
    multi-runtime snapshot, and every member's final digest matches the
    uninterrupted run's."""
    kw = dict(SMALL) if engine == "events" else dict(
        scale=0.005, seed=0, n_datasets=8)
    world = get_scenario("federation-paper-twice").build(**kw)
    stats = EngineStats()
    rep = run_world(world, engine=engine, stats=stats)
    ref = federation_trajectory_summary(rep, stats, world)
    total = stats.iterations

    world2 = get_scenario("federation-paper-twice").build(**kw)
    ck = Checkpointer(str(tmp_path), kill_after=total // 2)
    with pytest.raises(CampaignKilled):
        run_world(world2, engine=engine, stats=EngineStats(),
                  checkpointer=ck)
    # one table copy per member landed next to the snapshot
    tables = [f for f in os.listdir(tmp_path) if f.startswith("table-")]
    assert len(tables) == 2

    world3, snap, loop = resume_world(str(tmp_path))
    assert isinstance(snap, FederationSnapshot)
    assert snap.iterations == total // 2
    assert snap.engine == engine
    assert isinstance(world3, FederationWorld)
    stats3 = EngineStats()
    rep3 = run_world(world3, engine=engine, stats=stats3, resume=loop)
    assert federation_trajectory_summary(rep3, stats3, world3) == ref


def test_federation_resume_is_repeatable_and_gc_prunes_members(tmp_path):
    ref, total = _fed_reference(**SMALL)
    world = get_scenario("federation-paper-twice").build(**SMALL)
    ck = Checkpointer(str(tmp_path), every=10, keep=2, kill_after=total // 3)
    with pytest.raises(CampaignKilled):
        run_world(world, stats=EngineStats(), checkpointer=ck)
    snaps = [f for f in os.listdir(tmp_path) if f.startswith("snapshot-")]
    tables = [f for f in os.listdir(tmp_path) if f.startswith("table-")]
    assert 1 <= len(snaps) <= 2
    assert len(tables) == 2 * len(snaps)           # GC removed older epochs
    results = []
    for _ in range(2):
        w, snap, loop = resume_world(str(tmp_path))
        st = EngineStats()
        rep = run_world(w, engine=snap.engine, stats=st, resume=loop)
        results.append(federation_trajectory_summary(rep, st, w))
    assert results[0] == results[1] == ref


def test_federation_snapshot_roundtrip_and_version_guard(tmp_path):
    world = get_scenario("federation-paper-twice").build(**SMALL)
    ck = Checkpointer(str(tmp_path), kill_after=10)
    with pytest.raises(CampaignKilled):
        run_world(world, stats=EngineStats(), checkpointer=ck)
    snap = load_snapshot(str(tmp_path))
    assert isinstance(snap, FederationSnapshot)
    assert snap.transport["live"], "no live transfers captured"
    assert len(snap.runtimes) == 2
    assert [r["label"] for r in snap.runtimes] == ["alcf", "olcf"]
    back = FederationSnapshot.loads(snap.dumps())
    for f in dataclasses.fields(FederationSnapshot):
        assert getattr(back, f.name) == getattr(snap, f.name), f.name
    assert FederationSnapshot.loads(back.dumps()) == back  # fixed point
    d = snap.to_dict()
    d["version"] = 999
    with pytest.raises(SnapshotVersionError, match="999"):
        FederationSnapshot.from_dict(d)
    d2 = snap.to_dict()
    d2["kind"] = "campaign"
    with pytest.raises(SnapshotError, match="kind"):
        FederationSnapshot.from_dict(d2)
    d3 = snap.to_dict()
    d3["runtimes"][0].pop("scheduler")
    with pytest.raises(SnapshotError, match="scheduler"):
        FederationSnapshot.from_dict(d3)


def test_crash_resume_federation_scenario(tmp_path):
    spec = get_scenario("crash-resume-federation")
    res = run_crash_resume(spec, str(tmp_path), seed=0, scale=0.01,
                           n_datasets=10)
    assert res["kills"]
    assert res["match"], (res["reference"], res["resumed"])


# ----------------------------------------------------------------- registry
def test_federation_family_registered():
    names = list_federations()
    for required in ("federation-paper-twice", "federation-paper-serial",
                     "federation-paper-and-topup"):
        assert required in names
        assert isinstance(get_scenario(required), FederationSpec)
    assert FEDERATION_PAPER_TWICE.member_labels() == ["alcf", "olcf"]


# ---------------------------------------------------------------- dashboard
def test_dashboard_progress_rows_side_by_side():
    # heavy enough that transfers are still moving after a few hours
    world = get_scenario("federation-paper-twice").build(scale=0.5, seed=0,
                                                         n_datasets=12)
    clock, tr = world.shared.clock, world.shared.transport
    for _ in range(10):
        for rt in world.runtimes:
            rt.sched.step(clock.now)
        clock.advance(1800.0)
        tr.tick()
    rows = progress_rows(
        [(rt.label, rt.table, list(rt.cfg.replicas),
          sum(d.bytes for d in rt.catalog.values()))
         for rt in world.runtimes])
    assert [(r["campaign"], r["destination"]) for r in rows] == \
        [("alcf", "ALCF"), ("olcf", "OLCF")]
    for r in rows:
        assert {"bytes", "files", "faults", "eta_days", "rate", "active",
                "complete_fraction"} <= set(r)
        assert 0.0 <= r["complete_fraction"] <= 1.0
    # a campaign actively moving bytes has a finite, positive ETA
    moving = [r for r in rows if r["rate"] > 0]
    assert moving
    assert all(0 < r["eta_days"] < float("inf") for r in moving)
    txt = render_federation_text(world, clock.now)
    assert "alcf" in txt and "olcf" in txt and "ETA" in txt
    # single-campaign render keeps working and carries the progress header
    from repro.core.dashboard import render_text
    rt = world.runtimes[0]
    txt2 = render_text(rt.table, list(rt.cfg.replicas),
                       sum(d.bytes for d in rt.catalog.values()),
                       clock.now, campaign=rt.label)
    assert "Replication progress" in txt2 and "Replication to ALCF" in txt2


# ----------------------------------------------------------------------- CLI
def test_cli_federation_transparent(tmp_path):
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    base = [sys.executable, "-m", "repro.scenarios.run", "--scenario",
            "federation-paper-twice", "--datasets", "8", "--scale", "0.004"]
    ref_json = str(tmp_path / "ref.json")
    r = subprocess.run(base + ["--json", ref_json], capture_output=True,
                       text=True, timeout=300, env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    ref = json.load(open(ref_json))
    assert ref["scenario"] == "federation-paper-twice"
    assert set(ref["members"]) == {"alcf", "olcf"}
    assert set(ref["trajectory"]["members"]) == {"alcf", "olcf"}

    ck = str(tmp_path / "ck")
    kill_at = max(1, ref["engine_iterations"] // 2)
    r = subprocess.run(base + ["--checkpoint-dir", ck, "--kill-after",
                               str(kill_at)],
                       capture_output=True, text=True, timeout=300, env=env,
                       cwd=".")
    assert r.returncode == 3, (r.returncode, r.stderr[-2000:])
    res_json = str(tmp_path / "resumed.json")
    r = subprocess.run([sys.executable, "-m", "repro.scenarios.run",
                        "--resume", ck, "--json", res_json],
                       capture_output=True, text=True, timeout=300, env=env,
                       cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    resumed = json.load(open(res_json))
    assert resumed["trajectory"] == ref["trajectory"]
    assert resumed["resumed_from"]["iterations"] == kill_at
