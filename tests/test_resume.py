"""Durable checkpoint/resume acceptance tests.

The contract (ISSUE 3 / paper §3): a campaign killed at ANY iteration and
resumed from its last snapshot finishes with a trajectory identical to the
uninterrupted run — same iteration count, simulated days, fault count, and
succeeded-set digest — under both driver engines.  Plus: snapshot round-trip
fidelity field by field, loud version-mismatch failures, checkpoint-directory
atomicity/GC, the crash-resume scenario family, the CLI kill/resume flow,
and the ``TransferTable`` resume-from-disk-store path.
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

from repro.core.snapshot import (CampaignKilled, CampaignSnapshot,
                                 Checkpointer, LoopState, SnapshotError,
                                 SnapshotVersionError, capture_snapshot,
                                 load_snapshot, resume_world,
                                 succeeded_digest, trajectory_summary)
from repro.core.transfer_table import Status, TransferRecord, TransferTable
from repro.scenarios.crash_resume import CrashResumeSpec, run_crash_resume
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import get_scenario, list_crash_scenarios

SMALL = dict(scale=0.01, seed=0, n_datasets=16)


def _reference(spec_name="paper-2022", engine="events", **overrides):
    kw = dict(SMALL, **overrides)
    world = get_scenario(spec_name).build(**kw)
    stats = EngineStats()
    rep = run_world(world, engine=engine, stats=stats)
    return trajectory_summary(rep, stats, world.table), stats.iterations, kw


# ------------------------------------------------------------- bit-identity
@pytest.mark.parametrize("engine", ("events", "step"))
def test_kill_and_resume_bit_identical(tmp_path, engine):
    """Acceptance: kill at ~50% of iterations, resume from the snapshot, and
    the final trajectory (digest included) equals the uninterrupted run's."""
    kw = dict(SMALL) if engine == "events" else dict(
        scale=0.005, seed=0, n_datasets=10)
    ref, total, kw = _reference(engine=engine, **kw)
    spec = get_scenario("paper-2022")

    world = spec.build(**kw)
    stats = EngineStats()
    ck = Checkpointer(str(tmp_path), kill_after=total // 2)
    with pytest.raises(CampaignKilled):
        run_world(world, engine=engine, stats=stats, checkpointer=ck)

    world2, snap, loop = resume_world(str(tmp_path))
    assert snap.iterations == total // 2
    assert snap.engine == engine
    stats2 = EngineStats()
    rep2 = run_world(world2, engine=engine, stats=stats2, resume=loop)
    assert trajectory_summary(rep2, stats2, world2.table) == ref


def test_resume_is_repeatable(tmp_path):
    """A checkpoint is read-only: resuming it twice gives the same result."""
    ref, total, kw = _reference()
    spec = get_scenario("paper-2022")
    world = spec.build(**kw)
    ck = Checkpointer(str(tmp_path), kill_after=total // 3)
    with pytest.raises(CampaignKilled):
        run_world(world, stats=EngineStats(), checkpointer=ck)
    results = []
    for _ in range(2):
        w, snap, loop = resume_world(str(tmp_path))
        st = EngineStats()
        rep = run_world(w, engine=snap.engine, stats=st, resume=loop)
        results.append(trajectory_summary(rep, st, w.table))
    assert results[0] == results[1] == ref


def test_periodic_checkpoints_do_not_perturb_and_gc_keeps_latest(tmp_path):
    """Cadenced snapshotting must be trajectory-neutral, keep at most
    ``keep`` epochs on disk, and leave a resumable LATEST even after the
    campaign completed."""
    ref, _, kw = _reference()
    spec = get_scenario("paper-2022")
    world = spec.build(**kw)
    stats = EngineStats()
    ck = Checkpointer(str(tmp_path), every=10, keep=2)
    rep = run_world(world, stats=stats, checkpointer=ck)
    assert trajectory_summary(rep, stats, world.table) == ref  # neutral
    assert ck.writes >= 3
    snaps = [f for f in os.listdir(tmp_path) if f.startswith("snapshot-")]
    tables = [f for f in os.listdir(tmp_path) if f.startswith("table-")]
    assert 1 <= len(snaps) <= 2 and len(tables) == len(snaps)  # GC ran
    # resume from the last mid-run snapshot: completes to the same trajectory
    w, snap, loop = resume_world(str(tmp_path))
    st = EngineStats()
    rep2 = run_world(w, engine=snap.engine, stats=st, resume=loop)
    assert trajectory_summary(rep2, st, w.table) == ref


def test_signal_requested_kill_checkpoints_at_next_boundary(tmp_path):
    """The signal path (request_kill) writes a snapshot and raises at the
    next loop boundary; resuming completes bit-identically."""
    ref, total, kw = _reference()
    spec = get_scenario("paper-2022")
    world = spec.build(**kw)
    ck = Checkpointer(str(tmp_path))
    fired_at = total // 4

    def observer(w, now):
        if not ck._kill and stats_box["stats"].iterations >= fired_at:
            ck.request_kill()           # as the SIGTERM handler would

    stats_box = {"stats": EngineStats()}
    with pytest.raises(CampaignKilled) as exc:
        run_world(world, stats=stats_box["stats"], checkpointer=ck,
                  on_iteration=observer)
    assert exc.value.iterations >= fired_at
    assert os.path.exists(os.path.join(tmp_path, "LATEST"))
    w, snap, loop = resume_world(str(tmp_path))
    st = EngineStats()
    rep = run_world(w, engine=snap.engine, stats=st, resume=loop)
    assert trajectory_summary(rep, st, w.table) == ref


# -------------------------------------------------------- crash-resume family
def test_crash_resume_family_registered():
    names = list_crash_scenarios()
    for required in ("crash-resume-paper", "crash-resume-storm",
                     "crash-resume-topup", "crash-resume-step"):
        assert required in names
        assert isinstance(get_scenario(required), CrashResumeSpec)


@pytest.mark.parametrize("name,overrides", [
    ("crash-resume-paper", dict(scale=0.01, n_datasets=12)),
    ("crash-resume-storm", dict(scale=0.01, n_datasets=12)),
    ("crash-resume-topup", dict(scale=0.004, n_datasets=8)),
    ("crash-resume-step", dict(scale=0.005, n_datasets=10)),
])
def test_crash_resume_scenarios_match(tmp_path, name, overrides):
    """Every family member: N kills + resumes == uninterrupted, exactly."""
    spec = get_scenario(name)
    res = run_crash_resume(spec, str(tmp_path), seed=0, **overrides)
    assert res["kills"], "campaign finished before the first kill point"
    assert len(res["kills"]) == len(set(spec.kill_fracs))
    assert res["match"], (res["reference"], res["resumed"])


# ------------------------------------------------------- snapshot round-trip
def _mid_campaign_snapshot(tmp_path):
    """A snapshot captured mid-flight with live movers, backoff state, and
    top-up cursors populated (incremental-top-up under fault-storm-ish
    pressure would be ideal; topup at 50% is plenty)."""
    spec = get_scenario("incremental-top-up")
    world = spec.build(scale=0.004, seed=0, n_datasets=8)
    stats = EngineStats()
    ck = Checkpointer(str(tmp_path), kill_after=20)
    with pytest.raises(CampaignKilled):
        run_world(world, stats=stats, checkpointer=ck)
    return load_snapshot(str(tmp_path))


def test_snapshot_roundtrip_every_field(tmp_path):
    """Serialize→deserialize preserves every ``CampaignSnapshot`` field
    exactly (floats bit-for-bit, nested structures canonicalized)."""
    snap = _mid_campaign_snapshot(tmp_path)
    # the snapshot is non-trivial: live movers, queues, RNG position, faults
    assert snap.transport["live"], "no live transfers captured"
    assert snap.scheduler["direct"] or snap.scheduler["relay"]
    assert snap.injector["fragility"]
    assert snap.injector["rng"]["bit_generator"]
    assert snap.clock_now > 0
    back = CampaignSnapshot.loads(snap.dumps())
    for f in dataclasses.fields(CampaignSnapshot):
        assert getattr(back, f.name) == getattr(snap, f.name), f.name
    assert back == snap
    # a second round-trip is a fixed point
    assert CampaignSnapshot.loads(back.dumps()) == back


def test_snapshot_version_mismatch_fails_loudly(tmp_path):
    snap = _mid_campaign_snapshot(tmp_path)
    d = snap.to_dict()
    d["version"] = 999
    with pytest.raises(SnapshotVersionError, match="999"):
        CampaignSnapshot.from_dict(d)
    d.pop("version")
    with pytest.raises(SnapshotVersionError):
        CampaignSnapshot.from_dict(d)
    # unknown/missing payload fields are loud too (forward-compat guard)
    d2 = snap.to_dict()
    d2["mystery_field"] = 1
    with pytest.raises(SnapshotError, match="mystery_field"):
        CampaignSnapshot.from_dict(d2)
    d3 = snap.to_dict()
    d3.pop("clock_now")
    with pytest.raises(SnapshotError, match="clock_now"):
        CampaignSnapshot.from_dict(d3)


def test_apply_snapshot_rejects_wrong_scenario(tmp_path):
    from repro.core.snapshot import apply_snapshot
    snap = _mid_campaign_snapshot(tmp_path)
    other = get_scenario("paper-2022").build(scale=0.004, seed=0,
                                             n_datasets=8)
    with pytest.raises(SnapshotError, match="scenario"):
        apply_snapshot(other, snap)


def test_load_snapshot_refuses_non_checkpoint_dir(tmp_path):
    with pytest.raises(SnapshotError, match="LATEST"):
        load_snapshot(str(tmp_path))


# ---------------------------------------------------------------- CLI flow
def test_cli_kill_resume_trajectory_identical(tmp_path):
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    base = [sys.executable, "-m", "repro.scenarios.run", "--scenario",
            "paper-2022", "--datasets", "12", "--scale", "0.01"]
    ref_json = str(tmp_path / "ref.json")
    r = subprocess.run(base + ["--json", ref_json], capture_output=True,
                       text=True, timeout=300, env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    ref = json.load(open(ref_json))
    assert "trajectory" in ref and ref["trajectory"]["succeeded_digest"]

    ck = str(tmp_path / "ck")
    kill_at = max(1, ref["engine_iterations"] // 2)
    r = subprocess.run(base + ["--checkpoint-dir", ck, "--kill-after",
                               str(kill_at)],
                       capture_output=True, text=True, timeout=300, env=env,
                       cwd=".")
    assert r.returncode == 3, (r.returncode, r.stderr[-2000:])
    killed = json.loads(r.stdout)
    assert killed["killed"] and killed["iterations"] == kill_at

    res_json = str(tmp_path / "resumed.json")
    r = subprocess.run([sys.executable, "-m", "repro.scenarios.run",
                        "--resume", ck, "--json", res_json],
                       capture_output=True, text=True, timeout=300, env=env,
                       cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    resumed = json.load(open(res_json))
    assert resumed["trajectory"] == ref["trajectory"]
    assert resumed["resumed_from"]["iterations"] == kill_at


def test_cli_runs_crash_resume_scenario(tmp_path):
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "repro.scenarios.run", "--scenario",
         "crash-resume-paper", "--datasets", "10", "--scale", "0.005",
         "--checkpoint-dir", str(tmp_path / "w")],
        capture_output=True, text=True, timeout=600, env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    doc = json.loads(r.stdout)
    assert doc["match"] and doc["kills"]


# ------------------------------------------- TransferTable disk-store resume
def _mutate(t: TransferTable):
    t.populate(["a", "b", "c"], "LLNL", ["ALCF", "OLCF"])
    t.update("a", "ALCF", status=Status.SUCCEEDED, bytes_transferred=123,
             rate=4.5, uuid="u1", requested=1.0, completed=2.5, files=7,
             directories=3)
    t.update("b", "ALCF", status=Status.FAILED, retries=2, faults=1)
    t.update("b", "OLCF", status=Status.ACTIVE, uuid="u2", requested=3.0)
    t.update("c", "OLCF", status=Status.QUARANTINED, faults=7, retries=9)
    # re-routed relay row: source rewritten, then succeeded
    t.update("a", "OLCF", source="ALCF", status=Status.SUCCEEDED,
             bytes_transferred=123, rate=2.25)


def test_transfer_table_cold_load_matches_fresh(tmp_path):
    """The `resume from a disk store` constructor path: reopening a
    populated sqlite file must reconstruct rows, caches, indexes, and
    counters exactly as the live table held them."""
    path = str(tmp_path / "table.sqlite")
    t = TransferTable(path)
    _mutate(t)
    want = t.all()
    t.close()

    r = TransferTable(path)
    assert r.all() == want
    # derived counters/indexes, rebuilt not persisted
    assert r.bytes_at("ALCF") == 123 and r.bytes_at("OLCF") == 123
    assert r.succeeded_set("ALCF") == {"a"}
    assert r.succeeded_set("OLCF") == {"a"}
    assert r.count_route("LLNL", "ALCF", Status.FAILED) == 1
    assert r.count_route("ALCF", "OLCF", Status.SUCCEEDED) == 1
    assert r.count_status(Status.QUARANTINED) == 1
    assert r.count_status(*Status) == 6
    assert [x.dataset for x in r.by_status(Status.SUCCEEDED)] == ["a", "a"]
    assert not r.done()
    # cache and sqlite agree row for row
    db_rows = sorted(((x.dataset, x.destination, x.status)
                      for x in r._select_db("", ())))
    cache_rows = sorted((x.dataset, x.destination, x.status)
                        for x in r.all())
    assert db_rows == cache_rows
    # the reopened table is fully live: listeners fire, counters track
    seen = []
    r.add_listener(lambda rec, old, src: seen.append((rec.dataset, old)))
    r.update("b", "OLCF", status=Status.SUCCEEDED, bytes_transferred=50)
    assert seen == [("b", Status.ACTIVE)]
    assert r.bytes_at("OLCF") == 173
    r.close()


def test_transfer_table_dump_load_roundtrip(tmp_path):
    path = str(tmp_path / "copy.sqlite")
    t = TransferTable()
    _mutate(t)
    t.dump(path)
    assert not os.path.exists(path + ".tmp")    # atomic: temp renamed away
    c = TransferTable.load(path)
    assert c.all() == t.all()
    assert c.bytes_at("ALCF") == t.bytes_at("ALCF")
    # load() copies: mutating the copy leaves the file (and re-loads) intact
    c.update("a", "ALCF", status=Status.FAILED)
    c2 = TransferTable.load(path)
    assert c2.all() == t.all()
    # dump overwrites atomically with fresh content
    t.update("c", "ALCF", status=Status.ACTIVE, uuid="u9")
    t.dump(path)
    assert TransferTable.load(path).all() == t.all()


def test_transfer_table_load_missing_file(tmp_path):
    with pytest.raises(FileNotFoundError):
        TransferTable.load(str(tmp_path / "nope.sqlite"))


def test_scheduler_resumes_over_cold_loaded_table(tmp_path):
    """A scheduler constructed over a disk-reopened table adopts its rows:
    outstanding work continues, finished work is not redone."""
    from repro.core.campaign import CampaignConfig, build_campaign

    cfg = CampaignConfig(n_datasets=8, scale=0.004, seed=3)
    path = str(tmp_path / "t.sqlite")
    # run half a campaign against a disk-backed table, then drop everything
    g, cat, clock, pause, tr, table, sched, notif = build_campaign(
        cfg, table=TransferTable(path))
    for _ in range(60):
        sched.step(clock.now)
        clock.advance(cfg.step_s)
        tr.tick()
    before = {(r.dataset, r.destination): r.status for r in table.all()}
    done_before = {k for k, s in before.items() if s == Status.SUCCEEDED}
    table.close()

    # cold reopen: statuses are intact; in-flight rows (their movers died
    # with the process) are still occupying their slots, exactly what the
    # snapshot layer overwrites — here we just verify adoption + durability
    t2 = TransferTable(path)
    after = {(r.dataset, r.destination): r.status for r in t2.all()}
    assert after == before
    assert {k for k, s in after.items()
            if s == Status.SUCCEEDED} == done_before
    t2.close()


# --------------------------------------------------------------- digest unit
def test_succeeded_digest_sensitivity():
    a, b = TransferTable(), TransferTable()
    for t in (a, b):
        t.populate(["x", "y"], "LLNL", ["ALCF"])
    a.update("x", "ALCF", status=Status.SUCCEEDED, bytes_transferred=10)
    b.update("x", "ALCF", status=Status.SUCCEEDED, bytes_transferred=10)
    assert succeeded_digest(a) == succeeded_digest(b)
    b.update("y", "ALCF", status=Status.SUCCEEDED, bytes_transferred=1)
    assert succeeded_digest(a) != succeeded_digest(b)
    a.update("y", "ALCF", status=Status.SUCCEEDED, bytes_transferred=2)
    assert succeeded_digest(a) != succeeded_digest(b)  # bytes differ
