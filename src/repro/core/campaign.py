"""End-to-end replication campaign driver (paper §4) under a simulated clock.

Reconstructs the 2022 campaign: 2291 ESGF paths, 7.3 PB / 29 M files, three
sites, Table-3 bandwidths, ALCF weekly maintenance, OLCF coming online late,
the CMIP5 permission/GPFS incident around day 60, and termination when every
dataset lives at both LCFs.  EXPERIMENTS.md validates the simulated duration
(~77 days vs the 58-day single-path floor) and fault statistics against the
paper.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.faults import FaultInjector, Notifier, RetryPolicy
from repro.core.pause import DAY, PauseManager
from repro.core.routes import (GB, PB, Dataset, RouteGraph, make_catalog,
                               paper_route_graph, split_oversized)
from repro.core.scheduler import ReplicationPolicy, ReplicationScheduler
from repro.core.transfer_table import Status, TransferTable
from repro.core.transport import SimClock, SimulatedTransport


@dataclass
class CampaignConfig:
    n_datasets: int = 2291
    total_bytes: int = int(7.3 * PB)
    total_files: int = 28_907_532
    source: str = "LLNL"
    replicas: Tuple[str, ...] = ("ALCF", "OLCF")
    step_s: float = 1800.0               # scheduler cadence
    max_days: float = 200.0
    seed: int = 0
    # incidents (paper Fig. 5 phases)
    olcf_online_day: float = 5.0         # phase 1: OLCF DTN not yet online
    alcf_weekly_maint_day: float = 5.0   # phase 2: first ALCF maintenance start
    alcf_maint_hours: float = 12.0
    unreadable_fraction: float = 0.01    # phase 4: CMIP5 permission incident
    human_fix_days: float = 3.0          # time for admins to fix permissions
    scale: float = 1.0                   # 1.0 = full 7.3 PB; tests use less
    task_setup_s: float = 0.0            # fixed dispatch cost per transfer task
    # retention horizon (days) for the transport's per-(day, route) flow
    # telemetry; None keeps the whole campaign (seed behaviour)
    flow_horizon_days: Optional[float] = None


@dataclass
class CampaignReport:
    duration_days: float
    floor_days: float                    # single-path theoretical minimum
    total_bytes: int
    bytes_at: Dict[str, int]
    per_route_gbps: Dict[Tuple[str, str], float]
    per_route_transfers: Dict[Tuple[str, str], int]
    faults_total: int
    faults_per_transfer_mean: float
    faults_per_transfer_max: int
    fault_histogram: Dict[int, int]
    timeline: List[Tuple[float, Dict[str, int]]]   # (day, bytes at each replica)
    notifications: List[str]
    quarantined: int


@dataclass
class FederationReport:
    """Aggregate outcome of N concurrent campaigns driven over one shared
    simulated world (``repro.scenarios.spec.FederationSpec``).  ``members``
    preserves member order; each member's ``duration_days`` is the absolute
    simulation day it finished (stagger included)."""
    members: Dict[str, CampaignReport]       # label -> per-campaign report
    started_day: Dict[str, float]            # label -> scheduled start day
    finished_day: Dict[str, float]           # label -> completion/timeout day
    span_days: float                         # last member's finish day


def build_catalog(cfg: CampaignConfig,
                  graph: RouteGraph) -> Dict[str, Dataset]:
    """The campaign's dataset catalog: synthesized ESGF-like paths,
    oversized requests pre-split to fit the source's scan memory (paper §5),
    and the permission incident's unreadable fraction marked.  Pure function
    of (cfg, graph) — callers may build it ahead of ``build_campaign`` (the
    control plane does, to bundle it) without perturbing the trajectory."""
    raw = make_catalog(
        n_datasets=cfg.n_datasets,
        total_bytes=int(cfg.total_bytes * cfg.scale),
        total_files=int(cfg.total_files * cfg.scale),
        seed=cfg.seed)
    catalog: Dict[str, Dataset] = {}
    limit = graph.sites[cfg.source].scan_mem_limit_files
    rng = np.random.default_rng(cfg.seed + 1)
    for ds in raw:
        for part in split_oversized(ds, limit):
            catalog[part.path] = part
    # permission incident: a fraction of (CMIP5-ish) datasets unreadable
    paths = sorted(catalog)
    n_bad = int(len(paths) * cfg.unreadable_fraction)
    for p in rng.choice(paths, size=n_bad, replace=False):
        catalog[p].unreadable = True
    return catalog


def build_campaign(cfg: CampaignConfig, *,
                   graph: Optional[RouteGraph] = None,
                   pause: Optional[PauseManager] = None,
                   injector: Optional[FaultInjector] = None,
                   retry: Optional[RetryPolicy] = None,
                   max_active_per_route: int = 2,
                   table: Optional[TransferTable] = None,
                   transport: Optional[SimulatedTransport] = None,
                   notifier: Optional[Notifier] = None,
                   catalog: Optional[Dict[str, Dataset]] = None):
    """Wire up catalog, sites, calendar, transport, table, scheduler.

    The keyword overrides let a ``repro.scenarios.spec.ScenarioSpec`` compile
    its own topology, maintenance calendar, and fault profile onto the same
    wiring; with no overrides this reproduces the paper's 2022 campaign.
    ``table`` accepts a pre-populated transfer table (checkpoint resume); the
    populate pass then inserts nothing, because every row already exists.

    ``transport`` attaches this campaign to an existing (shared) transport
    instead of constructing its own — the federation path, where N campaign
    runtimes contend through one ``SimulatedTransport``'s fair-share rate
    allocator.  The shared transport's clock/pause/injector are then
    authoritative; ``notifier`` is the *campaign's* notifier (the scheduler's
    quarantine notifications go there), which may differ from the transport's
    routing notifier.

    ``catalog`` overrides the internally built catalog — the control plane's
    bundling path, where the scheduler's work items are composed *bundles*
    (possibly a live, growing dict) rather than raw catalog datasets.
    """
    if graph is None:
        graph = paper_route_graph()
    if catalog is None:
        catalog = build_catalog(cfg, graph)

    clock = transport.clock if transport is not None else SimClock(0.0)
    if pause is None and transport is not None:
        pause = transport.pause
    if pause is None:
        pause = PauseManager()
        # OLCF offline until its DTN comes up (phase 1)
        pause.add_window("OLCF", 0.0, cfg.olcf_online_day * DAY, planned=False)
        # phase 2: the first ALCF maintenance was an extended multi-day window
        # (paper Feb 20-25), then a weekly occurrence
        pause.add_window("ALCF", cfg.alcf_weekly_maint_day * DAY,
                         (cfg.alcf_weekly_maint_day + 5) * DAY)
        pause.add_weekly("ALCF", (cfg.alcf_weekly_maint_day + 12) * DAY,
                         cfg.alcf_maint_hours * 3600.0, cfg.max_days * DAY)
        # occasional OLCF maintenance
        pause.add_weekly("OLCF", 40 * DAY, 12 * 3600.0, cfg.max_days * DAY)

    if injector is None and transport is None:
        injector = FaultInjector(seed=cfg.seed)
    if notifier is None:
        notifier = Notifier()
    if retry is None:
        retry = RetryPolicy(max_retries=8, backoff_s=3600.0)
    if transport is None:
        transport = SimulatedTransport(graph, clock, pause, injector,
                                       notifier, retry,
                                       task_setup_s=cfg.task_setup_s,
                                       flow_horizon_days=cfg.flow_horizon_days)
    if table is None:
        table = TransferTable()
    sched = ReplicationScheduler(
        table, transport, catalog,
        ReplicationPolicy(cfg.source, cfg.replicas, max_active_per_route),
        retry, notifier)
    sched.populate()
    return graph, catalog, clock, pause, transport, table, sched, notifier


def apply_human_fixes(notifier: Notifier, fix_at: Dict[str, float],
                      now: float, human_fix_days: float) -> None:
    """Human-in-the-loop: permission fixes land ``human_fix_days`` after
    notification (paper phase 4→5).  ``fix_at`` is the caller's pending-fix
    schedule, mutated in place; shared by the step and event drivers."""
    for ds_path, fixed in list(notifier.fixed.items()):
        if not fixed and ds_path not in fix_at:
            fix_at[ds_path] = now + human_fix_days * DAY
    for ds_path, t in list(fix_at.items()):
        if now >= t and not notifier.is_fixed(ds_path):
            notifier.fix(ds_path)


def aggregate_report(cfg: CampaignConfig, graph: RouteGraph,
                     catalog: Dict[str, Dataset], clock: SimClock,
                     table: TransferTable, notifier: Notifier,
                     timeline: List[Tuple[float, Dict[str, int]]]
                     ) -> CampaignReport:
    """Campaign statistics from a finished (or timed-out) table — per-route
    achieved rates over *active* time only (Table 3 semantics), the Fig. 6
    fault histogram, and final per-replica byte counts."""
    total = sum(d.bytes for d in catalog.values())
    per_route_rates: Dict[Tuple[str, str], list] = {}
    per_route_n: Dict[Tuple[str, str], int] = {}
    faults = []
    for rec in table.all():
        if rec.status != Status.SUCCEEDED:
            continue
        route = (rec.source, rec.destination)
        per_route_n[route] = per_route_n.get(route, 0) + 1
        if rec.rate:
            per_route_rates.setdefault(route, []).append(rec.rate)
        faults.append(rec.faults)
    per_route_gbps = {
        r: float(np.mean(v)) / GB for r, v in per_route_rates.items()}
    hist: Dict[int, int] = {}
    for f in faults:
        hist[f] = hist.get(f, 0) + 1
    return CampaignReport(
        duration_days=clock.now / DAY,
        floor_days=total / graph.sites[cfg.source].read_bw / DAY,
        total_bytes=total,
        bytes_at={r: _bytes_at(table, r) for r in cfg.replicas},
        per_route_gbps=per_route_gbps,
        per_route_transfers=per_route_n,
        faults_total=int(np.sum(faults)) if faults else 0,
        faults_per_transfer_mean=float(np.mean(faults)) if faults else 0.0,
        faults_per_transfer_max=int(np.max(faults)) if faults else 0,
        fault_histogram=hist,
        timeline=timeline,
        notifications=list(notifier.notifications),
        quarantined=table.count_status(Status.QUARANTINED),
    )


def run_campaign(cfg: CampaignConfig, verbose: bool = False) -> CampaignReport:
    (graph, catalog, clock, pause, transport, table, sched,
     notifier) = build_campaign(cfg)
    timeline: List[Tuple[float, Dict[str, int]]] = []
    fix_at: Dict[str, float] = {}
    while clock.now < cfg.max_days * DAY:
        sched.step(clock.now)
        apply_human_fixes(notifier, fix_at, clock.now, cfg.human_fix_days)
        clock.advance(cfg.step_s)
        transport.tick()
        if int(clock.now) % int(DAY) < cfg.step_s:
            snap = {r: _bytes_at(table, r) for r in cfg.replicas}
            timeline.append((clock.now / DAY, snap))
        if sched.done():
            break
    return aggregate_report(cfg, graph, catalog, clock, table, notifier,
                            timeline)


def _bytes_at(table: TransferTable, replica: str) -> int:
    return table.bytes_at(replica)
