"""Incremental replication (paper C7): after the initial campaign, newly
published datasets are detected daily and replicated to all replicas.

``PublishFeed`` abstracts the index node (here: an in-memory/jsonl feed);
``IncrementalReplicator`` polls it, inserts fresh rows into the transfer
table, and lets the Figure-4 scheduler move them.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from repro.core.routes import Dataset
from repro.core.scheduler import ReplicationScheduler
from repro.core.transfer_table import Status


class PublishFeed:
    """Datasets published over (simulated) time."""

    def __init__(self):
        self._events: List[tuple] = []   # (publish_time, Dataset)

    def publish(self, at: float, ds: Dataset) -> None:
        self._events.append((at, ds))

    def new_since(self, t0: float, t1: float) -> List[Dataset]:
        return [d for (t, d) in self._events if t0 < t <= t1]

    def all_events(self) -> List[tuple]:
        """Every ``(publish_time, Dataset)`` ever published."""
        return list(self._events)

    def count(self) -> int:
        """Number of publications so far — an O(1) growth cursor, so pollers
        can notice new events without copying the feed."""
        return len(self._events)

    def events_since(self, cursor: int) -> List[tuple]:
        """Publications appended at or after position ``cursor``."""
        return self._events[cursor:]


@dataclass
class IncrementalReplicator:
    feed: PublishFeed
    scheduler: ReplicationScheduler
    check_interval: float = 86400.0      # daily (paper §3)

    def __post_init__(self):
        self._last_check = 0.0

    def maybe_check(self, now: float) -> List[str]:
        """Call from the daemon loop; enqueues any newly published datasets."""
        if now - self._last_check < self.check_interval:
            return []
        new = self.feed.new_since(self._last_check, now)
        self._last_check = now
        added = []
        pol = self.scheduler.policy
        for ds in new:
            self.scheduler.catalog[ds.path] = ds
            self.scheduler.table.populate([ds.path], pol.source,
                                          list(pol.replicas))
            added.append(ds.path)
        return added
