"""Replication dashboard (paper Fig. 7): live view of the transfer tables.

Renders a progress table with one row per (campaign, destination) —
complete fraction, bytes, files, faults, live transfer count, aggregate
rate, and ETA — side by side across however many campaigns share the world,
followed (in the detailed view) by the ACTIVE / PAUSED transfers and the
most recent SUCCEEDED ones per destination.  The paper notes such a
dashboard was "relatively easy to create" and valuable for progress
communication and spotting failures; here it is a first-class feature that
covers federated campaigns too.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.transfer_table import Status, TransferRecord, TransferTable

# one campaign's dashboard identity: (label, table, destinations, total bytes)
CampaignEntry = Tuple[str, TransferTable, List[str], int]


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if n < 1024 or unit == "PB":
            return f"{n:.2f} {unit}"
        n /= 1024
    return f"{n:.2f} PB"


def _fmt_rate(bps: float) -> str:
    return _fmt_bytes(bps) + "/s"


def _fmt_eta(days: Optional[float]) -> str:
    if days == 0.0:
        return "done"
    if days is None or days != days or days == float("inf"):
        return "stalled"
    return f"{days:.1f} d"


# ------------------------------------------------------------- progress rows
def progress_rows(campaigns: Sequence[CampaignEntry]) -> List[Dict]:
    """One row per (campaign, destination): landed bytes/files/faults, the
    live transfer count, the current aggregate achieved rate, and the ETA at
    that rate.  This is the side-by-side federation view — pass one entry
    per campaign sharing the world."""
    rows: List[Dict] = []
    for label, table, destinations, total_bytes in campaigns:
        for dst in destinations:
            done = table.by_status(Status.SUCCEEDED, destination=dst)
            live = table.by_status(Status.ACTIVE, Status.QUEUED,
                                   Status.PAUSED, destination=dst)
            # faults count every row's accumulated faults — including rows
            # waiting out a retry backoff or quarantined — so the column is
            # monotonic and ends equal to the report's faults_total
            other = table.by_status(Status.FAILED, Status.QUARANTINED,
                                    destination=dst)
            got = table.bytes_at(dst)
            files = sum(r.files for r in done)
            faults = sum(r.faults for r in done + live + other)
            # a freshly resumed campaign's first tick can report rows with
            # zero elapsed active time: drop non-finite per-row rates so the
            # aggregate (and the ETA below) never goes inf/nan
            rate = sum(r.rate for r in live
                       if r.status == Status.ACTIVE
                       and r.rate == r.rate and r.rate != float("inf"))
            remaining = max(0, total_bytes - got)
            if remaining == 0:
                eta_days = 0.0
            elif rate > 0:
                eta_days = remaining / rate / 86400.0
            else:
                eta_days = None     # stalled: no JSON-hostile inf/nan
            rows.append({
                "campaign": label,
                "destination": dst,
                "complete_fraction": (got / total_bytes
                                      if total_bytes else 0.0),
                "bytes": got,
                "files": files,
                "faults": faults,
                "active": len(live),
                "rate": rate,
                "eta_days": eta_days,
            })
    return rows


def _render_rows(rows: Sequence[Dict], now: float) -> str:
    lines = [f"=== Replication progress @ t={now/86400:.2f} d ===",
             f"{'Campaign':16} {'Dest':6} {'Done':>6} {'Bytes':>10} "
             f"{'Files':>9} {'Faults':>6} {'Live':>4} {'Rate':>12} {'ETA':>8}"]
    for r in rows:
        lines.append(
            f"{r['campaign'][:16]:16} {r['destination']:6} "
            f"{r['complete_fraction']*100:5.1f}% "
            f"{_fmt_bytes(r['bytes']):>10} {r['files']:>9} "
            f"{r['faults']:>6} {r['active']:>4} "
            f"{_fmt_rate(r['rate']):>12} {_fmt_eta(r['eta_days']):>8}")
    return "\n".join(lines)


def render_progress(campaigns: Sequence[CampaignEntry], now: float) -> str:
    """The progress table as text: campaigns/destinations side by side."""
    return _render_rows(progress_rows(campaigns), now)


def render_federation_text(world, now: float) -> str:
    """Progress table for a compiled ``FederationWorld``: one row per
    (member campaign, destination), plus each member's control-plane state
    when one is attached."""
    campaigns = [(rt.label, rt.table, list(rt.cfg.replicas),
                  sum(d.bytes for d in rt.catalog.values()))
                 for rt in world.runtimes]
    lines = [render_progress(campaigns, now)]
    for rt in world.runtimes:
        if rt.control is not None:
            lines.append(render_policy_text(rt.control, now))
        if rt.demand is not None:
            lines.append(render_demand_text(rt.demand, now))
        if rt.scrub is not None:
            lines.append(render_scrub_text(rt.scrub, now))
        if rt.obs is not None:
            lines.append(render_obs_text(rt.obs, now))
    return "\n".join(lines)


# ------------------------------------------------------- control-plane view
def policy_rows(control) -> List[Dict]:
    """The control plane's live state as dashboard rows: current per-route
    concurrency caps, the composer's cut progress and current targets, and
    the most recent ledger decisions."""
    rows: List[Dict] = [{
        "campaign": control.label,
        "kind": "caps",
        "route_caps": {f"{s}->{d}": c
                       for (s, d), c in
                       sorted(control.sched.policy.route_caps.items())},
        "default_cap": control.sched.policy.max_active_per_route,
    }]
    comp = control.composer
    if comp is not None:
        rows.append({
            "campaign": control.label,
            "kind": "composer",
            "bundles_cut": len(comp.bundle_catalog),
            "exhausted": comp.done,
            "target_files": comp.target_files,
            "target_bytes": comp.target_bytes,
        })
    for e in control.ledger.entries[-8:]:
        rows.append(dict(e, campaign=control.label, kind="decision"))
    return rows


def render_policy_text(control, now: float) -> str:
    """The policy view as text: caps line, composer line, recent decisions."""
    lines = [f"--- policy [{control.label}] @ t={now/86400:.2f} d ---"]
    for r in policy_rows(control):
        if r["kind"] == "caps":
            caps = ", ".join(f"{k}:{v}" for k, v in r["route_caps"].items())
            lines.append(f"caps  default={r['default_cap']} "
                         f"{caps or '(all default)'}")
        elif r["kind"] == "composer":
            lines.append(
                f"bundles cut={r['bundles_cut']} "
                f"target={r['target_files']} files/"
                f"{_fmt_bytes(r['target_bytes'])} "
                f"{'EXHAUSTED' if r['exhausted'] else 'composing'}")
        else:
            what = (f"{'->'.join(r['route'])} cap {r['prev_cap']}->{r['cap']}"
                    if "route" in r else
                    f"target {r['target_files']} files/"
                    f"{_fmt_bytes(r['target_bytes'])}")
            lines.append(f"t={r['t_day']:.2f}d {r['controller']:8} {what} "
                         f"({r['gbps']:.3f} GB/s)")
    return "\n".join(lines)


# ------------------------------------------------------- demand-engine view
def demand_rows(demand) -> List[Dict]:
    """The demand engine's serving SLOs as dashboard rows: the hit-rate /
    latency / bytes-served headline, then one cache row per replica site."""
    s = demand.summary()
    rows: List[Dict] = [{
        "campaign": demand.label,
        "kind": "serving",
        "users": s["users"],
        "requests": s["requests"],
        "hit_rate": s["hit_rate"],
        "cache_hit_rate": s["cache_hit_rate"],
        "p50_s": s["p50_s"],
        "p99_s": s["p99_s"],
        "bytes_served_tb": s["bytes_served_tb"],
        "day90": s["day90"],
    }]
    for site, c in s["caches"].items():
        rows.append(dict(c, campaign=demand.label, kind="cache", site=site))
    return rows


def render_demand_text(demand, now: float) -> str:
    """The serving view as text: SLO line, one cache line per replica."""
    lines = [f"--- serving [{demand.label}] @ t={now/86400:.2f} d ---"]
    for r in demand_rows(demand):
        if r["kind"] == "serving":
            day90 = "-" if r["day90"] is None else f"{r['day90']}d"
            lines.append(
                f"users={r['users']:,} requests={r['requests']:,} "
                f"hit={r['hit_rate']*100:.1f}% "
                f"(cache {r['cache_hit_rate']*100:.1f}%) "
                f"p50={r['p50_s']:.3f}s p99={r['p99_s']:.1f}s "
                f"served={r['bytes_served_tb']:.1f} TB day90={day90}")
        else:
            lines.append(
                f"cache {r['site']:6} {r['entries']} entries "
                f"{_fmt_bytes(r['used_bytes'])} hits={r['hits']:,} "
                f"misses={r['misses']:,} evictions={r['evictions']:,}")
    return "\n".join(lines)


# -------------------------------------------------------- scrub-engine view
def scrub_rows(scrub) -> List[Dict]:
    """The scrub engine's integrity state as dashboard rows: one headline
    row — scan progress, detections/repairs, and the data currently at
    risk (landed but carrying undetected or unrepaired corruption)."""
    s = scrub.summary()
    return [{
        "campaign": scrub.label,
        "kind": "integrity",
        "scans": s["scans"],
        "scanned_replicas": s["scanned_replicas"],
        "scanned_bytes": s["scanned_bytes"],
        "detected": s["detected"],
        "repaired": s["repaired"],
        "at_risk_replicas": s["at_risk_replicas"],
        "repairing_replicas": s["repairing_replicas"],
        "data_at_risk_bytes": s["data_at_risk_bytes"],
        "corrupt_files": s["corrupt_files"],
        "corrupt_bytes": s["corrupt_bytes"],
        "exposure_days": s["exposure_days"],
        "clean": s["clean"],
    }]


def render_scrub_text(scrub, now: float) -> str:
    """The integrity view as text: one scrub/repair status line."""
    lines = [f"--- integrity [{scrub.label}] @ t={now/86400:.2f} d ---"]
    for r in scrub_rows(scrub):
        state = "CLEAN" if r["clean"] else (
            f"AT RISK {_fmt_bytes(r['data_at_risk_bytes'])} "
            f"({r['at_risk_replicas']} undetected, "
            f"{r['repairing_replicas']} repairing)")
        lines.append(
            f"scans={r['scans']} scanned={_fmt_bytes(r['scanned_bytes'])} "
            f"detected={r['detected']} repaired={r['repaired']} "
            f"exposure={r['exposure_days']:.2f} replica-days {state}")
    return "\n".join(lines)


# ------------------------------------------------------ flight-recorder view
def obs_rows(obs) -> List[Dict]:
    """The flight recorder's own health as dashboard rows: trace volume and
    ring retention, sample count, and the latest metrics sample headline."""
    rows: List[Dict] = []
    if obs.trace is not None:
        t = obs.trace.summary()
        rows.append(dict(t, campaign=obs.label, kind="trace"))
    if obs.metrics is not None:
        row = {"campaign": obs.label, "kind": "metrics",
               "samples": len(obs.samples)}
        if obs.samples:
            last = obs.samples[-1]
            row["t_day"] = last["t_day"]
            row["queue_depth"] = last["queue_depth"]
            row["backoff_depth"] = last["backoff_depth"]
        rows.append(row)
    return rows


def render_obs_text(obs, now: float) -> str:
    """The flight-recorder view as text: one line per stream."""
    lines = [f"--- obs [{obs.label}] @ t={now/86400:.2f} d ---"]
    for r in obs_rows(obs):
        if r["kind"] == "trace":
            lines.append(
                f"trace events={r['events']:,} retained={r['retained']:,} "
                f"dropped={r['dropped']:,} "
                f"ring={_fmt_bytes(r['ring_bytes'])}/"
                f"{_fmt_bytes(r['budget_bytes'])}")
        else:
            at = (f" last@d{r['t_day']:.2f} queue={r['queue_depth']} "
                  f"backoff={r['backoff_depth']}" if "t_day" in r else "")
            lines.append(f"metrics samples={r['samples']}{at}")
    return "\n".join(lines)


# ----------------------------------------------------------- detailed views
def snapshot(table: TransferTable, destinations: List[str],
             total_bytes: int, now: float, n_recent: int = 4,
             campaign: str = "campaign") -> Dict:
    out: Dict = {"now": now, "destinations": {},
                 "progress": progress_rows(
                     [(campaign, table, destinations, total_bytes)])}
    for dst in destinations:
        live = table.by_status(Status.ACTIVE, Status.PAUSED, destination=dst)
        done = table.by_status(Status.SUCCEEDED, destination=dst)
        done.sort(key=lambda r: r.completed or 0.0, reverse=True)
        got = sum(r.bytes_transferred for r in done)
        out["destinations"][dst] = {
            "complete_fraction": got / total_bytes if total_bytes else 0.0,
            "bytes": got,
            "succeeded": len(done),
            "rows": [_row(r) for r in live + done[:n_recent]],
        }
    return out


def row_dict(r: TransferRecord) -> Dict:
    """One transfer row as a JSON-clean dict — the single builder shared by
    ``snapshot``/``render_json`` and the flight recorder's NDJSON sink.
    Non-finite rates (a resumed row's first tick) become None: the output
    must survive ``json.dumps(allow_nan=False)`` byte-stably."""
    rate = r.rate
    if rate != rate or rate in (float("inf"), float("-inf")):
        rate = None
    return {
        "dataset": r.dataset, "from": r.source, "requested": r.requested,
        "completed": r.completed, "status": r.status.value,
        "directories": r.directories, "files": r.files,
        "bytes_transferred": r.bytes_transferred, "faults": r.faults,
        "rate": rate,
    }


# backwards-compatible alias (the pre-obs private name)
_row = row_dict


def render_text(table: TransferTable, destinations: List[str],
                total_bytes: int, now: float,
                campaign: str = "campaign") -> str:
    snap = snapshot(table, destinations, total_bytes, now, campaign=campaign)
    by_dst = {r["destination"]: r for r in snap["progress"]}
    lines = [_render_rows(snap["progress"], now)]
    for dst, info in snap["destinations"].items():
        prog = by_dst[dst]
        lines.append(f"\nReplication to {dst}  "
                     f"[{info['complete_fraction']*100:5.1f}% — "
                     f"{_fmt_bytes(info['bytes'])} | "
                     f"{info['succeeded']} datasets | "
                     f"ETA {_fmt_eta(prog['eta_days'])}]")
        lines.append(f"{'No':>3} {'Dataset':54} {'From':5} {'Status':12} "
                     f"{'Files':>9} {'Bytes':>10} {'Faults':>6} {'Rate':>12}")
        for i, r in enumerate(info["rows"], 1):
            lines.append(
                f"{i:>3} {r['dataset'][:54]:54} {r['from']:5} "
                f"{r['status']:12} {r['files']:>9} "
                f"{_fmt_bytes(r['bytes_transferred']):>10} {r['faults']:>6} "
                f"{_fmt_rate(r['rate'] or 0.0):>12}")
    return "\n".join(lines)


def render_json(table: TransferTable, destinations: List[str],
                total_bytes: int, now: float) -> str:
    return json.dumps(snapshot(table, destinations, total_bytes, now), indent=2)
