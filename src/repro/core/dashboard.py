"""Replication dashboard (paper Fig. 7): live view of the transfer table.

Renders, per destination, the ACTIVE / PAUSED transfers and the most recent
SUCCEEDED ones, plus campaign totals — as text (terminal) or JSON (for a web
front end).  The paper notes such a dashboard was "relatively easy to create"
and valuable for progress communication and spotting failures; here it is a
first-class feature.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.core.transfer_table import Status, TransferRecord, TransferTable


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if n < 1024 or unit == "PB":
            return f"{n:.2f} {unit}"
        n /= 1024
    return f"{n:.2f} PB"


def _fmt_rate(bps: float) -> str:
    return _fmt_bytes(bps) + "/s"


def snapshot(table: TransferTable, destinations: List[str],
             total_bytes: int, now: float, n_recent: int = 4) -> Dict:
    out: Dict = {"now": now, "destinations": {}}
    for dst in destinations:
        live = table.by_status(Status.ACTIVE, Status.PAUSED, destination=dst)
        done = table.by_status(Status.SUCCEEDED, destination=dst)
        done.sort(key=lambda r: r.completed or 0.0, reverse=True)
        got = sum(r.bytes_transferred for r in done)
        out["destinations"][dst] = {
            "complete_fraction": got / total_bytes if total_bytes else 0.0,
            "bytes": got,
            "succeeded": len(done),
            "rows": [_row(r) for r in live + done[:n_recent]],
        }
    return out


def _row(r: TransferRecord) -> Dict:
    return {
        "dataset": r.dataset, "from": r.source, "requested": r.requested,
        "completed": r.completed, "status": r.status.value,
        "directories": r.directories, "files": r.files,
        "bytes_transferred": r.bytes_transferred, "faults": r.faults,
        "rate": r.rate,
    }


def render_text(table: TransferTable, destinations: List[str],
                total_bytes: int, now: float) -> str:
    snap = snapshot(table, destinations, total_bytes, now)
    lines = [f"=== Replication dashboard @ t={now/86400:.2f} d ==="]
    for dst, info in snap["destinations"].items():
        lines.append(f"\nReplication to {dst}  "
                     f"[{info['complete_fraction']*100:5.1f}% — "
                     f"{_fmt_bytes(info['bytes'])} | "
                     f"{info['succeeded']} datasets]")
        lines.append(f"{'No':>3} {'Dataset':54} {'From':5} {'Status':12} "
                     f"{'Files':>9} {'Bytes':>10} {'Faults':>6} {'Rate':>12}")
        for i, r in enumerate(info["rows"], 1):
            lines.append(
                f"{i:>3} {r['dataset'][:54]:54} {r['from']:5} "
                f"{r['status']:12} {r['files']:>9} "
                f"{_fmt_bytes(r['bytes_transferred']):>10} {r['faults']:>6} "
                f"{_fmt_rate(r['rate']):>12}")
    return "\n".join(lines)


def render_json(table: TransferTable, destinations: List[str],
                total_bytes: int, now: float) -> str:
    return json.dumps(snapshot(table, destinations, total_bytes, now), indent=2)
