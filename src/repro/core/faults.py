"""Fault taxonomy, injection, and retry policy (paper C3 / §5).

The paper recorded 4086 faults over 4582 transfers — all transient ("bad
permissions, system maintenance periods, packet corruption"), none fatal,
because the transfer fabric retried automatically and notified on repeated
failure.  Fault counts were heavily skewed: most transfers fault-free, a few
with hundreds (Fig. 6) — we model that skew with a per-dataset "fragility"
drawn from a heavy-tailed distribution.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.kernels.checksum.ref import checksum_bytes_np

_PB = 1024 ** 5


def stable_digest(text: str) -> int:
    """Process-independent 32-bit digest of a string, via the checksum
    kernel.  Python's ``hash()`` is randomized per process (PYTHONHASHSEED),
    so anything derived from it silently differs between the sweep runner's
    workers and the main process; this is the seedable replacement."""
    return int(checksum_bytes_np(text.encode("utf-8")))


class FaultKind(str, enum.Enum):
    NETWORK = "network"            # packet corruption, connection reset
    FILESYSTEM = "filesystem"      # fs hiccup / metadata timeout
    PERMISSION = "permission"      # unreadable files (persistent until fixed)
    OOM_SCAN = "oom_scan"          # directory scan exhausted memory
    INTEGRITY = "integrity"        # checksum mismatch -> retransmit file


TRANSIENT = (FaultKind.NETWORK, FaultKind.FILESYSTEM, FaultKind.INTEGRITY)


@dataclass
class Fault:
    kind: FaultKind
    at: float                    # sim time
    detail: str = ""


@dataclass
class RetryPolicy:
    max_retries: int = 5         # per transfer, before QUARANTINE + notify
    backoff_s: float = 60.0      # requeue delay after FAILED
    fault_retry_cost_s: float = 30.0  # in-transfer stall per transient fault


class FaultInjector:
    """Seeded, deterministic fault model for the simulated transport."""

    def __init__(self, seed: int = 0,
                 transient_per_tb: float = 0.15,
                 fragility_tail: float = 2.5,
                 persistent_fraction: float = 0.01):
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.transient_per_tb = transient_per_tb
        self.fragility_tail = fragility_tail
        self.persistent_fraction = persistent_fraction
        self._fragility: Dict[str, float] = {}

    def fragility(self, dataset: str) -> float:
        """Heavy-tailed multiplier reproducing Fig. 6's skew (most transfers
        fault-free; a few with dozens-to-hundreds of faults)."""
        if dataset not in self._fragility:
            # Pareto-ish: ~75% of datasets get ~0 faults, the tail gets many
            u = self.rng.random()
            if u < 0.75:
                f = 0.0
            else:
                f = float(self.rng.pareto(self.fragility_tail) + 1.0) * 4.0
            self._fragility[dataset] = f
        return self._fragility[dataset]

    def n_transient_faults(self, dataset: str, nbytes: int) -> int:
        lam = self.transient_per_tb * (nbytes / 1024 ** 4) * self.fragility(dataset)
        return int(self.rng.poisson(lam))

    def transient_marks(self, dataset: str, nbytes: int) -> List[float]:
        """The complete submit-time draw for one transfer: fault count, then
        the sorted byte positions of each transient fault.  This is the ONLY
        way a transfer may consume the shared stream — the scalar transport
        and the ensemble lanes engine both call it, so their per-seed RNG
        consumption is identical by construction.  Draw order (fragility
        memo, Poisson count, uniform positions) is part of the determinism
        contract; reordering it changes every trajectory after the first
        fault."""
        n = self.n_transient_faults(dataset, nbytes)
        if not n:
            return []
        return sorted(float(b) for b in self.rng.uniform(0, nbytes, n))

    def is_persistent_unreadable(self, dataset: str) -> bool:
        # deterministic per (seed, dataset) — and, unlike Python's hash(),
        # identical across processes regardless of PYTHONHASHSEED
        h = stable_digest(f"perm|{self.seed}|{dataset}") % 10_000
        return h < int(self.persistent_fraction * 10_000)

    # --------------------------------------------------------- latent corruption
    def latent_corrupt_offsets(self, dataset: str, destination: str,
                               nbytes: int, rate_per_pb: float,
                               incarnation: int = 1) -> np.ndarray:
        """Silent-corruption draw for one landed replica: sorted byte offsets
        of blocks that arrived intact (the in-flight INTEGRITY retransmit
        already caught transfer corruption) but rot on the destination media
        and are detectable only by a later re-verification scan.

        Pure function of ``(seed, dataset, destination, incarnation)`` —
        independent of ``self.rng``, so evaluating it lazily at scrub time
        perturbs neither the shared transient-fault stream nor any existing
        trajectory.  ``incarnation`` counts SUCCEEDED landings of this
        replica: a repaired (re-transferred) copy is a fresh draw, which is
        what lets a scrub/repair campaign converge to zero corrupt bytes.
        """
        rng = np.random.default_rng(
            [self.seed, stable_digest(dataset), stable_digest(destination),
             int(incarnation)])
        n = int(rng.poisson(rate_per_pb * nbytes / _PB))
        if n == 0:
            return np.empty(0, dtype=np.int64)
        offs = rng.uniform(0.0, float(nbytes), n).astype(np.int64)
        return np.unique(offs)

    # ------------------------------------------------------------ checkpoints
    def state_dict(self) -> dict:
        """JSON-serializable RNG stream position + memoized fragilities, so a
        resumed campaign draws exactly the fault sequence the killed run
        would have drawn."""
        return {"rng": self.rng.bit_generator.state,
                "fragility": dict(self._fragility)}

    def load_state_dict(self, d: dict) -> None:
        self.rng.bit_generator.state = d["rng"]
        self._fragility = {k: float(v) for k, v in d["fragility"].items()}


class Notifier:
    """Paper §5: persistent failures are resolved by notifying a person.
    The hook records notifications; ``fix`` simulates the human fixing it."""

    def __init__(self):
        self.notifications: List[str] = []
        self.fixed: Dict[str, bool] = {}

    def notify(self, msg: str, dataset: str = "") -> None:
        self.notifications.append(msg)
        if dataset:
            self.fixed.setdefault(dataset, False)

    def fix(self, dataset: str) -> None:
        self.fixed[dataset] = True

    def is_fixed(self, dataset: str) -> bool:
        return self.fixed.get(dataset, False)

    # ------------------------------------------------------------ checkpoints
    def state_dict(self) -> dict:
        return {"notifications": list(self.notifications),
                "fixed": dict(self.fixed)}

    def load_state_dict(self, d: dict) -> None:
        self.notifications = list(d["notifications"])
        self.fixed = {k: bool(v) for k, v in d["fixed"].items()}


class FederationNotifier:
    """Routes a shared transport's notifications to the campaign(s) that own
    the dataset, and treats human fixes as global.

    When N campaigns share one ``SimulatedTransport``, a permission failure
    or scan OOM raised by a mover must land in the owning campaign's
    ``Notifier`` (that is where its human-fix clock and report live).  A
    dataset replicated by several campaigns (the paper moved the same 29 M
    files twice) notifies each of them — and once any campaign's admin fixes
    the underlying problem at the source, ``is_fixed`` unblocks every
    campaign's transfers: permissions are repaired once, not per campaign.

    Stateless by design: each member ``Notifier`` checkpoints itself, so this
    router needs no snapshot entry.  With a single member it is a transparent
    pass-through (the bit-identity anchor for 1-element federations).
    """

    def __init__(self):
        self._members: List[tuple] = []      # (catalog dict, Notifier)

    def attach(self, catalog: Dict[str, object], notifier: "Notifier") -> None:
        self._members.append((catalog, notifier))

    def notify(self, msg: str, dataset: str = "") -> None:
        targets = [n for cat, n in self._members
                   if dataset and dataset in cat]
        if not targets:                      # unattributable: tell everyone
            targets = [n for _, n in self._members]
        for n in targets:
            n.notify(msg, dataset)

    def is_fixed(self, dataset: str) -> bool:
        return any(n.is_fixed(dataset) for _, n in self._members)
