"""Site/route model: bandwidths, dataset catalogs, and relay planning.

The paper's key performance insight (C2 in DESIGN.md): the source file system
is the bottleneck (LLNL could source at only ~1.5 GB/s), so read it ONCE per
dataset and relay replica→replica over the faster inter-LCF path (up to
7.5 GB/s), with the two hops overlapping.  ``RouteGraph`` captures per-site
read/write caps and per-route bandwidths (paper Table 3) so both the simulator
and the scheduler can reason about them.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

GB = 1024 ** 3
TB = 1024 ** 4
PB = 1024 ** 5
DAY = 86400.0


def fair_share_rates(route_bw, read_cap, write_cap, n_route, src_load,
                     dst_load, src_knee=None, dst_knee=None, xp=None):
    """Vectorized fair-share allocation — the pure arithmetic core of
    ``RouteGraph.effective_rate``, elementwise over arbitrarily-shaped
    arrays (numpy or jax.numpy via ``xp``) so the ensemble lanes engine can
    price every route of every lane in one shot.

    All inputs broadcast together: per-route bandwidth and the owning
    sites' read/write caps against the route's active count and the site
    loads (``n_route``/``src_load``/``dst_load`` are clamped to ≥ 1 exactly
    as the scalar path's ``max(1, ·)`` / ``or 1`` do).  Contention knees are
    scalars or arrays with ``inf`` (or ``None``) meaning "no knee declared".
    Missing routes are encoded as ``route_bw == 0`` and price to 0.0.  The
    expression tree (divide, multiply, min — no reassociation) is identical
    to the scalar path, so results agree bit-for-bit in float64.
    """
    import numpy as _np
    if xp is None:
        xp = _np
    inf = float("inf")
    sk = inf if src_knee is None else src_knee
    dk = inf if dst_knee is None else dst_knee
    nr = xp.maximum(1, n_route)
    sl = xp.maximum(1, src_load)
    dl = xp.maximum(1, dst_load)
    with _np.errstate(divide="ignore", invalid="ignore"):
        src_cap = xp.where(sl <= sk, read_cap, read_cap * (sk / sl))
        dst_cap = xp.where(dl <= dk, write_cap, write_cap * (dk / dl))
        return xp.minimum(route_bw / nr,
                          xp.minimum(src_cap / sl, dst_cap / dl))


@dataclass
class Dataset:
    """One ESGF path (a directory tree)."""
    path: str
    bytes: int
    files: int
    directories: int
    unreadable: bool = False  # persistent permission fault (paper §4 phase 4)


@dataclass
class Site:
    name: str
    read_bw: float            # aggregate source rate cap (bytes/s)
    write_bw: float           # aggregate sink rate cap (bytes/s)
    scan_files_per_s: float = 50_000.0   # metadata scan throughput
    scan_mem_limit_files: int = 5_000_000  # OOM threshold for one scan (paper §5)
    # DTN contention knee: beyond this many concurrent transfers touching the
    # site, aggregate throughput *degrades* (stream thrashing — the classic
    # GridFTP parallelism curve rises then falls).  None = ideal fair share,
    # exactly the pre-knee model.
    concurrency_knee: Optional[int] = None


@dataclass
class Route:
    source: str
    destination: str
    bandwidth: float          # per-route cap (bytes/s); min with site caps applies


class RouteGraph:
    def __init__(self, sites: Sequence[Site], routes: Sequence[Route]):
        self.sites: Dict[str, Site] = {s.name: s for s in sites}
        self.routes: Dict[Tuple[str, str], Route] = {
            (r.source, r.destination): r for r in routes}

    def route(self, src: str, dst: str) -> Optional[Route]:
        return self.routes.get((src, dst))

    def bandwidth(self, src: str, dst: str) -> float:
        r = self.route(src, dst)
        if r is None:
            return 0.0
        return min(r.bandwidth, self.sites[src].read_bw, self.sites[dst].write_bw)

    @staticmethod
    def _contended(cap: float, load: int, knee: Optional[int]) -> float:
        """A site's aggregate cap under ``load`` concurrent transfers: ideal
        up to the contention knee, degrading as ``knee/load`` beyond it."""
        if knee is None or load <= knee:
            return cap
        return cap * (knee / load)

    def effective_rate(self, src: str, dst: str,
                       active_by_route: Dict[Tuple[str, str], int]) -> float:
        """Fair-share rate for ONE transfer on (src, dst) given concurrent
        transfers: the route cap is shared among its actives, and each site's
        read/write caps are shared among all transfers touching the site
        (degraded past the site's contention knee, when one is declared)."""
        n_route = max(1, active_by_route.get((src, dst), 1))
        src_load = sum(n for (s, _), n in active_by_route.items() if s == src) or 1
        dst_load = sum(n for (_, d), n in active_by_route.items() if d == dst) or 1
        r = self.route(src, dst)
        if r is None:
            return 0.0
        s_src, s_dst = self.sites[src], self.sites[dst]
        # one shared arithmetic with the batched lanes engine (bit-identical)
        return float(fair_share_rates(
            r.bandwidth, s_src.read_bw, s_dst.write_bw,
            n_route, src_load, dst_load,
            s_src.concurrency_knee, s_dst.concurrency_knee))


# --------------------------------------------------------------- paper setup
def paper_route_graph() -> RouteGraph:
    """Three-site graph with paper Table 3 / §1 bandwidths.

    LLNL file system sources ~1.5 GB/s aggregate; with 2 concurrent transfers
    per route that is ~0.65 GB/s each (Table 3).  Inter-LCF single transfers
    reached 2-3.5 GB/s, peak >7.5 GB/s aggregate.
    """
    sites = [
        Site("LLNL", read_bw=1.5 * GB, write_bw=1.5 * GB,
             scan_files_per_s=20_000, scan_mem_limit_files=2_000_000),
        Site("ALCF", read_bw=10 * GB, write_bw=10 * GB),
        Site("OLCF", read_bw=10 * GB, write_bw=10 * GB),
    ]
    routes = [
        Route("LLNL", "ALCF", 2 * 0.648 * GB),
        Route("LLNL", "OLCF", 2 * 0.662 * GB),
        Route("ALCF", "OLCF", 2 * 1.706 * GB),
        Route("OLCF", "ALCF", 2 * 2.352 * GB),
    ]
    return RouteGraph(sites, routes)


def make_catalog(n_datasets: int = 2291, total_bytes: int = int(7.3 * PB),
                 total_files: int = 28_907_532,
                 total_dirs: int = 17_347_671,
                 seed: int = 0) -> List[Dataset]:
    """Synthesize an ESGF-like catalog: n_datasets directory trees whose sizes
    follow a lognormal distribution, normalized to the paper's totals."""
    import numpy as np
    rng = np.random.default_rng(seed)
    w = rng.lognormal(mean=0.0, sigma=1.6, size=n_datasets)
    w = w / w.sum()
    sizes = (w * total_bytes).astype(np.int64)
    files = np.maximum(1, (w * total_files)).astype(np.int64)
    dirs = np.maximum(1, (w * total_dirs)).astype(np.int64)
    names = [_esgf_path(i, rng) for i in range(n_datasets)]
    return [Dataset(names[i], int(sizes[i]), int(files[i]), int(dirs[i]))
            for i in range(n_datasets)]


_INSTITUTIONS = ["MPI-M", "MOHC", "MIROC", "IPSL", "NCAR", "CSIRO", "NOAA-GFDL",
                 "EC-Earth-Consortium", "CNRM-CERFACS", "BCC"]
_EXPERIMENTS = ["historical", "amip", "piControl", "abrupt-4xCO2", "ssp585",
                "ssp245", "esm-hist", "1pctCO2"]


_PATH_CACHE: dict = {}


def _esgf_path(i: int, rng) -> str:
    # pure function of i (rng unused); memoized — every catalog re-derives
    # the same name table
    p = _PATH_CACHE.get(i)
    if p is None:
        inst = _INSTITUTIONS[i % len(_INSTITUTIONS)]
        exp = _EXPERIMENTS[(i // len(_INSTITUTIONS)) % len(_EXPERIMENTS)]
        phase = "CMIP6" if (i % 10) < 9 else "CMIP5"   # ~90% CMIP6 by count
        p = f"/css03_data/{phase}/CMIP/{inst}/model-{i % 97}/{exp}/r{i}i1p1f1"
        _PATH_CACHE[i] = p
    return p


def split_oversized(ds: Dataset, scan_limit_files: int) -> List[Dataset]:
    """Paper §5: scanning an extremely large directory OOM'd a LLNL node; the
    fix was to split into multiple smaller subdirectory transfers."""
    if ds.files <= scan_limit_files:
        return [ds]
    n = math.ceil(ds.files / scan_limit_files)
    out = []
    for j in range(n):
        out.append(Dataset(
            path=f"{ds.path}/part-{j:03d}",
            bytes=ds.bytes // n, files=ds.files // n,
            directories=max(1, ds.directories // n),
            unreadable=ds.unreadable))
    return out
