"""Durable campaign checkpoint/resume (paper §3: "restart and recover from a
variety of transient failures... largely automatically").

The paper's replication tool survived arbitrary process deaths because all
progress lived in a database.  Our ``TransferTable`` is already durable, but
the *driver* carries deterministic state only in memory: the simulation
clock, the fault-RNG stream position, the scheduler's pending/backoff heaps,
the transport's live-mover pool, and the run loop's cursors.  A
``CampaignSnapshot`` serializes all of it, versioned, next to an atomic copy
of the sqlite transfer table — so a campaign killed at ANY iteration resumes
from its last checkpoint and replays a **bit-identical** trajectory (same
iteration count, simulated days, fault sequence, and succeeded-set digest)
to an uninterrupted run.

Checkpoint directory layout (all writes are temp-file + ``os.replace``)::

    <dir>/snapshot-00001234.json   # CampaignSnapshot at iteration 1234
    <dir>/table-00001234.sqlite    # matching TransferTable copy
    <dir>/LATEST                   # name of the newest complete snapshot

``LATEST`` is renamed into place only after both files land, so a crash
mid-checkpoint leaves the previous snapshot authoritative.  Older epochs are
garbage-collected (``Checkpointer.keep``).

Determinism contract: every float round-trips exactly (``json`` emits
shortest-repr doubles), the RNG serializes its bit-generator state, heaps
serialize in heap order, and dicts preserve insertion order — so the resumed
process performs the same arithmetic in the same order as the killed one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import signal
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.transfer_table import Status, TransferTable

# v2: adds the control-plane block (bundle-composer cursor + cut bundles,
# controller internals, live per-route caps, policy ledger) and the
# transport's per-route telemetry counters + per-task setup cursor
# v3: adds the demand block (request-workload RNG + popularity order, read
# caches, wave cursors, serving counters) and the transport's user read load
# v4: adds the scrub block (scan anchor/cursor, per-replica integrity ledger
# with incarnation counts, data-at-risk counters), so a kill mid-scrub
# resumes the scrub/repair campaign digest-identically
#
# The flight recorder (repro.obs) is deliberately NOT snapshotted: observers
# are rebuilt fresh on resume, and snapshot bytes are identical with obs on
# or off — part of the obs bit-identity contract.
SNAPSHOT_VERSION = 4
FEDERATION_SNAPSHOT_VERSION = 4
FEDERATION_KIND = "federation"
SNAPSHOT_PREFIX = "snapshot-"
TABLE_PREFIX = "table-"
LATEST_FILE = "LATEST"


class SnapshotError(RuntimeError):
    """Malformed or inconsistent checkpoint state."""


class SnapshotVersionError(SnapshotError):
    """Snapshot written by an incompatible serialization version."""


class CampaignKilled(RuntimeError):
    """Raised by the run loop after a requested kill (signal or
    ``kill_after``) once a consistent snapshot has been written."""

    def __init__(self, checkpoint_dir: str, iterations: int):
        super().__init__(
            f"campaign killed at iteration {iterations}; resume with "
            f"--resume {checkpoint_dir}")
        self.checkpoint_dir = checkpoint_dir
        self.iterations = iterations


@dataclass
class LoopState:
    """The ``run_world`` loop's own mutable state, checkpointed alongside the
    world and handed back on resume."""
    iterations: int = 0
    fix_at: Dict[str, float] = field(default_factory=dict)
    next_snap_day: float = 1.0
    timeline: List[Tuple[float, Dict[str, int]]] = field(default_factory=list)
    pending_top_ups: Set[str] = field(default_factory=set)
    feed_cursor: int = 0


@dataclass
class FederationLoopState:
    """The federated run loop's mutable state: one ``LoopState`` per member
    runtime plus the shared iteration counter and each member's completion
    time (``None`` while it is still running)."""
    iterations: int = 0
    members: List[LoopState] = field(default_factory=list)
    finished_at: List[Optional[float]] = field(default_factory=list)


@dataclass
class CampaignSnapshot:
    """Versioned, JSON-serializable image of everything that determines the
    rest of a campaign's trajectory (the transfer table itself lives in the
    sibling sqlite file named by ``table_file``)."""
    version: int
    scenario: str                 # registry name used to rebuild the world
    engine: str                   # "events" | "step"
    scale: float
    seed: int
    n_datasets: Optional[int]
    table_file: str
    clock_now: float
    injector: dict                # FaultInjector.state_dict()
    notifier: dict                # Notifier.state_dict()
    scheduler: dict               # ReplicationScheduler.state_dict()
    transport: dict               # SimulatedTransport.state_dict()
    iterations: int
    fix_at: Dict[str, float]
    next_snap_day: float
    timeline: List[Tuple[float, Dict[str, int]]]
    pending_top_ups: List[str]
    feed_cursor: int
    incremental_last_check: float
    admitted_top_ups: List[str]
    control: Optional[dict]       # ControlPlane.state_dict(); None = static
    demand: Optional[dict]        # DemandEngine.state_dict(); None = no users
    scrub: Optional[dict]         # ScrubEngine.state_dict(); None = no rot
    # True when the run forced the static per-dataset baseline (CLI
    # --policy static): resume must re-apply the override instead of
    # rebuilding the registry scenario's declared (possibly adaptive) policy
    policy_static: bool

    # ------------------------------------------------------------- serialize
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def dumps(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignSnapshot":
        version = d.get("version")
        if version != SNAPSHOT_VERSION:
            raise SnapshotVersionError(
                f"snapshot version {version!r} is not supported "
                f"(this build reads version {SNAPSHOT_VERSION}); "
                "re-run the campaign or use the writing build to resume")
        kw = dict(d)
        # canonicalize the JSON list-of-lists back to the in-memory shapes
        kw["timeline"] = [(float(t), {k: int(v) for k, v in b.items()})
                          for t, b in d["timeline"]]
        kw["pending_top_ups"] = list(d["pending_top_ups"])
        kw["admitted_top_ups"] = list(d["admitted_top_ups"])
        names = {f.name for f in dataclasses.fields(cls)}
        extra = set(kw) - names
        if extra:
            raise SnapshotError(f"unknown snapshot fields: {sorted(extra)}")
        missing = names - set(kw)
        if missing:
            raise SnapshotError(f"missing snapshot fields: {sorted(missing)}")
        return cls(**kw)

    @classmethod
    def loads(cls, text: str) -> "CampaignSnapshot":
        return cls.from_dict(json.loads(text))


@dataclass
class FederationSnapshot:
    """Versioned, JSON-serializable image of a federated run: the shared
    substrate's state (clock, fault RNG, transport) once, plus one runtime
    block per member campaign (scheduler queues, notifier, loop cursors, and
    the name of its sibling sqlite table copy).  Discriminated from a
    single-campaign ``CampaignSnapshot`` by ``kind == "federation"``."""
    version: int
    kind: str
    federation: str               # registry name used to rebuild the world
    engine: str                   # "events" | "step"
    scale: float
    seed: int
    n_datasets: Optional[int]
    clock_now: float
    iterations: int
    injector: dict                # FaultInjector.state_dict()
    transport: dict               # SimulatedTransport.state_dict()
    finished_at: List[Optional[float]]
    runtimes: List[dict]          # per-member blocks, member order
    policy_static: bool           # run forced the static per-dataset policy

    # ------------------------------------------------------------- serialize
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def dumps(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "FederationSnapshot":
        if d.get("kind") != FEDERATION_KIND:
            raise SnapshotError(
                f"not a federation snapshot (kind={d.get('kind')!r})")
        version = d.get("version")
        if version != FEDERATION_SNAPSHOT_VERSION:
            raise SnapshotVersionError(
                f"federation snapshot version {version!r} is not supported "
                f"(this build reads version {FEDERATION_SNAPSHOT_VERSION}); "
                "re-run the campaign or use the writing build to resume")
        kw = dict(d)
        kw["finished_at"] = [None if f is None else float(f)
                             for f in d["finished_at"]]
        kw["runtimes"] = [dict(r) for r in d["runtimes"]]
        names = {f.name for f in dataclasses.fields(cls)}
        extra = set(kw) - names
        if extra:
            raise SnapshotError(f"unknown snapshot fields: {sorted(extra)}")
        missing = names - set(kw)
        if missing:
            raise SnapshotError(f"missing snapshot fields: {sorted(missing)}")
        _RUNTIME_KEYS = {"label", "scenario", "start_day", "table_file",
                         "scheduler", "notifier", "fix_at", "next_snap_day",
                         "timeline", "pending_top_ups", "feed_cursor",
                         "incremental_last_check", "admitted_top_ups",
                         "control", "demand", "scrub"}
        for r in kw["runtimes"]:
            if set(r) != _RUNTIME_KEYS:
                raise SnapshotError(
                    f"malformed runtime block for "
                    f"{r.get('label', '?')!r}: fields "
                    f"{sorted(set(r) ^ _RUNTIME_KEYS)} unexpected/missing")
        return cls(**kw)

    @classmethod
    def loads(cls, text: str) -> "FederationSnapshot":
        return cls.from_dict(json.loads(text))


# ---------------------------------------------------------------- capture/apply
def capture_snapshot(world, loop: LoopState, engine: str,
                     table_file: str) -> CampaignSnapshot:
    """Snapshot a ``ScenarioWorld`` at a run-loop boundary.  Read-only: the
    world's trajectory is unchanged whether or not a snapshot was taken."""
    feed_events = (world.incremental.feed.all_events()
                   if world.incremental is not None else [])
    # archive entries matter only while their row still occupies a slot (the
    # scheduler polls each terminal uid exactly once); serializing just those
    # keeps the snapshot O(active transfers), not O(campaign history)
    pollable = {rec.uuid
                for rec in world.table.by_status(Status.ACTIVE, Status.QUEUED,
                                                 Status.PAUSED)
                if rec.uuid is not None}
    return CampaignSnapshot(
        version=SNAPSHOT_VERSION,
        scenario=world.spec.name,
        engine=engine,
        scale=world.scale,
        seed=world.seed,
        n_datasets=world.n_datasets,
        table_file=table_file,
        clock_now=world.clock.now,
        injector=world.transport.injector.state_dict(),
        notifier=world.notifier.state_dict(),
        scheduler=world.sched.state_dict(),
        transport=world.transport.state_dict(archive_uids=pollable),
        iterations=loop.iterations,
        fix_at=dict(loop.fix_at),
        next_snap_day=loop.next_snap_day,
        timeline=[(t, dict(b)) for t, b in loop.timeline],
        pending_top_ups=sorted(loop.pending_top_ups),
        feed_cursor=loop.feed_cursor,
        incremental_last_check=(world.incremental._last_check
                                if world.incremental is not None else 0.0),
        admitted_top_ups=sorted(d.path for _, d in feed_events
                                if d.path in world.catalog),
        control=(world.control.state_dict()
                 if world.control is not None else None),
        demand=(world.demand.state_dict()
                if world.demand is not None else None),
        scrub=(world.scrub.state_dict()
               if world.scrub is not None else None),
        policy_static=not world.spec.policy.enabled,
    )


def apply_snapshot(world, snap: CampaignSnapshot) -> LoopState:
    """Overwrite a freshly built world's mutable state with the snapshot's.
    The world must have been built from the same spec/scale/seed (and over
    the snapshot's restored table).  Returns the loop state to resume with."""
    if snap.scenario != world.spec.name:
        raise SnapshotError(
            f"snapshot is for scenario {snap.scenario!r}, world is "
            f"{world.spec.name!r}")
    if world.incremental is not None:
        by_path = {d.path: d
                   for _, d in world.incremental.feed.all_events()}
        for p in snap.admitted_top_ups:
            world.catalog[p] = by_path[p]   # before live movers re-bind
        world.incremental._last_check = snap.incremental_last_check
    elif snap.admitted_top_ups:
        raise SnapshotError("snapshot has top-ups but the scenario has no "
                            "incremental feed")
    if (snap.control is None) != (world.control is None):
        raise SnapshotError(
            "snapshot and world disagree about the control plane — the "
            "scenario's transfer policy changed since the snapshot was "
            "written")
    if world.control is not None:
        # restore the composer cursor / cut bundles BEFORE re-binding the
        # transport's live movers: movers may reference bundle paths
        world.control.load_state_dict(snap.control)
    if (snap.demand is None) != (world.demand is None):
        raise SnapshotError(
            "snapshot and world disagree about the demand engine — the "
            "scenario's demand spec changed since the snapshot was written")
    world.clock.now = snap.clock_now
    world.transport.injector.load_state_dict(snap.injector)
    world.notifier.load_state_dict(snap.notifier)
    world.sched.load_state_dict(snap.scheduler)
    world.transport.load_state_dict(snap.transport,
                                    world.runtime.binding_catalog())
    if world.demand is not None:
        # after the scheduler: its restored direct heaps already carry the
        # killed run's priorities verbatim, and the replica catalog was
        # rebuilt by table-listener adoption at build time
        world.demand.load_state_dict(snap.demand)
    if (snap.scrub is None) != (world.scrub is None):
        raise SnapshotError(
            "snapshot and world disagree about the scrub engine — the "
            "scenario's scrub spec changed since the snapshot was written")
    if world.scrub is not None:
        # replaces the constructor's table-adoption ledger with the killed
        # run's exact incarnation counts, at-risk/repairing sets, and cursor
        world.scrub.load_state_dict(snap.scrub)
    return LoopState(
        iterations=snap.iterations,
        fix_at=dict(snap.fix_at),
        next_snap_day=snap.next_snap_day,
        timeline=[(t, dict(b)) for t, b in snap.timeline],
        pending_top_ups=set(snap.pending_top_ups),
        feed_cursor=snap.feed_cursor)


# -------------------------------------------------------- federation capture
def _capture_runtime(rt, ls: LoopState, table_file: str) -> dict:
    """One member campaign's snapshot block (the table itself lives in the
    sibling sqlite file named by ``table_file``)."""
    feed_events = (rt.incremental.feed.all_events()
                   if rt.incremental is not None else [])
    return {
        "label": rt.label,
        "scenario": rt.spec.name,
        "start_day": rt.start_day,
        "table_file": table_file,
        "scheduler": rt.sched.state_dict(),
        "notifier": rt.notifier.state_dict(),
        "fix_at": dict(ls.fix_at),
        "next_snap_day": ls.next_snap_day,
        "timeline": [(t, dict(b)) for t, b in ls.timeline],
        "pending_top_ups": sorted(ls.pending_top_ups),
        "feed_cursor": ls.feed_cursor,
        "incremental_last_check": (rt.incremental._last_check
                                   if rt.incremental is not None else 0.0),
        "admitted_top_ups": sorted(d.path for _, d in feed_events
                                   if d.path in rt.catalog),
        "control": (rt.control.state_dict()
                    if rt.control is not None else None),
        "demand": (rt.demand.state_dict()
                   if rt.demand is not None else None),
        "scrub": (rt.scrub.state_dict()
                  if rt.scrub is not None else None),
    }


def capture_federation_snapshot(world, loop: "FederationLoopState",
                                engine: str,
                                table_files: Sequence[str]
                                ) -> FederationSnapshot:
    """Snapshot a ``FederationWorld`` at a run-loop boundary: the shared
    clock/RNG/transport once, one block per member runtime."""
    pollable = set()
    for rt in world.runtimes:
        pollable.update(
            rec.uuid
            for rec in rt.table.by_status(Status.ACTIVE, Status.QUEUED,
                                          Status.PAUSED)
            if rec.uuid is not None)
    return FederationSnapshot(
        version=FEDERATION_SNAPSHOT_VERSION,
        kind=FEDERATION_KIND,
        federation=world.spec.name,
        engine=engine,
        scale=world.scale,
        seed=world.seed,
        n_datasets=world.n_datasets,
        clock_now=world.shared.clock.now,
        iterations=loop.iterations,
        injector=world.shared.transport.injector.state_dict(),
        transport=world.shared.transport.state_dict(archive_uids=pollable),
        finished_at=list(loop.finished_at),
        runtimes=[_capture_runtime(rt, ls, tf)
                  for rt, ls, tf in zip(world.runtimes, loop.members,
                                        table_files)],
        policy_static=(world.spec.policy is not None
                       and not world.spec.policy.enabled),
    )


def _apply_runtime(rt, block: dict) -> LoopState:
    """Overwrite one freshly built member runtime's mutable state with its
    snapshot block; returns the member's loop state."""
    if block["scenario"] != rt.spec.name or block["label"] != rt.label:
        raise SnapshotError(
            f"snapshot member {block['label']!r} ({block['scenario']!r}) "
            f"does not match built runtime {rt.label!r} ({rt.spec.name!r})")
    if rt.incremental is not None:
        by_path = {d.path: d for _, d in rt.incremental.feed.all_events()}
        for p in block["admitted_top_ups"]:
            rt.catalog[p] = by_path[p]   # before live movers re-bind
        rt.incremental._last_check = block["incremental_last_check"]
    elif block["admitted_top_ups"]:
        raise SnapshotError(f"member {rt.label!r} snapshot has top-ups but "
                            "the scenario has no incremental feed")
    if (block["control"] is None) != (rt.control is None):
        raise SnapshotError(
            f"member {rt.label!r}: snapshot and world disagree about the "
            "control plane — the member's transfer policy changed")
    if rt.control is not None:
        rt.control.load_state_dict(block["control"])
    if (block["demand"] is None) != (rt.demand is None):
        raise SnapshotError(
            f"member {rt.label!r}: snapshot and world disagree about the "
            "demand engine — the member's demand spec changed")
    rt.notifier.load_state_dict(block["notifier"])
    rt.sched.load_state_dict(block["scheduler"])
    if rt.demand is not None:
        rt.demand.load_state_dict(block["demand"])
    if (block["scrub"] is None) != (rt.scrub is None):
        raise SnapshotError(
            f"member {rt.label!r}: snapshot and world disagree about the "
            "scrub engine — the member's scrub spec changed")
    if rt.scrub is not None:
        rt.scrub.load_state_dict(block["scrub"])
    return LoopState(
        iterations=0,
        fix_at=dict(block["fix_at"]),
        next_snap_day=block["next_snap_day"],
        timeline=[(float(t), {k: int(v) for k, v in b.items()})
                  for t, b in block["timeline"]],
        pending_top_ups=set(block["pending_top_ups"]),
        feed_cursor=block["feed_cursor"])


def apply_federation_snapshot(world, snap: FederationSnapshot
                              ) -> "FederationLoopState":
    """Overwrite a freshly built ``FederationWorld``'s mutable state with the
    snapshot's.  Returns the loop state to resume with."""
    if snap.federation != world.spec.name:
        raise SnapshotError(
            f"snapshot is for federation {snap.federation!r}, world is "
            f"{world.spec.name!r}")
    if len(snap.runtimes) != len(world.runtimes):
        raise SnapshotError(
            f"snapshot has {len(snap.runtimes)} member runtimes, world has "
            f"{len(world.runtimes)}")
    members = [_apply_runtime(rt, block)
               for rt, block in zip(world.runtimes, snap.runtimes)]
    world.shared.clock.now = snap.clock_now
    world.shared.transport.injector.load_state_dict(snap.injector)
    world.shared.transport.load_state_dict(snap.transport,
                                           world.merged_catalog())
    return FederationLoopState(
        iterations=snap.iterations,
        members=members,
        finished_at=[None if f is None else float(f)
                     for f in snap.finished_at])


# --------------------------------------------------------------------- loading
def _reapply_static_policy(spec, snap):
    """A run launched with the static-policy override (CLI ``--policy
    static``) must resume under that same override — the registry scenario's
    declared policy may be adaptive, and rebuilding with it would leave the
    world with a control plane the snapshot never had.  Idempotent for
    scenarios whose declared policy is already static."""
    if not snap.policy_static or not hasattr(spec, "with_policy"):
        return spec
    from repro.control.policy import STATIC_POLICY
    return spec.with_policy(STATIC_POLICY)


def load_snapshot(ckpt_dir: str):
    """The newest complete snapshot in ``ckpt_dir`` (via ``LATEST``): a
    ``CampaignSnapshot`` or, for federated runs, a ``FederationSnapshot``
    (discriminated by the JSON ``kind`` field)."""
    latest = os.path.join(ckpt_dir, LATEST_FILE)
    if not os.path.exists(latest):
        raise SnapshotError(f"no {LATEST_FILE} in {ckpt_dir!r} — not a "
                            "checkpoint directory, or no snapshot completed")
    with open(latest) as f:
        name = f.read().strip()
    with open(os.path.join(ckpt_dir, name)) as f:
        d = json.loads(f.read())
    if d.get("kind") == FEDERATION_KIND:
        return FederationSnapshot.from_dict(d)
    return CampaignSnapshot.from_dict(d)


def resume_world(ckpt_dir: str, spec=None):
    """Rebuild a runnable world from the newest snapshot in ``ckpt_dir``.

    Returns ``(world, snapshot, loop_state)``; continue with
    ``run_world(world, engine=snapshot.engine, resume=loop_state)``.  The
    checkpoint files are read, never mutated — resume as many times as you
    like.  ``spec`` overrides registry lookup (tests with ad-hoc specs).
    Federation snapshots rebuild a ``FederationWorld`` over every member's
    restored table.
    """
    snap = load_snapshot(ckpt_dir)
    if isinstance(snap, FederationSnapshot):
        if spec is None:
            from repro.scenarios.registry import get_scenario
            spec = get_scenario(snap.federation)
        spec = _reapply_static_policy(spec, snap)
        tables = [TransferTable.load(os.path.join(ckpt_dir, r["table_file"]))
                  for r in snap.runtimes]
        world = spec.build(scale=snap.scale, seed=snap.seed,
                           n_datasets=snap.n_datasets, tables=tables)
        loop = apply_federation_snapshot(world, snap)
        return world, snap, loop
    if spec is None:
        from repro.scenarios.registry import get_scenario
        spec = get_scenario(snap.scenario)
    spec = _reapply_static_policy(spec, snap)
    table = TransferTable.load(os.path.join(ckpt_dir, snap.table_file))
    world = spec.build(scale=snap.scale, seed=snap.seed,
                       n_datasets=snap.n_datasets, table=table)
    loop = apply_snapshot(world, snap)
    return world, snap, loop


# ----------------------------------------------------------------- checkpointer
def _atomic_write_text(path: str, text: str) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class Checkpointer:
    """Writes snapshots at run-loop boundaries: every ``every`` iterations,
    and unconditionally when a kill was requested (``kill_after`` iteration
    budget, or a SIGTERM/SIGINT routed through ``install_signal_handlers`` /
    ``request_kill``) — after which ``CampaignKilled`` is raised so the
    process can exit knowing a consistent checkpoint exists."""

    def __init__(self, directory: str, every: int = 0,
                 kill_after: Optional[int] = None, keep: int = 2):
        self.directory = directory
        self.every = int(every)
        self.kill_after = kill_after
        self.keep = max(1, int(keep))
        self._anchor: Optional[int] = None  # iterations at last write/run start
        self._kill = False
        # telemetry (benchmarks/campaign_replay.py --checkpoint-bench)
        self.writes = 0
        self.write_s = 0.0
        self.last_bytes = 0

    # ------------------------------------------------------------------ kills
    def request_kill(self) -> None:
        self._kill = True

    def _on_signal(self, signum, frame) -> None:  # pragma: no cover - trivial
        self._kill = True

    def install_signal_handlers(
            self, signums: Sequence[int] = (signal.SIGTERM, signal.SIGINT)
    ) -> None:
        """Route termination signals into a checkpoint-then-exit at the next
        loop boundary (main thread only, as the signal module requires)."""
        for s in signums:
            signal.signal(s, self._on_signal)

    # --------------------------------------------------------------- boundary
    def on_boundary(self, world, loop: LoopState, engine: str) -> None:
        """Called by ``run_world`` at the top of every iteration (state is
        consistent there: ``loop.iterations`` iterations fully applied)."""
        it = loop.iterations
        if self._anchor is None:
            self._anchor = it           # cadence counts from run/resume start
        kill = self._kill or (self.kill_after is not None
                              and it >= self.kill_after)
        if kill or (self.every > 0 and it - self._anchor >= self.every):
            self.write(world, loop, engine)
        if kill:
            raise CampaignKilled(self.directory, it)

    def write(self, world, loop, engine: str) -> str:
        """One atomic checkpoint epoch; returns the snapshot filename.
        Accepts a single-campaign world (``LoopState``) or a federation
        (``FederationLoopState``); a federation epoch dumps one sqlite table
        copy per member runtime next to one shared snapshot."""
        t0 = time.time()
        os.makedirs(self.directory, exist_ok=True)
        it = loop.iterations
        if hasattr(world, "runtimes"):      # federation
            table_files = []
            for i, rt in enumerate(world.runtimes):
                tf = f"{TABLE_PREFIX}{it:08d}-m{i}.sqlite"
                rt.table.dump(os.path.join(self.directory, tf))
                table_files.append(tf)
            snap = capture_federation_snapshot(world, loop, engine,
                                               table_files)
        else:
            table_files = [f"{TABLE_PREFIX}{it:08d}.sqlite"]
            world.table.dump(os.path.join(self.directory, table_files[0]))
            snap = capture_snapshot(world, loop, engine, table_files[0])
        text = snap.dumps()
        snap_file = f"{SNAPSHOT_PREFIX}{it:08d}.json"
        _atomic_write_text(os.path.join(self.directory, snap_file), text)
        # LATEST lands last: a crash before this line leaves the previous
        # epoch authoritative and this one orphaned (GC'd next time)
        _atomic_write_text(os.path.join(self.directory, LATEST_FILE),
                           snap_file + "\n")
        self._anchor = it
        self._gc()
        self.writes += 1
        self.write_s += time.time() - t0
        self.last_bytes = len(text) + sum(
            os.path.getsize(os.path.join(self.directory, tf))
            for tf in table_files)
        return snap_file

    def _gc(self) -> None:
        """Drop all but the newest ``keep`` complete epochs (every table
        copy of an epoch shares the snapshot's iteration stem)."""
        entries = os.listdir(self.directory)
        snaps = sorted(f for f in entries
                       if f.startswith(SNAPSHOT_PREFIX) and f.endswith(".json"))
        for old in snaps[:-self.keep]:
            stem = old[len(SNAPSHOT_PREFIX):-len(".json")]
            victims = [old] + [f for f in entries
                               if f.startswith(f"{TABLE_PREFIX}{stem}")]
            for victim in victims:
                try:
                    os.remove(os.path.join(self.directory, victim))
                except OSError:  # pragma: no cover - best-effort cleanup
                    pass


# --------------------------------------------------------------- trajectory id
def succeeded_digest(table: TransferTable) -> str:
    """Order-independent digest of the succeeded set: every SUCCEEDED row's
    identity and outcome columns, hashed in canonical (dataset, destination)
    order.  Two campaigns with the same digest moved the same datasets over
    the same final routes with the same fault/retry/byte outcomes."""
    h = hashlib.sha256()
    for rec in table.all():                       # sorted by (dataset, dest)
        if rec.status is not Status.SUCCEEDED:
            continue
        h.update((f"{rec.dataset}|{rec.destination}|{rec.source}|"
                  f"{rec.faults}|{rec.retries}|{rec.bytes_transferred}|"
                  f"{rec.rate!r}\n").encode())
    return h.hexdigest()


def replica_set_digest(table: TransferTable) -> str:
    """Order-independent digest of WHICH replicas exist: every SUCCEEDED
    (dataset, destination) pair, nothing else.  Scrub repairs re-transfer
    replicas — changing retries, rates, and possibly the final source — so
    the scrub acceptance invariant ("a completed scrub/repair campaign ends
    in the corruption-free run's end state") compares this digest, not
    ``succeeded_digest``."""
    h = hashlib.sha256()
    for rec in table.all():                       # sorted by (dataset, dest)
        if rec.status is Status.SUCCEEDED:
            h.update(f"{rec.dataset}|{rec.destination}\n".encode())
    return h.hexdigest()


def trajectory_summary(report, stats, table: TransferTable) -> dict:
    """The bit-identity acceptance tuple: a resumed campaign must reproduce
    this dict *exactly* (float equality included) vs an uninterrupted run."""
    return {
        "iterations": stats.iterations,
        "sim_days": report.duration_days,
        "faults_total": report.faults_total,
        "quarantined": report.quarantined,
        "bytes_at": {k: int(v) for k, v in report.bytes_at.items()},
        "succeeded_digest": succeeded_digest(table),
    }


def federation_trajectory_summary(report, stats, world) -> dict:
    """The federated bit-identity tuple: shared iteration count and span plus
    every member campaign's own trajectory summary (digest included)."""
    return {
        "iterations": stats.iterations,
        "span_days": report.span_days,
        "members": {
            rt.label: {
                "sim_days": report.members[rt.label].duration_days,
                "faults_total": report.members[rt.label].faults_total,
                "quarantined": report.members[rt.label].quarantined,
                "bytes_at": {k: int(v) for k, v in
                             report.members[rt.label].bytes_at.items()},
                "succeeded_digest": succeeded_digest(rt.table),
            }
            for rt in world.runtimes
        },
    }
