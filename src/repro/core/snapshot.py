"""Durable campaign checkpoint/resume (paper §3: "restart and recover from a
variety of transient failures... largely automatically").

The paper's replication tool survived arbitrary process deaths because all
progress lived in a database.  Our ``TransferTable`` is already durable, but
the *driver* carries deterministic state only in memory: the simulation
clock, the fault-RNG stream position, the scheduler's pending/backoff heaps,
the transport's live-mover pool, and the run loop's cursors.  A
``CampaignSnapshot`` serializes all of it, versioned, next to an atomic copy
of the sqlite transfer table — so a campaign killed at ANY iteration resumes
from its last checkpoint and replays a **bit-identical** trajectory (same
iteration count, simulated days, fault sequence, and succeeded-set digest)
to an uninterrupted run.

Checkpoint directory layout (all writes are temp-file + ``os.replace``)::

    <dir>/snapshot-00001234.json   # CampaignSnapshot at iteration 1234
    <dir>/table-00001234.sqlite    # matching TransferTable copy
    <dir>/LATEST                   # name of the newest complete snapshot

``LATEST`` is renamed into place only after both files land, so a crash
mid-checkpoint leaves the previous snapshot authoritative.  Older epochs are
garbage-collected (``Checkpointer.keep``).

Determinism contract: every float round-trips exactly (``json`` emits
shortest-repr doubles), the RNG serializes its bit-generator state, heaps
serialize in heap order, and dicts preserve insertion order — so the resumed
process performs the same arithmetic in the same order as the killed one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import signal
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.transfer_table import Status, TransferTable

SNAPSHOT_VERSION = 1
SNAPSHOT_PREFIX = "snapshot-"
TABLE_PREFIX = "table-"
LATEST_FILE = "LATEST"


class SnapshotError(RuntimeError):
    """Malformed or inconsistent checkpoint state."""


class SnapshotVersionError(SnapshotError):
    """Snapshot written by an incompatible serialization version."""


class CampaignKilled(RuntimeError):
    """Raised by the run loop after a requested kill (signal or
    ``kill_after``) once a consistent snapshot has been written."""

    def __init__(self, checkpoint_dir: str, iterations: int):
        super().__init__(
            f"campaign killed at iteration {iterations}; resume with "
            f"--resume {checkpoint_dir}")
        self.checkpoint_dir = checkpoint_dir
        self.iterations = iterations


@dataclass
class LoopState:
    """The ``run_world`` loop's own mutable state, checkpointed alongside the
    world and handed back on resume."""
    iterations: int = 0
    fix_at: Dict[str, float] = field(default_factory=dict)
    next_snap_day: float = 1.0
    timeline: List[Tuple[float, Dict[str, int]]] = field(default_factory=list)
    pending_top_ups: Set[str] = field(default_factory=set)
    feed_cursor: int = 0


@dataclass
class CampaignSnapshot:
    """Versioned, JSON-serializable image of everything that determines the
    rest of a campaign's trajectory (the transfer table itself lives in the
    sibling sqlite file named by ``table_file``)."""
    version: int
    scenario: str                 # registry name used to rebuild the world
    engine: str                   # "events" | "step"
    scale: float
    seed: int
    n_datasets: Optional[int]
    table_file: str
    clock_now: float
    injector: dict                # FaultInjector.state_dict()
    notifier: dict                # Notifier.state_dict()
    scheduler: dict               # ReplicationScheduler.state_dict()
    transport: dict               # SimulatedTransport.state_dict()
    iterations: int
    fix_at: Dict[str, float]
    next_snap_day: float
    timeline: List[Tuple[float, Dict[str, int]]]
    pending_top_ups: List[str]
    feed_cursor: int
    incremental_last_check: float
    admitted_top_ups: List[str]

    # ------------------------------------------------------------- serialize
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def dumps(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignSnapshot":
        version = d.get("version")
        if version != SNAPSHOT_VERSION:
            raise SnapshotVersionError(
                f"snapshot version {version!r} is not supported "
                f"(this build reads version {SNAPSHOT_VERSION}); "
                "re-run the campaign or use the writing build to resume")
        kw = dict(d)
        # canonicalize the JSON list-of-lists back to the in-memory shapes
        kw["timeline"] = [(float(t), {k: int(v) for k, v in b.items()})
                          for t, b in d["timeline"]]
        kw["pending_top_ups"] = list(d["pending_top_ups"])
        kw["admitted_top_ups"] = list(d["admitted_top_ups"])
        names = {f.name for f in dataclasses.fields(cls)}
        extra = set(kw) - names
        if extra:
            raise SnapshotError(f"unknown snapshot fields: {sorted(extra)}")
        missing = names - set(kw)
        if missing:
            raise SnapshotError(f"missing snapshot fields: {sorted(missing)}")
        return cls(**kw)

    @classmethod
    def loads(cls, text: str) -> "CampaignSnapshot":
        return cls.from_dict(json.loads(text))


# ---------------------------------------------------------------- capture/apply
def capture_snapshot(world, loop: LoopState, engine: str,
                     table_file: str) -> CampaignSnapshot:
    """Snapshot a ``ScenarioWorld`` at a run-loop boundary.  Read-only: the
    world's trajectory is unchanged whether or not a snapshot was taken."""
    feed_events = (world.incremental.feed.all_events()
                   if world.incremental is not None else [])
    # archive entries matter only while their row still occupies a slot (the
    # scheduler polls each terminal uid exactly once); serializing just those
    # keeps the snapshot O(active transfers), not O(campaign history)
    pollable = {rec.uuid
                for rec in world.table.by_status(Status.ACTIVE, Status.QUEUED,
                                                 Status.PAUSED)
                if rec.uuid is not None}
    return CampaignSnapshot(
        version=SNAPSHOT_VERSION,
        scenario=world.spec.name,
        engine=engine,
        scale=world.scale,
        seed=world.seed,
        n_datasets=world.n_datasets,
        table_file=table_file,
        clock_now=world.clock.now,
        injector=world.transport.injector.state_dict(),
        notifier=world.notifier.state_dict(),
        scheduler=world.sched.state_dict(),
        transport=world.transport.state_dict(archive_uids=pollable),
        iterations=loop.iterations,
        fix_at=dict(loop.fix_at),
        next_snap_day=loop.next_snap_day,
        timeline=[(t, dict(b)) for t, b in loop.timeline],
        pending_top_ups=sorted(loop.pending_top_ups),
        feed_cursor=loop.feed_cursor,
        incremental_last_check=(world.incremental._last_check
                                if world.incremental is not None else 0.0),
        admitted_top_ups=sorted(d.path for _, d in feed_events
                                if d.path in world.catalog),
    )


def apply_snapshot(world, snap: CampaignSnapshot) -> LoopState:
    """Overwrite a freshly built world's mutable state with the snapshot's.
    The world must have been built from the same spec/scale/seed (and over
    the snapshot's restored table).  Returns the loop state to resume with."""
    if snap.scenario != world.spec.name:
        raise SnapshotError(
            f"snapshot is for scenario {snap.scenario!r}, world is "
            f"{world.spec.name!r}")
    if world.incremental is not None:
        by_path = {d.path: d
                   for _, d in world.incremental.feed.all_events()}
        for p in snap.admitted_top_ups:
            world.catalog[p] = by_path[p]   # before live movers re-bind
        world.incremental._last_check = snap.incremental_last_check
    elif snap.admitted_top_ups:
        raise SnapshotError("snapshot has top-ups but the scenario has no "
                            "incremental feed")
    world.clock.now = snap.clock_now
    world.transport.injector.load_state_dict(snap.injector)
    world.notifier.load_state_dict(snap.notifier)
    world.sched.load_state_dict(snap.scheduler)
    world.transport.load_state_dict(snap.transport, world.catalog)
    return LoopState(
        iterations=snap.iterations,
        fix_at=dict(snap.fix_at),
        next_snap_day=snap.next_snap_day,
        timeline=[(t, dict(b)) for t, b in snap.timeline],
        pending_top_ups=set(snap.pending_top_ups),
        feed_cursor=snap.feed_cursor)


# --------------------------------------------------------------------- loading
def load_snapshot(ckpt_dir: str) -> CampaignSnapshot:
    """The newest complete snapshot in ``ckpt_dir`` (via ``LATEST``)."""
    latest = os.path.join(ckpt_dir, LATEST_FILE)
    if not os.path.exists(latest):
        raise SnapshotError(f"no {LATEST_FILE} in {ckpt_dir!r} — not a "
                            "checkpoint directory, or no snapshot completed")
    with open(latest) as f:
        name = f.read().strip()
    with open(os.path.join(ckpt_dir, name)) as f:
        return CampaignSnapshot.loads(f.read())


def resume_world(ckpt_dir: str, spec=None):
    """Rebuild a runnable world from the newest snapshot in ``ckpt_dir``.

    Returns ``(world, snapshot, loop_state)``; continue with
    ``run_world(world, engine=snapshot.engine, resume=loop_state)``.  The
    checkpoint files are read, never mutated — resume as many times as you
    like.  ``spec`` overrides registry lookup (tests with ad-hoc specs).
    """
    snap = load_snapshot(ckpt_dir)
    if spec is None:
        from repro.scenarios.registry import get_scenario
        spec = get_scenario(snap.scenario)
    table = TransferTable.load(os.path.join(ckpt_dir, snap.table_file))
    world = spec.build(scale=snap.scale, seed=snap.seed,
                       n_datasets=snap.n_datasets, table=table)
    loop = apply_snapshot(world, snap)
    return world, snap, loop


# ----------------------------------------------------------------- checkpointer
def _atomic_write_text(path: str, text: str) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class Checkpointer:
    """Writes snapshots at run-loop boundaries: every ``every`` iterations,
    and unconditionally when a kill was requested (``kill_after`` iteration
    budget, or a SIGTERM/SIGINT routed through ``install_signal_handlers`` /
    ``request_kill``) — after which ``CampaignKilled`` is raised so the
    process can exit knowing a consistent checkpoint exists."""

    def __init__(self, directory: str, every: int = 0,
                 kill_after: Optional[int] = None, keep: int = 2):
        self.directory = directory
        self.every = int(every)
        self.kill_after = kill_after
        self.keep = max(1, int(keep))
        self._anchor: Optional[int] = None  # iterations at last write/run start
        self._kill = False
        # telemetry (benchmarks/campaign_replay.py --checkpoint-bench)
        self.writes = 0
        self.write_s = 0.0
        self.last_bytes = 0

    # ------------------------------------------------------------------ kills
    def request_kill(self) -> None:
        self._kill = True

    def _on_signal(self, signum, frame) -> None:  # pragma: no cover - trivial
        self._kill = True

    def install_signal_handlers(
            self, signums: Sequence[int] = (signal.SIGTERM, signal.SIGINT)
    ) -> None:
        """Route termination signals into a checkpoint-then-exit at the next
        loop boundary (main thread only, as the signal module requires)."""
        for s in signums:
            signal.signal(s, self._on_signal)

    # --------------------------------------------------------------- boundary
    def on_boundary(self, world, loop: LoopState, engine: str) -> None:
        """Called by ``run_world`` at the top of every iteration (state is
        consistent there: ``loop.iterations`` iterations fully applied)."""
        it = loop.iterations
        if self._anchor is None:
            self._anchor = it           # cadence counts from run/resume start
        kill = self._kill or (self.kill_after is not None
                              and it >= self.kill_after)
        if kill or (self.every > 0 and it - self._anchor >= self.every):
            self.write(world, loop, engine)
        if kill:
            raise CampaignKilled(self.directory, it)

    def write(self, world, loop: LoopState, engine: str) -> str:
        """One atomic checkpoint epoch; returns the snapshot filename."""
        t0 = time.time()
        os.makedirs(self.directory, exist_ok=True)
        it = loop.iterations
        table_file = f"{TABLE_PREFIX}{it:08d}.sqlite"
        world.table.dump(os.path.join(self.directory, table_file))
        snap = capture_snapshot(world, loop, engine, table_file)
        text = snap.dumps()
        snap_file = f"{SNAPSHOT_PREFIX}{it:08d}.json"
        _atomic_write_text(os.path.join(self.directory, snap_file), text)
        # LATEST lands last: a crash before this line leaves the previous
        # epoch authoritative and this one orphaned (GC'd next time)
        _atomic_write_text(os.path.join(self.directory, LATEST_FILE),
                           snap_file + "\n")
        self._anchor = it
        self._gc()
        self.writes += 1
        self.write_s += time.time() - t0
        self.last_bytes = (
            len(text)
            + os.path.getsize(os.path.join(self.directory, table_file)))
        return snap_file

    def _gc(self) -> None:
        """Drop all but the newest ``keep`` complete epochs."""
        snaps = sorted(f for f in os.listdir(self.directory)
                       if f.startswith(SNAPSHOT_PREFIX) and f.endswith(".json"))
        for old in snaps[:-self.keep]:
            stem = old[len(SNAPSHOT_PREFIX):-len(".json")]
            for victim in (old, f"{TABLE_PREFIX}{stem}.sqlite"):
                try:
                    os.remove(os.path.join(self.directory, victim))
                except OSError:  # pragma: no cover - best-effort cleanup
                    pass


# --------------------------------------------------------------- trajectory id
def succeeded_digest(table: TransferTable) -> str:
    """Order-independent digest of the succeeded set: every SUCCEEDED row's
    identity and outcome columns, hashed in canonical (dataset, destination)
    order.  Two campaigns with the same digest moved the same datasets over
    the same final routes with the same fault/retry/byte outcomes."""
    h = hashlib.sha256()
    for rec in table.all():                       # sorted by (dataset, dest)
        if rec.status is not Status.SUCCEEDED:
            continue
        h.update((f"{rec.dataset}|{rec.destination}|{rec.source}|"
                  f"{rec.faults}|{rec.retries}|{rec.bytes_transferred}|"
                  f"{rec.rate!r}\n").encode())
    return h.hexdigest()


def trajectory_summary(report, stats, table: TransferTable) -> dict:
    """The bit-identity acceptance tuple: a resumed campaign must reproduce
    this dict *exactly* (float equality included) vs an uninterrupted run."""
    return {
        "iterations": stats.iterations,
        "sim_days": report.duration_days,
        "faults_total": report.faults_total,
        "quarantined": report.quarantined,
        "bytes_at": {k: int(v) for k, v in report.bytes_at.items()},
        "succeeded_digest": succeeded_digest(table),
    }
