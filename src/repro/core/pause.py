"""Maintenance windows -> PAUSED transfers (paper C4).

ALCF pauses active transfers involving its endpoints before maintenance so
they do not fail; the replication tool detects PAUSED and re-routes.  We model
per-site maintenance calendars in simulated time, including ALCF's weekly
extended window and occasional unplanned outages.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

DAY = 86400.0


@dataclass
class MaintenanceWindow:
    start: float
    end: float
    planned: bool = True


class PauseManager:
    def __init__(self):
        self._windows: Dict[str, List[MaintenanceWindow]] = {}

    def add_window(self, site: str, start: float, end: float,
                   planned: bool = True) -> None:
        self._windows.setdefault(site, []).append(
            MaintenanceWindow(start, end, planned))

    def add_weekly(self, site: str, first_start: float, duration: float,
                   until: float, planned: bool = True) -> None:
        t = first_start
        while t < until:
            self.add_window(site, t, min(t + duration, until), planned)
            t += 7 * DAY

    def paused(self, site: str, now: float) -> bool:
        return any(w.start <= now < w.end for w in self._windows.get(site, ()))

    def next_change(self, now: float) -> float:
        """Next time any window opens or closes (all sites)."""
        return min((self.next_boundary(s, now) for s in self._windows),
                   default=float("inf"))

    def next_boundary(self, site: str, now: float) -> float:
        """Next time ``site``'s paused/unpaused state can flip: the start of a
        future window or the end of one containing ``now``.  ``inf`` when the
        site has no boundary after ``now``."""
        ts = [t for w in self._windows.get(site, ())
              for t in (w.start, w.end) if t > now]
        return min(ts) if ts else float("inf")

    def windows(self, site: str) -> List[MaintenanceWindow]:
        return list(self._windows.get(site, ()))
