"""In-mesh relay collectives — the paper's routing insight as TPU collectives.

The campaign's key trick was *relay routing*: read the slow source once, then
forward replica→replica over fast links, with the hops overlapping
(LLNL→ALCF concurrent with ALCF→OLCF).  On a TPU mesh the same pattern is a
**pipelined chain broadcast** along an axis: chunk k moves hop i→i+1 while
chunk k−1 moves hop i+1→i+2.  For P pods and n chunks the wall-clock is
``bytes/BW * (1 + (P-2)/n)`` vs ``(P-1) * bytes/BW`` for a naive source
fan-out over the same links.

Used for: cross-pod parameter broadcast on elastic join / restart-from-
checkpoint, and staged dataset fan-out.  All functions are shard_map-friendly
(they use ``jax.lax`` collectives with a named axis).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _chain_perm(axis_size: int):
    return [(i, i + 1) for i in range(axis_size - 1)]


def relay_broadcast_inner(x: jnp.ndarray, axis_name: str, axis_size: int,
                          src: int = 0, n_chunks: int = 4) -> jnp.ndarray:
    """Inside shard_map: broadcast ``x`` (present on the ``src`` slice) to all
    slices along ``axis_name`` via a pipelined chunked relay chain.

    Every slice returns the full ``x``.  Lowers to ``(P-1) * n_chunks``
    independent collective-permutes, which the TPU scheduler overlaps — the
    in-mesh analogue of LLNL→ALCF→OLCF with concurrent hops.
    """
    if axis_size == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    lead = x.shape[0]
    n_chunks = min(n_chunks, lead) or 1
    assert lead % n_chunks == 0, (lead, n_chunks)
    chunks = jnp.split(x, n_chunks, axis=0)
    out = []
    perm = _chain_perm(axis_size)
    for ch in chunks:
        # own the value only at the source slice
        y = jnp.where(idx == src, ch, jnp.zeros_like(ch))
        for hop in range(axis_size - 1):
            p = jax.lax.ppermute(y, axis_name, perm)
            # receive exactly once, at your distance from src
            y = jnp.where(idx == src + hop + 1, p, y)
        out.append(y)
    return jnp.concatenate(out, axis=0)


def relay_broadcast(x: jax.Array, mesh: Mesh, axis: str = "pod",
                    src: int = 0, n_chunks: int = 4) -> jax.Array:
    """Host-level wrapper: broadcast a replicated-elsewhere array so that all
    ``axis`` slices hold the ``src`` slice's value."""
    other = tuple(a for a in mesh.axis_names if a != axis)
    spec_in = P()   # replicated input per-slice (value differs across axis)
    from repro.compat import shard_map
    fn = shard_map(
        functools.partial(relay_broadcast_inner, axis_name=axis,
                          axis_size=mesh.shape[axis], src=src,
                          n_chunks=n_chunks),
        mesh=mesh, in_specs=(P(axis),), out_specs=P(axis),
        check=False)
    # reshape: treat axis as a leading stacked dim
    stacked = x  # (P * chunk, ...) layout: caller passes axis-stacked array
    return fn(stacked)


def naive_broadcast_inner(x: jnp.ndarray, axis_name: str, axis_size: int,
                          src: int = 0) -> jnp.ndarray:
    """Source fans out to every destination directly (the 2×58-day plan the
    paper rejected): P-1 full-size sends all leaving the same source's egress
    link, expressed as P-1 separate permutes (ppermute requires unique
    sources, which is exactly the point — one sender serializes)."""
    if axis_size == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    y = jnp.where(idx == src, x, jnp.zeros_like(x))
    for d in range(axis_size):
        if d == src:
            continue
        p = jax.lax.ppermute(y, axis_name, [(src, d)])
        y = jnp.where(idx == d, p, y)
    return y


def ring_all_gather_inner(x: jnp.ndarray, axis_name: str, axis_size: int
                          ) -> jnp.ndarray:
    """Bandwidth-optimal ring all-gather via ppermute (building block for
    overlap-friendly FSDP prefetch; each step moves 1/P of the result)."""
    if axis_size == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    ring = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    pieces = [x]
    cur = x
    for _ in range(axis_size - 1):
        cur = jax.lax.ppermute(cur, axis_name, ring)
        pieces.append(cur)
    # piece j held locally is the shard of device (idx - j) mod P; roll into
    # canonical order with a gather-free select over static offsets
    stacked = jnp.stack(pieces)                       # (P, ...) by age
    order = jnp.mod(idx - jnp.arange(axis_size), axis_size)
    canonical = jnp.zeros_like(stacked)
    canonical = canonical.at[order].set(stacked)
    return canonical.reshape((-1,) + x.shape[1:])


def estimate_relay_time(total_bytes: float, link_bw: float, p: int,
                        n_chunks: int) -> float:
    """Analytic pipeline model (per-link serialization)."""
    if p <= 1:
        return 0.0
    chunk = total_bytes / n_chunks
    return (n_chunks + p - 2) * chunk / link_bw


def estimate_naive_time(total_bytes: float, link_bw: float, p: int) -> float:
    """Naive fan-out: all P-1 copies leave the source's single egress link."""
    if p <= 1:
        return 0.0
    return (p - 1) * total_bytes / link_bw
