"""Transfer transports.

``SimulatedTransport`` — event/step-driven WAN simulation with the paper's
bandwidth model: per-site read/write caps, per-route caps, fair sharing among
concurrent transfers, a metadata *scan* phase preceding data movement (Globus
scans source directories to size the transfer), transient fault stalls,
persistent permission failures, and PAUSED semantics during maintenance.

``LocalFSTransport`` — real file movement between site directories on the
local filesystem with checksum verification and retransmission of corrupted
files; used by checkpoint replication and the end-to-end examples.
"""
from __future__ import annotations

import abc
import dataclasses
import os
import shutil
import uuid as uuidlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.faults import (FaultInjector, FaultKind, Notifier, RetryPolicy)
from repro.core.pause import PauseManager
from repro.core.routes import Dataset, RouteGraph
from repro.core.transfer_table import Status


class SimClock:
    def __init__(self, t0: float = 0.0):
        self.now = t0

    def advance(self, dt: float) -> None:
        self.now += dt


# fraction of a dataset transferred before its unreadable files are reached
UNREADABLE_HALT_FRACTION = 0.25


@dataclass
class TransferState:
    status: Status
    bytes_done: int = 0
    files_done: int = 0
    dirs_done: int = 0
    faults: int = 0
    rate: float = 0.0
    detail: str = ""


class Transport(abc.ABC):
    @abc.abstractmethod
    def submit(self, dataset: Dataset, source: str, destination: str) -> str: ...

    @abc.abstractmethod
    def poll(self, uid: str) -> TransferState: ...

    def cancel(self, uid: str) -> None:  # pragma: no cover - optional
        pass


# ================================================================= simulation
@dataclass
class _SimXfer:
    dataset: Dataset
    source: str
    destination: str
    submitted_at: float
    phase: str = "scan"              # scan -> move -> done/failed
    scan_files_left: float = 0.0
    bytes_done: float = 0.0
    active_s: float = 0.0                 # time actually moving bytes
    faults: int = 0
    fault_marks: List[float] = field(default_factory=list)  # byte positions
    stall_left: float = 0.0
    status: Status = Status.ACTIVE
    completed_at: Optional[float] = None
    detail: str = ""


class SimulatedTransport(Transport):
    def __init__(self, graph: RouteGraph, clock: SimClock,
                 pause: PauseManager, injector: FaultInjector,
                 notifier: Notifier,
                 retry: RetryPolicy = RetryPolicy()):
        self.graph = graph
        self.clock = clock
        self.pause = pause
        self.injector = injector
        self.notifier = notifier
        self.retry = retry
        self._xfers: Dict[str, _SimXfer] = {}
        self._last_tick = clock.now
        # telemetry: (time, route, bytes_moved_this_tick)
        self.flow_log: List[Tuple[float, Tuple[str, str], float]] = []

    # ----------------------------------------------------------------- submit
    def submit(self, dataset: Dataset, source: str, destination: str) -> str:
        uid = str(uuidlib.uuid4())
        x = _SimXfer(dataset=dataset, source=source, destination=destination,
                     submitted_at=self.clock.now,
                     scan_files_left=float(dataset.files))
        n_faults = self.injector.n_transient_faults(dataset.path, dataset.bytes)
        if n_faults:
            rng = self.injector.rng
            x.fault_marks = sorted(
                float(b) for b in rng.uniform(0, dataset.bytes, n_faults))
        self._xfers[uid] = x
        return uid

    def poll(self, uid: str) -> TransferState:
        x = self._xfers[uid]
        # rate over *active* time (paper Table 3 reports achieved per-transfer
        # rates; PAUSED maintenance windows and metadata scans don't count)
        dur = max(1e-9, x.active_s)
        frac = x.bytes_done / max(1, x.dataset.bytes)
        return TransferState(
            status=x.status,
            bytes_done=int(x.bytes_done),
            files_done=int(x.dataset.files * frac),
            dirs_done=int(x.dataset.directories * frac),
            faults=x.faults,
            rate=x.bytes_done / dur,
            detail=x.detail)

    # ------------------------------------------------------------------- tick
    def tick(self) -> None:
        """Advance all transfers by (clock.now - last_tick)."""
        dt = self.clock.now - self._last_tick
        self._last_tick = self.clock.now
        if dt <= 0:
            return
        live = [x for x in self._xfers.values()
                if x.status in (Status.ACTIVE, Status.PAUSED)]
        # pause state first
        for x in live:
            paused = (self.pause.paused(x.source, self.clock.now)
                      or self.pause.paused(x.destination, self.clock.now))
            x.status = Status.PAUSED if paused else Status.ACTIVE
        movers = [x for x in live if x.status == Status.ACTIVE and x.phase == "move"]
        scanners = [x for x in live if x.status == Status.ACTIVE and x.phase == "scan"]

        # --- metadata scans (shared per source site) -------------------------
        by_src: Dict[str, List[_SimXfer]] = {}
        for x in scanners:
            by_src.setdefault(x.source, []).append(x)
        for src, xs in by_src.items():
            site = self.graph.sites[src]
            rate = site.scan_files_per_s / max(1, len(xs))
            for x in xs:
                if x.dataset.files > site.scan_mem_limit_files:
                    x.status = Status.FAILED
                    x.faults += 1
                    x.detail = FaultKind.OOM_SCAN.value
                    x.completed_at = self.clock.now
                    self.notifier.notify(
                        f"scan OOM on {src} for {x.dataset.path} "
                        f"({x.dataset.files} files) — split into smaller requests",
                        x.dataset.path)
                    continue
                x.scan_files_left -= rate * dt
                if x.scan_files_left <= 0:
                    x.phase = "move"

        # --- data movement (fair share of route + site caps) -----------------
        active_by_route: Dict[Tuple[str, str], int] = {}
        for x in movers:
            r = (x.source, x.destination)
            active_by_route[r] = active_by_route.get(r, 0) + 1
        for x in movers:
            rate = self.graph.effective_rate(x.source, x.destination,
                                             active_by_route)
            self._advance_mover(x, dt, rate)

    def _advance_mover(self, x: _SimXfer, dt: float, rate: float) -> None:
        """Advance one moving transfer by wall time ``dt`` at fair-share
        ``rate``, processing fault stalls, fault marks, the unreadable-file
        halt point, and completion *in order* within the tick.  Segment-exact:
        the result is independent of how ``dt`` is sliced, so the fixed-step
        and event-driven drivers see identical trajectories."""
        halt: Optional[float] = None
        if (x.dataset.unreadable
                and not self.notifier.is_fixed(x.dataset.path)):
            halt = UNREADABLE_HALT_FRACTION * x.dataset.bytes
        moved_total = 0.0
        t = dt
        while t > 1e-9:
            if x.stall_left > 0:
                used = min(x.stall_left, t)
                x.stall_left -= used
                t -= used
                continue
            if halt is not None and x.bytes_done >= halt:
                x.bytes_done = halt
                x.status = Status.FAILED
                x.faults += 1
                x.detail = FaultKind.PERMISSION.value
                x.completed_at = self.clock.now
                self.notifier.notify(
                    f"permission failure (unreadable files) in {x.dataset.path}",
                    x.dataset.path)
                break
            if rate <= 0:
                break
            # next byte boundary: fault mark, halt point, or completion
            nxt = float(x.dataset.bytes)
            if halt is not None:
                nxt = min(nxt, halt)
            if x.fault_marks and x.fault_marks[0] < nxt:
                nxt = x.fault_marks[0]
            need = max(0.0, nxt - x.bytes_done) / rate
            if need > t:
                x.bytes_done += rate * t
                x.active_s += t
                moved_total += rate * t
                t = 0.0
                break
            x.bytes_done = nxt
            x.active_s += need
            moved_total += rate * need
            t -= need
            if x.fault_marks and x.fault_marks[0] <= nxt:
                x.fault_marks.pop(0)
                x.faults += 1
                x.stall_left += self.retry.fault_retry_cost_s
                continue
            if halt is not None and nxt >= halt:
                continue            # halt handled at the top of the loop
            if nxt >= x.dataset.bytes:
                x.bytes_done = float(x.dataset.bytes)
                x.status = Status.SUCCEEDED
                x.completed_at = self.clock.now
                break
        if moved_total > 0:
            self.flow_log.append(
                (self.clock.now, (x.source, x.destination), moved_total))

    # ------------------------------------------------------- next-event hints
    def next_event_hint(self) -> float:
        """Seconds until the earliest projected *state change* among live
        transfers, assuming current fair-share rates persist: a transfer
        completing or halting on unreadable files, or a metadata scan
        finishing (either of which changes route/site fair shares).  Fault
        marks and stall expiries are NOT events — ``_advance_mover`` resolves
        them exactly within a tick — but their stall time is folded into each
        completion estimate.  Returns ``inf`` when nothing is in flight;
        pause-window boundaries are the caller's responsibility (see
        ``PauseManager.next_boundary``)."""
        now = self.clock.now
        best = float("inf")
        scanners_by_src: Dict[str, List[_SimXfer]] = {}
        movers: List[_SimXfer] = []
        for x in self._xfers.values():
            if x.status not in (Status.ACTIVE, Status.PAUSED):
                continue
            if (self.pause.paused(x.source, now)
                    or self.pause.paused(x.destination, now)):
                continue        # state flips at a pause boundary, not here
            if x.phase == "scan":
                scanners_by_src.setdefault(x.source, []).append(x)
            elif x.phase == "move":
                movers.append(x)
        for src, xs in scanners_by_src.items():
            site = self.graph.sites[src]
            rate = site.scan_files_per_s / max(1, len(xs))
            for x in xs:
                if x.dataset.files > site.scan_mem_limit_files:
                    return 1.0  # OOM fires on the very next tick
                if rate > 0:
                    best = min(best, max(0.0, x.scan_files_left / rate))
        active_by_route: Dict[Tuple[str, str], int] = {}
        for x in movers:
            r = (x.source, x.destination)
            active_by_route[r] = active_by_route.get(r, 0) + 1
        for x in movers:
            rate = self.graph.effective_rate(x.source, x.destination,
                                             active_by_route)
            if rate <= 0:
                continue
            halt_active = (x.dataset.unreadable
                           and not self.notifier.is_fixed(x.dataset.path))
            target = (UNREADABLE_HALT_FRACTION * x.dataset.bytes
                      if halt_active else float(x.dataset.bytes))
            if target <= x.bytes_done:
                return max(x.stall_left, 1.0)   # halts on the next tick
            pending_stall = x.stall_left + self.retry.fault_retry_cost_s * sum(
                1 for m in x.fault_marks if m < target)
            best = min(best,
                       pending_stall + (target - x.bytes_done) / rate)
        return best


# ================================================================== local FS
class LocalFSTransport(Transport):
    """Moves real bytes between site directories with integrity verification.

    Site ``X`` maps to ``root/X/``.  A transfer of dataset path ``P`` copies
    ``root/src/P`` -> ``root/dst/P`` file by file, checksumming source and
    destination (paper: Globus checksums every file and retransmits corrupted
    ones).  ``corruptor`` lets tests flip bytes in flight to prove detection.
    """

    def __init__(self, root: str,
                 corruptor: Optional[Callable[[str, bytes], bytes]] = None):
        self.root = root
        self.corruptor = corruptor
        self._states: Dict[str, TransferState] = {}

    def site_dir(self, site: str) -> str:
        return os.path.join(self.root, site)

    def submit(self, dataset: Dataset, source: str, destination: str) -> str:
        from repro.core.integrity import file_checksum
        uid = str(uuidlib.uuid4())
        src_base = os.path.join(self.site_dir(source), dataset.path.lstrip("/"))
        dst_base = os.path.join(self.site_dir(destination), dataset.path.lstrip("/"))
        faults = 0
        nbytes = 0
        nfiles = 0
        ndirs = 0
        try:
            for dirpath, _, files in os.walk(src_base):
                rel = os.path.relpath(dirpath, src_base)
                ddir = os.path.join(dst_base, rel) if rel != "." else dst_base
                os.makedirs(ddir, exist_ok=True)
                ndirs += 1
                for fn in files:
                    sp = os.path.join(dirpath, fn)
                    dp = os.path.join(ddir, fn)
                    with open(sp, "rb") as f:
                        data = f.read()
                    want = file_checksum(data)
                    for _attempt in range(3):
                        payload = data
                        if self.corruptor is not None:
                            payload = self.corruptor(sp, data)
                        with open(dp, "wb") as f:
                            f.write(payload)
                        with open(dp, "rb") as f:
                            got = file_checksum(f.read())
                        if got == want:
                            break
                        faults += 1  # integrity fault -> retransmit
                    else:
                        raise IOError(f"persistent corruption for {sp}")
                    nbytes += len(data)
                    nfiles += 1
            st = TransferState(Status.SUCCEEDED, bytes_done=nbytes,
                               files_done=nfiles, dirs_done=ndirs, faults=faults)
        except (OSError, IOError) as e:
            st = TransferState(Status.FAILED, bytes_done=nbytes,
                               files_done=nfiles, dirs_done=ndirs,
                               faults=faults + 1, detail=str(e))
        self._states[uid] = st
        return uid

    def poll(self, uid: str) -> TransferState:
        return self._states[uid]
