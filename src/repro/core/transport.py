"""Transfer transports.

``SimulatedTransport`` — event/step-driven WAN simulation with the paper's
bandwidth model: per-site read/write caps, per-route caps, fair sharing among
concurrent transfers, a metadata *scan* phase preceding data movement (Globus
scans source directories to size the transfer), transient fault stalls,
persistent permission failures, and PAUSED semantics during maintenance.

The hot path is O(live transfers), not O(everything ever submitted): terminal
transfers are evicted from the live pool into a compact archive of final
``TransferState``s the moment they finish, so ``tick()`` / ``poll()`` /
``next_event_hint()`` never touch finished work.  Within a tick the live
movers advance through a structure-of-arrays NumPy pool: fair-share rates,
stall consumption, and the advance-to-next-byte-boundary test are batched
array ops, and only movers that actually cross a boundary (fault mark, halt
point, completion) fall back to the segment-exact scalar walk — so the
vectorized trajectory is bit-identical to the scalar one.

``LocalFSTransport`` — real file movement between site directories on the
local filesystem with checksum verification and retransmission of corrupted
files; used by checkpoint replication and the end-to-end examples.  Files
stream through in fixed-size chunks with incremental checksumming — nothing
is ever ``read()`` whole into memory.

Determinism invariants (enforced by the engine-equivalence and crash-resume
tests; every engine that drives this transport relies on them):

  * **Segment-exactness** — a mover's trajectory is independent of how wall
    time is sliced into ticks.  ``_advance_mover`` processes stalls, fault
    marks, the unreadable halt point, and completion in byte order within a
    tick, so fixed-step, event-driven, and ensemble drivers produce
    bit-identical ``bytes_done``/``active_s``/fault sequences.
  * **One shared arithmetic** — the vectorized SoA fast path, the scalar
    walk, and the ensemble lanes engine compute every advance through the
    pure helpers ``consume_stall`` / ``advance_segment`` (or expressions
    proven operation-for-operation identical to them), in float64.  Any
    reformulation (e.g. a fused multiply-add) changes trajectories.
  * **RNG consumption order** — the fault stream is consumed ONLY at
    ``submit`` via ``FaultInjector.transient_marks`` (fragility memo →
    Poisson count → uniform positions), in submission order.  Scheduler
    start order therefore determines the entire fault history.
  * **Rate snapshotting** — fair-share rates (``_route_rates``) are computed
    once per tick from the mover population *before* any scan finishes or
    mover completes within that tick, and held constant across the tick.
  * **Hint/advance agreement** — ``next_event_hint`` uses the same shared
    scan rate and fair-share rates as the tick advance, so a projected
    completion time is exactly when the advance lands it.
"""
from __future__ import annotations

import abc
import os
import uuid as uuidlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.faults import (FaultInjector, FaultKind, Notifier, RetryPolicy)
from repro.core.pause import DAY, PauseManager
from repro.core.routes import Dataset, RouteGraph, fair_share_rates
from repro.core.transfer_table import Status


class SimClock:
    def __init__(self, t0: float = 0.0):
        self.now = t0

    def advance(self, dt: float) -> None:
        self.now += dt


# fraction of a dataset transferred before its unreadable files are reached
UNREADABLE_HALT_FRACTION = 0.25


# ---------------------------------------------------------- pure segment math
# The two arithmetic steps of the mover segment walk, as pure float64 array
# functions.  The SoA fast path below and the ensemble lanes engine
# (repro.ensemble) call THESE — not re-derived formulas — so every driver
# advances movers through literally the same operations.  Scalars broadcast.

def consume_stall(t, stall):
    """Consume pending fault-stall time first (the walk's first branch):
    ``used = min(stall, t)``; returns ``(t - used, stall - used)``."""
    used = np.minimum(stall, t)
    return t - used, stall - used


def advance_segment(t, bytes_done, rate, bound):
    """Advance toward the next byte boundary at fair-share ``rate`` for up to
    ``t`` seconds.  ``bound`` is the nearest of completion / halt point /
    first fault mark.  Returns ``(t_left, new_bytes, active_add, moved,
    hit)`` where ``hit`` marks movers that reached the boundary within
    ``t`` (``need <= t``, the walk's branch condition).  Movers with
    ``rate <= 0`` get ``need = inf`` and never hit; callers gate them."""
    inf = float("inf")
    with np.errstate(divide="ignore", invalid="ignore"):
        need = np.where(rate > 0,
                        np.maximum(0.0, bound - bytes_done) / rate, inf)
    hit = need <= t
    adv = np.where(hit, need, t)
    new_bytes = np.where(hit, bound, bytes_done + rate * t)
    moved = rate * adv
    t_left = np.where(hit, t - need, 0.0)
    return t_left, new_bytes, adv, moved, hit


def shared_scan_rate(site, scanners: int) -> float:
    """Per-transfer metadata-scan rate when ``scanners`` concurrent scans
    share one source site's scan throughput — the single definition both the
    tick advance and the next-event hint must use, so the two can never
    drift apart."""
    return site.scan_files_per_s / max(1, scanners)


@dataclass
class TransferState:
    status: Status
    bytes_done: int = 0
    files_done: int = 0
    dirs_done: int = 0
    faults: int = 0
    rate: float = 0.0
    detail: str = ""


class Transport(abc.ABC):
    @abc.abstractmethod
    def submit(self, dataset: Dataset, source: str, destination: str) -> str: ...

    @abc.abstractmethod
    def poll(self, uid: str) -> TransferState: ...

    def cancel(self, uid: str) -> None:
        """Abort an in-flight transfer, releasing whatever capacity it holds.
        Cancelling an unknown or already-terminal uid is a no-op; the final
        state of a cancelled transfer must remain pollable."""


# ================================================================= simulation
@dataclass
class _SimXfer:
    dataset: Dataset
    source: str
    destination: str
    submitted_at: float
    phase: str = "scan"              # scan -> move -> done/failed
    setup_left: float = 0.0          # fixed per-task dispatch cost (seconds)
    scan_files_left: float = 0.0
    bytes_done: float = 0.0
    active_s: float = 0.0                 # time actually moving bytes
    faults: int = 0
    fault_marks: List[float] = field(default_factory=list)  # byte positions
    stall_left: float = 0.0
    status: Status = Status.ACTIVE
    completed_at: Optional[float] = None
    detail: str = ""


class SimulatedTransport(Transport):
    def __init__(self, graph: RouteGraph, clock: SimClock,
                 pause: PauseManager, injector: FaultInjector,
                 notifier: Notifier,
                 retry: RetryPolicy = RetryPolicy(),
                 vectorized: bool = True,
                 task_setup_s: float = 0.0,
                 flow_horizon_days: Optional[float] = None):
        self.graph = graph
        self.clock = clock
        self.pause = pause
        self.injector = injector
        self.notifier = notifier
        self.retry = retry
        self.vectorized = vectorized
        # fixed dispatch cost per submitted task, paid before the metadata
        # scan (Globus task setup/queueing) — what makes one-task-per-tiny-
        # dataset workloads slow and bundling worthwhile.  0.0 = seed model.
        self.task_setup_s = task_setup_s
        self._live: Dict[str, _SimXfer] = {}
        # terminal transfers: uid -> final TransferState, evicted from the
        # live pool so per-tick cost never grows with campaign history
        self._archive: Dict[str, TransferState] = {}
        self._last_tick = clock.now
        # telemetry, bounded: per-(day, route) byte totals instead of one
        # tuple per mover per tick
        self.flow_totals: Dict[Tuple[int, Tuple[str, str]], float] = {}
        # optional retention horizon for flow_totals: buckets older than
        # this many days are pruned at day crossings, so a 29M-file
        # campaign's telemetry stays O(routes · horizon) instead of
        # O(routes · campaign days).  None = keep the whole campaign.
        self.flow_horizon_days = flow_horizon_days
        self._flow_pruned_day = -1
        # cumulative per-route counters for the control plane's tuners:
        # bytes moved and transient/persistent faults observed, O(routes)
        self._route_bytes: Dict[Tuple[str, str], float] = {}
        self._route_faults: Dict[Tuple[str, str], int] = {}
        # user read traffic: owner label -> {site: concurrent reader streams}.
        # Readers consume the site *read* caps alongside movers (the serving
        # tier reads the same archive the movers read from) but occupy no
        # route, so they slow transfers out of a hot site without inventing
        # bandwidth between sites.
        self._read_load: Dict[str, Dict[str, int]] = {}
        # fair-share memo: the last priced population (mover routes + reader
        # pseudo-routes, with counts) and its rates dict.  Valid until any
        # mover joins/leaves a route or reader load shifts — graph caps and
        # knees are build-time constants, so population equality is the whole
        # invalidation condition.  ``_pop_buf`` is the reusable scratch dict
        # the per-tick population is counted into.
        self._rates_pop: Optional[Dict[Tuple[str, str], int]] = None
        self._rates: Dict[Tuple[str, str], float] = {}
        self._pop_buf: Dict[Tuple[str, str], int] = {}
        # interned pricing arrays per distinct active-route set: the routes'
        # bandwidths / site caps / knees as preallocated float64 arrays plus
        # int64 load buffers, so a cache miss prices EVERY route in one
        # vectorized ``fair_share_rates`` call
        self._route_arrays: Dict[Tuple[Tuple[str, str], ...], tuple] = {}

    @property
    def live_count(self) -> int:
        return len(self._live)

    # ----------------------------------------------------------------- submit
    def submit(self, dataset: Dataset, source: str, destination: str) -> str:
        uid = str(uuidlib.uuid4())
        x = _SimXfer(dataset=dataset, source=source, destination=destination,
                     submitted_at=self.clock.now,
                     setup_left=float(self.task_setup_s),
                     scan_files_left=float(dataset.files))
        x.fault_marks = self.injector.transient_marks(dataset.path,
                                                      dataset.bytes)
        self._live[uid] = x
        return uid

    def poll(self, uid: str) -> TransferState:
        done = self._archive.get(uid)
        if done is not None:
            return done
        return self._state_of(self._live[uid])

    def cancel(self, uid: str) -> None:
        """Evict a live transfer to the archive as FAILED/"cancelled".  The
        mover immediately stops occupying its route/site fair share (the next
        ``_route_rates`` no longer counts it), which is how a campaign ending
        early hands its bandwidth back to the survivors.  No-op for archived
        or unknown uids, so terminal transfers stay pollable unchanged."""
        x = self._live.pop(uid, None)
        if x is None:
            return
        x.status = Status.FAILED
        x.detail = "cancelled"
        x.completed_at = self.clock.now
        self._archive[uid] = self._state_of(x)

    @staticmethod
    def _state_of(x: _SimXfer) -> TransferState:
        # rate over *active* time (paper Table 3 reports achieved per-transfer
        # rates; PAUSED maintenance windows and metadata scans don't count)
        dur = max(1e-9, x.active_s)
        frac = x.bytes_done / max(1, x.dataset.bytes)
        return TransferState(
            status=x.status,
            bytes_done=int(x.bytes_done),
            files_done=int(x.dataset.files * frac),
            dirs_done=int(x.dataset.directories * frac),
            faults=x.faults,
            rate=x.bytes_done / dur,
            detail=x.detail)

    def _log_flow(self, route: Tuple[str, str], nbytes: float) -> None:
        key = (int(self.clock.now // DAY), route)
        self.flow_totals[key] = self.flow_totals.get(key, 0.0) + nbytes
        self._route_bytes[route] = self._route_bytes.get(route, 0.0) + nbytes

    def _log_fault(self, route: Tuple[str, str], n: int = 1) -> None:
        self._route_faults[route] = self._route_faults.get(route, 0) + n

    def route_telemetry(self) -> Dict[Tuple[str, str], Tuple[float, int]]:
        """Cumulative (bytes moved, faults observed) per route since the
        campaign start — the control plane's tuners difference consecutive
        readings to get per-interval throughput and fault rates.  Sorted
        route order, so any float reduction a controller runs over the
        values is evaluated identically in every process (kill/resume
        crosses process boundaries; set order does not)."""
        routes = sorted(set(self._route_bytes) | set(self._route_faults))
        return {r: (self._route_bytes.get(r, 0.0),
                    self._route_faults.get(r, 0))
                for r in routes}

    def live_route_counts(self) -> Dict[str, int]:
        """In-flight transfers per route ("SRC->DST", sorted) — the flight
        recorder's fair-share occupancy gauge.  Read-only, O(live)."""
        counts: Dict[str, int] = {}
        for x in self._live.values():
            key = f"{x.source}->{x.destination}"
            counts[key] = counts.get(key, 0) + 1
        return {k: counts[k] for k in sorted(counts)}

    def _pause_memo(self, now: float) -> Callable[[str], bool]:
        """Per-tick memoized site-pause lookup (two sites per transfer, but
        only a handful of distinct sites)."""
        memo: Dict[str, bool] = {}

        def paused(site: str) -> bool:
            p = memo.get(site)
            if p is None:
                p = memo[site] = self.pause.paused(site, now)
            return p

        return paused

    # destination token for pseudo-routes carrying user reader streams into
    # the fair-share computation; never a real site name
    _READERS = "__readers__"

    def set_read_load(self, owner: str, load: Dict[str, int]) -> None:
        """Register ``owner``'s concurrent user-read streams per site (the
        demand engine re-registers each admission wave).  An empty ``load``
        withdraws the owner entirely, so a finished campaign's readers stop
        taxing the shared transport."""
        load = {s: int(n) for s, n in load.items() if int(n) > 0}
        if load:
            self._read_load[owner] = load
        else:
            self._read_load.pop(owner, None)

    def _reader_streams(self) -> Dict[str, int]:
        """Total user reader streams per site across all owners."""
        total: Dict[str, int] = {}
        for load in self._read_load.values():
            for site, n in load.items():
                total[site] = total.get(site, 0) + n
        return total

    def _route_rates(self, movers: List[_SimXfer]) -> Dict[Tuple[str, str], float]:
        """Fair-share rate per route for the current mover population —
        computed once per route, shared by the tick advance and the
        next-event hints so the two can never diverge.  User reader streams
        are folded in as pseudo-routes ``(site, "__readers__")`` so they
        contend for the source read caps, but only real mover routes appear
        in the returned dict.

        O(movers) when the population is unchanged since the last pricing
        (the same rates dict is returned — callers never mutate it); a
        population change prices all routes in ONE vectorized
        ``fair_share_rates`` call over interned per-route arrays, elementwise
        bit-identical to the per-route scalar ``effective_rate`` path."""
        pop = self._pop_buf
        pop.clear()
        for x in movers:
            r = (x.source, x.destination)
            pop[r] = pop.get(r, 0) + 1
        routes = tuple(pop)
        for site, n in self._reader_streams().items():
            pop[(site, self._READERS)] = n
        if pop == self._rates_pop:
            return self._rates
        rates = self._price_routes(routes, pop)
        # ping-pong the buffers: ``pop`` becomes the cached population, the
        # previous cached dict (if any) becomes next call's scratch
        self._pop_buf = self._rates_pop if self._rates_pop is not None else {}
        self._rates_pop = pop
        self._rates = rates
        return rates

    def _price_routes(self, routes: Tuple[Tuple[str, str], ...],
                      pop: Dict[Tuple[str, str], int]
                      ) -> Dict[Tuple[str, str], float]:
        """Price every route in ``routes`` against the full population
        ``pop`` (mover routes plus reader pseudo-routes) with one vectorized
        ``fair_share_rates`` call.  Per distinct route set, the static
        per-route inputs (bandwidth, site caps, contention knees) are
        interned once into preallocated arrays; only the int64 load buffers
        are refilled per call.  Routes absent from the graph price to 0.0
        without touching site lookups, exactly like the scalar path."""
        arrs = self._route_arrays.get(routes)
        if arrs is None:
            if len(self._route_arrays) > 64:    # combinatorial-blowup guard
                self._route_arrays.clear()
            graph = self.graph
            idx = [i for i, r in enumerate(routes) if r in graph.routes]
            m = len(idx)
            route_bw = np.empty(m)
            read_cap = np.empty(m)
            write_cap = np.empty(m)
            src_knee = np.empty(m)
            dst_knee = np.empty(m)
            inf = float("inf")
            for j, i in enumerate(idx):
                src, dst = routes[i]
                s, d = graph.sites[src], graph.sites[dst]
                route_bw[j] = graph.routes[(src, dst)].bandwidth
                read_cap[j] = s.read_bw
                write_cap[j] = d.write_bw
                src_knee[j] = (inf if s.concurrency_knee is None
                               else s.concurrency_knee)
                dst_knee[j] = (inf if d.concurrency_knee is None
                               else d.concurrency_knee)
            arrs = (idx, route_bw, read_cap, write_cap, src_knee, dst_knee,
                    np.empty(m, dtype=np.int64), np.empty(m, dtype=np.int64),
                    np.empty(m, dtype=np.int64))
            self._route_arrays[routes] = arrs
        (idx, route_bw, read_cap, write_cap, src_knee, dst_knee,
         n_route, src_load, dst_load) = arrs
        sload: Dict[str, int] = {}
        dload: Dict[str, int] = {}
        for (s, d), n in pop.items():
            sload[s] = sload.get(s, 0) + n
            dload[d] = dload.get(d, 0) + n
        for j, i in enumerate(idx):
            src, dst = routes[i]
            n_route[j] = pop[(src, dst)]
            src_load[j] = sload[src]
            dst_load[j] = dload[dst]
        shares = fair_share_rates(route_bw, read_cap, write_cap,
                                  n_route, src_load, dst_load,
                                  src_knee, dst_knee)
        rates = dict.fromkeys(routes, 0.0)
        for j, i in enumerate(idx):
            rates[routes[i]] = float(shares[j])
        return rates

    def user_read_rate(self, site: str) -> float:
        """Fair-share bytes/s one user read stream gets from ``site``'s read
        cap right now, sharing it with every non-paused mover sourcing there
        and every other reader stream.  Paused sites serve at their paused
        fair share of zero concurrency — i.e. the full cap — because the
        maintenance window stalls movers, not the serving tier's disks."""
        s = self.graph.sites[site]
        paused = self._pause_memo(self.clock.now)
        load = self._reader_streams().get(site, 0)
        if not paused(site):
            for x in self._live.values():
                if (x.phase == "move" and x.source == site
                        and not paused(x.destination)):
                    load += 1
        load = max(1, load)
        return RouteGraph._contended(s.read_bw, load,
                                     s.concurrency_knee) / load

    # ------------------------------------------------------------------- tick
    def tick(self) -> None:
        """Advance all live transfers by (clock.now - last_tick)."""
        dt = self.clock.now - self._last_tick
        self._last_tick = self.clock.now
        if dt <= 0:
            return
        now = self.clock.now
        if self.flow_horizon_days is not None:
            day = int(now // DAY)
            if day > self._flow_pruned_day:
                self._flow_pruned_day = day
                floor = day - self.flow_horizon_days
                for key in [k for k in self.flow_totals if k[0] < floor]:
                    del self.flow_totals[key]
        paused = self._pause_memo(now)
        movers: List[_SimXfer] = []
        by_src: Dict[str, List[_SimXfer]] = {}
        for x in self._live.values():
            if paused(x.source) or paused(x.destination):
                x.status = Status.PAUSED
                continue
            x.status = Status.ACTIVE
            if x.phase == "move":
                movers.append(x)
            else:
                by_src.setdefault(x.source, []).append(x)

        # --- metadata scans (shared per source site) -------------------------
        for src, xs in by_src.items():
            site = self.graph.sites[src]
            rate = shared_scan_rate(site, len(xs))
            for x in xs:
                if x.dataset.files > site.scan_mem_limit_files:
                    x.status = Status.FAILED
                    x.faults += 1
                    x.detail = FaultKind.OOM_SCAN.value
                    x.completed_at = now
                    self._log_fault((x.source, x.destination))
                    self.notifier.notify(
                        f"scan OOM on {src} for {x.dataset.path} "
                        f"({x.dataset.files} files) — split into smaller requests",
                        x.dataset.path)
                    continue
                avail = dt
                if x.setup_left > 0:         # task dispatch precedes the scan
                    used = min(x.setup_left, avail)
                    x.setup_left -= used
                    avail -= used
                    if avail <= 0:
                        continue
                x.scan_files_left -= rate * avail
                if x.scan_files_left <= 0:
                    x.phase = "move"

        # --- data movement (fair share of route + site caps) -----------------
        if movers:
            self._advance_movers(movers, dt)

        # --- evict terminal transfers to the archive -------------------------
        finished = [uid for uid, x in self._live.items()
                    if x.status in (Status.SUCCEEDED, Status.FAILED)]
        for uid in finished:
            self._archive[uid] = self._state_of(self._live.pop(uid))

    def _advance_movers(self, movers: List[_SimXfer], dt: float) -> None:
        """Batched advance of the live mover pool.  The fair-share rate is
        computed once per route; a structure-of-arrays view of the pool then
        classifies each mover: the common case (no byte boundary reached
        within ``dt``) is resolved with pure array ops, and only movers that
        hit a fault mark, halt point, or completion take the segment-exact
        scalar walk.  Every arithmetic expression in the fast path mirrors
        ``_advance_mover``'s first loop iteration operation-for-operation, so
        both paths produce bit-identical trajectories."""
        route_rate = self._route_rates(movers)
        if not self.vectorized or dt <= 1e-9:
            for x in movers:
                self._advance_mover(x, dt, route_rate[(x.source, x.destination)])
            return
        n = len(movers)
        inf = float("inf")
        rate = np.empty(n)
        bd = np.empty(n)       # bytes_done
        st = np.empty(n)       # stall_left
        halt = np.empty(n)     # permission-halt byte position (inf if none)
        bound = np.empty(n)    # next byte boundary: completion/halt/fault mark
        for i, x in enumerate(movers):
            rate[i] = route_rate[(x.source, x.destination)]
            bd[i] = x.bytes_done
            st[i] = x.stall_left
            h = inf
            if (x.dataset.unreadable
                    and not self.notifier.is_fixed(x.dataset.path)):
                h = UNREADABLE_HALT_FRACTION * x.dataset.bytes
            halt[i] = h
            nxt = min(float(x.dataset.bytes), h)
            if x.fault_marks and x.fault_marks[0] < nxt:
                nxt = x.fault_marks[0]
            bound[i] = nxt
        # stall is consumed first (exactly as the scalar loop does), then one
        # shared segment step classifies each mover.  Movers whose whole dt
        # is eaten by stall never reach a boundary; otherwise the fast path
        # requires rate > 0, not already at the halt point, and the next
        # boundary strictly beyond this tick (``~hit``) — only boundary
        # crossers take the segment-exact scalar walk.
        rem, new_stall = consume_stall(dt, st)
        _, new_bd, adv, moved, hit = advance_segment(rem, bd, rate, bound)
        fast = (rem <= 1e-9) | ((rate > 0) & (bd < halt) & ~hit)
        for i, x in enumerate(movers):
            if not fast[i]:
                self._advance_mover(x, dt,
                                    route_rate[(x.source, x.destination)])
                continue
            x.stall_left = float(new_stall[i])
            r = float(rem[i])
            if r > 1e-9:
                x.bytes_done = float(new_bd[i])
                x.active_s += float(adv[i])
                self._log_flow((x.source, x.destination), float(moved[i]))

    def _advance_mover(self, x: _SimXfer, dt: float, rate: float) -> None:
        """Advance one moving transfer by wall time ``dt`` at fair-share
        ``rate``, processing fault stalls, fault marks, the unreadable-file
        halt point, and completion *in order* within the tick.  Segment-exact:
        the result is independent of how ``dt`` is sliced, so the fixed-step
        and event-driven drivers see identical trajectories."""
        halt: Optional[float] = None
        if (x.dataset.unreadable
                and not self.notifier.is_fixed(x.dataset.path)):
            halt = UNREADABLE_HALT_FRACTION * x.dataset.bytes
        moved_total = 0.0
        t = dt
        while t > 1e-9:
            if x.stall_left > 0:
                used = min(x.stall_left, t)
                x.stall_left -= used
                t -= used
                continue
            if halt is not None and x.bytes_done >= halt:
                x.bytes_done = halt
                x.status = Status.FAILED
                x.faults += 1
                x.detail = FaultKind.PERMISSION.value
                x.completed_at = self.clock.now
                self._log_fault((x.source, x.destination))
                self.notifier.notify(
                    f"permission failure (unreadable files) in {x.dataset.path}",
                    x.dataset.path)
                break
            if rate <= 0:
                break
            # next byte boundary: fault mark, halt point, or completion
            nxt = float(x.dataset.bytes)
            if halt is not None:
                nxt = min(nxt, halt)
            if x.fault_marks and x.fault_marks[0] < nxt:
                nxt = x.fault_marks[0]
            need = max(0.0, nxt - x.bytes_done) / rate
            if need > t:
                x.bytes_done += rate * t
                x.active_s += t
                moved_total += rate * t
                t = 0.0
                break
            x.bytes_done = nxt
            x.active_s += need
            moved_total += rate * need
            t -= need
            if x.fault_marks and x.fault_marks[0] <= nxt:
                x.fault_marks.pop(0)
                x.faults += 1
                x.stall_left += self.retry.fault_retry_cost_s
                self._log_fault((x.source, x.destination))
                continue
            if halt is not None and nxt >= halt:
                continue            # halt handled at the top of the loop
            if nxt >= x.dataset.bytes:
                x.bytes_done = float(x.dataset.bytes)
                x.status = Status.SUCCEEDED
                x.completed_at = self.clock.now
                break
        if moved_total > 0:
            self._log_flow((x.source, x.destination), moved_total)

    # ------------------------------------------------------------ checkpoints
    _XFER_SCALARS = ("source", "destination", "submitted_at", "phase",
                     "setup_left", "scan_files_left", "bytes_done",
                     "active_s", "faults", "stall_left", "completed_at",
                     "detail")
    _STATE_SCALARS = ("bytes_done", "files_done", "dirs_done", "faults",
                      "rate", "detail")

    def state_dict(self, archive_uids: Optional[set] = None) -> dict:
        """JSON-serializable copy of the mutable simulation state: the live
        mover pool (insertion order preserved — tick iteration order must
        survive a resume), the terminal-transfer archive, the tick cursor,
        and the per-(day, route) flow telemetry.  Datasets are referenced by
        path; ``load_state_dict`` re-binds them against the catalog.

        ``archive_uids`` restricts the serialized archive to uids that can
        still be polled (rows still occupying a transfer slot).  Entries the
        scheduler has already consumed — the archive's vast majority late in
        a campaign — are dead weight after their row went terminal, so
        filtering keeps snapshot size O(active), not O(campaign history)."""
        live = []
        for uid, x in self._live.items():
            e = {"uid": uid, "dataset": x.dataset.path,
                 "status": x.status.value,
                 "fault_marks": list(x.fault_marks)}
            for f in self._XFER_SCALARS:
                e[f] = getattr(x, f)
            live.append(e)
        archive = []
        for uid, st in self._archive.items():
            if archive_uids is not None and uid not in archive_uids:
                continue
            e = {"uid": uid, "status": st.status.value}
            for f in self._STATE_SCALARS:
                e[f] = getattr(st, f)
            archive.append(e)
        out = {"last_tick": self._last_tick, "live": live, "archive": archive,
               "flow": [[day, src, dst, v]
                        for (day, (src, dst)), v in self.flow_totals.items()],
               "route_bytes": [[src, dst, v]
                               for (src, dst), v in self._route_bytes.items()],
               "route_faults": [[src, dst, n]
                                for (src, dst), n in
                                self._route_faults.items()]}
        if self._read_load:
            # present only when demand traffic is live, so snapshots of
            # demand-free campaigns are byte-identical to pre-demand ones
            out["read_load"] = [[owner, site, n]
                                for owner in sorted(self._read_load)
                                for site, n in
                                sorted(self._read_load[owner].items())]
        return out

    def load_state_dict(self, d: dict, catalog: Dict[str, Dataset]) -> None:
        self._last_tick = d["last_tick"]
        self._live = {}
        for e in d["live"]:
            x = _SimXfer(dataset=catalog[e["dataset"]],
                         source=e["source"], destination=e["destination"],
                         submitted_at=e["submitted_at"],
                         status=Status(e["status"]),
                         fault_marks=[float(m) for m in e["fault_marks"]])
            for f in self._XFER_SCALARS:
                setattr(x, f, e[f])
            self._live[e["uid"]] = x
        self._archive = {
            e["uid"]: TransferState(
                status=Status(e["status"]),
                **{f: e[f] for f in self._STATE_SCALARS})
            for e in d["archive"]}
        self.flow_totals = {(day, (src, dst)): v
                            for day, src, dst, v in d["flow"]}
        self._route_bytes = {(src, dst): float(v)
                             for src, dst, v in d["route_bytes"]}
        self._route_faults = {(src, dst): int(n)
                              for src, dst, n in d["route_faults"]}
        self._read_load = {}
        for owner, site, n in d.get("read_load", ()):
            self._read_load.setdefault(owner, {})[site] = int(n)

    # ------------------------------------------------------- next-event hints
    def next_event_hint(self) -> float:
        """Seconds until the earliest projected *state change* among live
        transfers, assuming current fair-share rates persist: a transfer
        completing or halting on unreadable files, or a metadata scan
        finishing (either of which changes route/site fair shares).  Fault
        marks and stall expiries are NOT events — ``_advance_mover`` resolves
        them exactly within a tick — but their stall time is folded into each
        completion estimate.  Returns ``inf`` when nothing is in flight;
        pause-window boundaries are the caller's responsibility (see
        ``PauseManager.next_boundary``).  Touches only the live pool."""
        now = self.clock.now
        best = float("inf")
        paused = self._pause_memo(now)
        scanners_by_src: Dict[str, List[_SimXfer]] = {}
        movers: List[_SimXfer] = []
        for x in self._live.values():
            if paused(x.source) or paused(x.destination):
                continue        # state flips at a pause boundary, not here
            if x.phase == "scan":
                scanners_by_src.setdefault(x.source, []).append(x)
            elif x.phase == "move":
                movers.append(x)
        for src, xs in scanners_by_src.items():
            site = self.graph.sites[src]
            rate = shared_scan_rate(site, len(xs))
            for x in xs:
                if x.dataset.files > site.scan_mem_limit_files:
                    return 1.0  # OOM fires on the very next tick
                if rate > 0:
                    best = min(best, x.setup_left
                               + max(0.0, x.scan_files_left / rate))
        route_rate = self._route_rates(movers)
        for x in movers:
            rate = route_rate[(x.source, x.destination)]
            if rate <= 0:
                continue
            halt_active = (x.dataset.unreadable
                           and not self.notifier.is_fixed(x.dataset.path))
            target = (UNREADABLE_HALT_FRACTION * x.dataset.bytes
                      if halt_active else float(x.dataset.bytes))
            if target <= x.bytes_done:
                return max(x.stall_left, 1.0)   # halts on the next tick
            pending_stall = x.stall_left + self.retry.fault_retry_cost_s * sum(
                1 for m in x.fault_marks if m < target)
            best = min(best,
                       pending_stall + (target - x.bytes_done) / rate)
        return best


# ================================================================== local FS
_CHUNK_BYTES = 4 * 1024 * 1024


class LocalFSTransport(Transport):
    """Moves real bytes between site directories with integrity verification.

    Site ``X`` maps to ``root/X/``.  A transfer of dataset path ``P`` copies
    ``root/src/P`` -> ``root/dst/P`` file by file in ``_CHUNK_BYTES`` pieces,
    checksumming source and destination incrementally as the bytes stream
    through (paper: Globus checksums every file and retransmits corrupted
    ones) — whole files are never held in memory.  ``corruptor`` lets tests
    flip bytes in flight (it sees each chunk) to prove detection.
    """

    def __init__(self, root: str,
                 corruptor: Optional[Callable[[str, bytes], bytes]] = None):
        self.root = root
        self.corruptor = corruptor
        self._states: Dict[str, TransferState] = {}

    def site_dir(self, site: str) -> str:
        return os.path.join(self.root, site)

    def _copy_attempt(self, sp: str, dp: str) -> Tuple[int, int]:
        """Stream one source→destination copy; returns (nbytes, source
        checksum).  The corruptor (if any) mangles chunks in flight."""
        from repro.core.integrity import StreamingChecksum
        src_sum = StreamingChecksum()
        nbytes = 0
        with open(sp, "rb") as fin, open(dp, "wb") as fout:
            while True:
                chunk = fin.read(_CHUNK_BYTES)
                if not chunk:
                    break
                nbytes += len(chunk)
                src_sum.update(chunk)
                payload = chunk
                if self.corruptor is not None:
                    payload = self.corruptor(sp, chunk)
                fout.write(payload)
        return nbytes, src_sum.digest()

    @staticmethod
    def _checksum_file(path: str) -> int:
        from repro.core.integrity import stream_file_checksum
        return stream_file_checksum(path)[1]

    def submit(self, dataset: Dataset, source: str, destination: str) -> str:
        uid = str(uuidlib.uuid4())
        src_base = os.path.join(self.site_dir(source), dataset.path.lstrip("/"))
        dst_base = os.path.join(self.site_dir(destination), dataset.path.lstrip("/"))
        faults = 0
        nbytes = 0
        nfiles = 0
        ndirs = 0
        try:
            for dirpath, _, files in os.walk(src_base):
                rel = os.path.relpath(dirpath, src_base)
                ddir = os.path.join(dst_base, rel) if rel != "." else dst_base
                os.makedirs(ddir, exist_ok=True)
                ndirs += 1
                for fn in files:
                    sp = os.path.join(dirpath, fn)
                    dp = os.path.join(ddir, fn)
                    for _attempt in range(3):
                        size, want = self._copy_attempt(sp, dp)
                        if self._checksum_file(dp) == want:
                            break
                        faults += 1  # integrity fault -> retransmit
                    else:
                        raise IOError(f"persistent corruption for {sp}")
                    nbytes += size
                    nfiles += 1
            st = TransferState(Status.SUCCEEDED, bytes_done=nbytes,
                               files_done=nfiles, dirs_done=ndirs, faults=faults)
        except (OSError, IOError) as e:
            st = TransferState(Status.FAILED, bytes_done=nbytes,
                               files_done=nfiles, dirs_done=ndirs,
                               faults=faults + 1, detail=str(e))
        self._states[uid] = st
        return uid

    def poll(self, uid: str) -> TransferState:
        return self._states[uid]

    def audit(self, dataset: Dataset, source: str, destination: str,
              rels=None) -> Dict[str, dict]:
        """Post-landing scrub of a landed replica: scan the source tree into
        a ``Manifest`` and re-verify the destination copy against it with
        ``Manifest.verify_many`` — the same batched/partial API the simulated
        scrub engine models.  ``rels`` limits the audit to a subset of files
        (one scrub batch); returns the per-file verify_many report."""
        from repro.core.integrity import Manifest
        src = os.path.join(self.site_dir(source), dataset.path.lstrip("/"))
        dst = os.path.join(self.site_dir(destination), dataset.path.lstrip("/"))
        return Manifest.scan(src).verify_many(dst, rels=rels)
