"""Integrity checking: per-file checksums and transfer manifests (paper C3).

Globus computes and compares checksums at source and destination for every
file, retransmitting corrupted ones.  We implement the same contract with a
TPU-friendly streaming hash whose reference lives in
``repro.kernels.checksum.ref`` (numpy/jnp, exact uint32 arithmetic) and whose
production implementation is the Pallas kernel in
``repro.kernels.checksum.checksum`` (validated bit-exact against the ref).
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.kernels.checksum.ref import checksum_bytes_np


def file_checksum(data: bytes) -> int:
    return checksum_bytes_np(data)


@dataclass
class Manifest:
    """Checksums + sizes for a dataset (or checkpoint) directory tree."""
    entries: Dict[str, Tuple[int, int]] = field(default_factory=dict)  # path -> (size, csum)

    @classmethod
    def scan(cls, root: str) -> "Manifest":
        m = cls()
        for dirpath, _, files in os.walk(root):
            for fn in sorted(files):
                p = os.path.join(dirpath, fn)
                rel = os.path.relpath(p, root)
                with open(p, "rb") as f:
                    data = f.read()
                m.entries[rel] = (len(data), file_checksum(data))
        return m

    def verify(self, root: str) -> Dict[str, str]:
        """Returns {relpath: problem} for every mismatch; empty dict == clean."""
        problems: Dict[str, str] = {}
        for rel, (size, csum) in self.entries.items():
            p = os.path.join(root, rel)
            if not os.path.exists(p):
                problems[rel] = "missing"
                continue
            with open(p, "rb") as f:
                data = f.read()
            if len(data) != size:
                problems[rel] = f"size {len(data)} != {size}"
            elif file_checksum(data) != csum:
                problems[rel] = "checksum mismatch"
        return problems

    # ------------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({k: list(v) for k, v in self.entries.items()}, f)

    @classmethod
    def load(cls, path: str) -> "Manifest":
        with open(path) as f:
            raw = json.load(f)
        return cls(entries={k: (int(v[0]), int(v[1])) for k, v in raw.items()})

    @property
    def total_bytes(self) -> int:
        return sum(s for s, _ in self.entries.values())
