"""Integrity checking: per-file checksums and transfer manifests (paper C3).

Globus computes and compares checksums at source and destination for every
file, retransmitting corrupted ones.  We implement the same contract with a
TPU-friendly streaming hash whose reference lives in
``repro.kernels.checksum.ref`` (numpy/jnp, exact uint32 arithmetic) and whose
production implementation is the Pallas kernel in
``repro.kernels.checksum.checksum`` (validated bit-exact against the ref).

``StreamingChecksum`` feeds the hash chunk by chunk: because the fold is an
XOR-reduction of position-mixed words, partial folds over consecutive chunks
combine exactly to the whole-buffer hash, so transports and manifest scans
never need to hold a file in memory.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.kernels.checksum.ref import (checksum_bytes_np, finalize32_np,
                                        fold_words_np)

_SCAN_CHUNK = 4 * 1024 * 1024


def file_checksum(data: bytes) -> int:
    return checksum_bytes_np(data)


class StreamingChecksum:
    """Incremental ``checksum_bytes_np``: ``update()`` chunks in any split,
    then ``digest()`` — bit-identical to hashing the concatenation whole.
    Chunks need not be word-aligned; a ≤3-byte tail is carried between
    updates and only the final partial word is zero-padded."""

    def __init__(self):
        self._acc = 0
        self._nwords = 0
        self._nbytes = 0
        self._tail = b""

    def update(self, chunk: bytes) -> "StreamingChecksum":
        self._nbytes += len(chunk)
        data = self._tail + chunk
        nwords = len(data) // 4
        if nwords:
            words = np.frombuffer(data, dtype="<u4", count=nwords)
            self._acc ^= fold_words_np(words, self._nwords)
            self._nwords += nwords
        self._tail = data[nwords * 4:]
        return self

    def digest(self) -> int:
        acc = self._acc
        if self._tail:
            pad = self._tail + b"\0" * (-len(self._tail) % 4)
            acc ^= fold_words_np(np.frombuffer(pad, dtype="<u4"), self._nwords)
        return finalize32_np(acc, self._nbytes)


def stream_file_checksum(path: str) -> Tuple[int, int]:
    """(size, checksum) of a file, streamed in fixed-size chunks."""
    s = StreamingChecksum()
    size = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(_SCAN_CHUNK)
            if not chunk:
                break
            size += len(chunk)
            s.update(chunk)
    return size, s.digest()


@dataclass
class Manifest:
    """Checksums + sizes for a dataset (or checkpoint) directory tree."""
    entries: Dict[str, Tuple[int, int]] = field(default_factory=dict)  # path -> (size, csum)

    @classmethod
    def scan(cls, root: str) -> "Manifest":
        m = cls()
        for dirpath, _, files in os.walk(root):
            for fn in sorted(files):
                p = os.path.join(dirpath, fn)
                rel = os.path.relpath(p, root)
                m.entries[rel] = stream_file_checksum(p)
        return m

    def verify_many(self, root: str,
                    rels: Optional[Iterable[str]] = None) -> Dict[str, dict]:
        """Batched (partial-scrub) verification: check ``rels`` — any subset
        of the manifest's entries, default all — and report BOTH the size and
        checksum status of every file checked, even when the size already
        mismatches.  Returns ``{relpath: {"ok", "size_ok", "checksum_ok",
        "problem"}}``; scrub engines call this with one batch of files per
        pass instead of walking the whole manifest serially."""
        report: Dict[str, dict] = {}
        for rel in (self.entries if rels is None else rels):
            size, csum = self.entries[rel]
            p = os.path.join(root, rel)
            if not os.path.exists(p):
                report[rel] = {"ok": False, "size_ok": False,
                               "checksum_ok": False, "problem": "missing"}
                continue
            got_size, got_csum = stream_file_checksum(p)
            size_ok = got_size == size
            csum_ok = got_csum == csum
            problems = []
            if not size_ok:
                problems.append(f"size {got_size} != {size}")
            if not csum_ok:
                problems.append("checksum mismatch")
            report[rel] = {"ok": size_ok and csum_ok, "size_ok": size_ok,
                           "checksum_ok": csum_ok,
                           "problem": "; ".join(problems)}
        return report

    def verify(self, root: str) -> Dict[str, str]:
        """Returns {relpath: problem} for every mismatch; empty dict == clean."""
        return {rel: r["problem"]
                for rel, r in self.verify_many(root).items() if not r["ok"]}

    # ------------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({k: list(v) for k, v in self.entries.items()}, f)

    @classmethod
    def load(cls, path: str) -> "Manifest":
        with open(path) as f:
            raw = json.load(f)
        return cls(entries={k: (int(v[0]), int(v[1])) for k, v in raw.items()})

    @property
    def total_bytes(self) -> int:
        return sum(s for s, _ in self.entries.values())
