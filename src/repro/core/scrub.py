"""Silent-corruption injection and scrub/repair campaigns (paper §5 / C3).

The paper's campaign checksummed every one of 29 M files at source and
destination and retransmitted corrupted ones; long-lived replicas then need
the same treatment *over time* — media rots silently, and only periodic
re-verification (a "scrub") finds it.  This module adds both halves:

  * **Latent corruption**: when a replica lands (its row turns SUCCEEDED),
    a seeded per-(dataset, destination, incarnation) draw from
    ``FaultInjector.latent_corrupt_offsets`` decides which byte offsets rot
    on the destination media.  These blocks *survived* transfer — the
    in-flight ``INTEGRITY`` retransmit already caught wire corruption — and
    are detectable only by re-reading the replica.  The draw is a pure
    function of the campaign seed, so it is bit-identical across processes
    and never perturbs the shared transient-fault RNG stream.

  * **Scrub engine**: ``ScrubEngine`` schedules periodic re-verification
    passes on the sim clock (the ``ControlPlane`` interval-anchoring shape).
    Each pass selects a byte-budgeted batch of replicas round-robin via one
    ``np.cumsum`` + ``np.searchsorted`` — O(active replicas) per pass, never
    O(files) — and localizes corrupt blocks to files by searchsorting the
    draw's byte offsets into the dataset's lognormal file-size partition
    (the ``BundleComposer._file_cumsum`` treatment).  A detected-corrupt
    replica's row is flipped back to FAILED with ``retries=0`` (the
    quarantine re-admission precedent), which re-enters the ordinary
    ``ReplicationScheduler`` retry/relay path: repairs are just re-transfer
    work contending fairly with live replication and demand traffic, and the
    ``ReplicaCatalog`` drops the replica from serving until it re-lands.

Replica integrity states: **clean** (no latent draw), **at-risk** (bad
blocks present, not yet detected), **corrupt** (detected, repair in
flight).  ``summary()`` reports the data-at-risk metric — bytes, files, and
exposure-days (landed -> repaired) — that the dashboard and the
``integrity`` benchmark gate surface.

Like ``DemandSpec``, the default ``NO_SCRUB`` spec compiles to **no engine
at all**: a scenario that does not opt in replays its pre-scrub trajectory
bit-identically.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.faults import FaultInjector, stable_digest
from repro.core.pause import DAY
from repro.core.transfer_table import Status, TransferRecord, TransferTable

TB = 1024 ** 4

Key = Tuple[str, str]                      # (dataset, destination)


@dataclass(frozen=True)
class ScrubSpec:
    """Declarative silent-corruption + scrub configuration.

    ``latent_per_pb`` is the expected number of latently corrupt blocks per
    PB landed (0 = subsystem off).  ``interval_days`` is the scrub cadence;
    0 disables scrubbing while keeping corruption live — the bit-rot
    ablation, where corrupt replicas survive to the end of the campaign.
    ``scan_tb_per_pass`` bounds the bytes re-verified per pass (0 =
    unlimited), which is what stretches detection latency — and therefore
    exposure-days — on large catalogs.
    """
    latent_per_pb: float = 0.0      # E[corrupt blocks] per PB landed; 0 = off
    interval_days: float = 10.0     # scrub cadence; 0 = never scrub (bit rot)
    scan_tb_per_pass: float = 500.0  # re-verification byte budget; 0 = all

    @property
    def enabled(self) -> bool:
        """True when this spec needs a live scrub engine."""
        return self.latent_per_pb > 0

    @property
    def scrubbing(self) -> bool:
        """True when periodic re-verification (and repair) is scheduled."""
        return self.enabled and self.interval_days > 0

    def validate(self) -> None:
        if self.latent_per_pb < 0:
            raise ValueError(
                f"latent_per_pb must be >= 0, got {self.latent_per_pb}")
        if not self.enabled:
            return
        if self.interval_days < 0:
            raise ValueError(
                f"interval_days must be >= 0, got {self.interval_days}")
        if self.scan_tb_per_pass < 0:
            raise ValueError(
                f"scan_tb_per_pass must be >= 0, got {self.scan_tb_per_pass}")


NO_SCRUB = ScrubSpec()


class ScrubEngine:
    """Tracks every replica's integrity state off the transfer table's
    listener stream, runs cadenced scrub passes, and routes repairs through
    the ordinary scheduler retry path by flipping corrupt rows to FAILED."""

    def __init__(self, spec: ScrubSpec, catalog: Dict[str, object],
                 table: TransferTable, injector: FaultInjector,
                 source: str, replicas, label: str = ""):
        self.spec = spec
        self.catalog = catalog          # live reference: top-ups route too
        self.table = table
        self.injector = injector
        self.source = source
        self.replicas = tuple(replicas)
        self.label = label
        # scrub-pass scheduling (ControlPlane interval anchoring)
        self._anchor: Optional[float] = None
        self._next_scan = math.inf
        self._cursor = 0                # round-robin position over replicas
        self._now = 0.0
        # integrity ledger: landed-at sim time per replica with bad blocks
        self._incarnation: Dict[Key, int] = {}   # SUCCEEDED landings per key
        self._at_risk: Dict[Key, float] = {}     # undetected bad blocks
        self._repairing: Dict[Key, float] = {}   # detected; re-transfer queued
        # cached lognormal file partitions (size cumsums), built lazily per
        # corrupt dataset.  The pool is bounded: repairs usually revisit the
        # same few datasets, but a long campaign can eventually corrupt every
        # dataset in a 29M-file catalog, and an unbounded cache would grow
        # O(catalog files).  Entries beyond the budget are recomputed
        # transiently — same draw, same result, O(one manifest) memory.
        self._file_parts: Dict[str, np.ndarray] = {}
        self._file_part_entries = 0
        # counters
        self.scans = 0                  # completed scrub passes
        self.scanned_replicas = 0
        self.scanned_bytes = 0
        self.detected = 0               # corrupt replicas found by scans
        self.repaired = 0               # corrupt replicas re-landed clean
        self.corrupt_files = 0          # corrupt files localized, cumulative
        self.corrupt_bytes = 0          # their sizes, cumulative
        self._exposure_days = 0.0       # closed exposure (repaired replicas)
        # flight-recorder seam: called after each scrub pass with (now,
        # pass stats); plain attribute, None compiles to no observation
        self.obs_hook = None
        table.add_listener(self._on_row)
        # adopt rows that predate this engine (checkpoint resume: the
        # restored table already carries the campaign's history; a following
        # load_state_dict replaces the ledger with the snapshot's truth)
        for rec in table.all():
            self._on_row(rec, None, None)

    # --------------------------------------------------------------- listener
    def _on_row(self, rec: TransferRecord, old_status: Optional[Status],
                old_source: Optional[str]) -> None:
        if rec.status is not Status.SUCCEEDED or \
                old_status is Status.SUCCEEDED:
            return
        key = (rec.dataset, rec.destination)
        inc = self._incarnation.get(key, 0) + 1
        self._incarnation[key] = inc
        landed_at = self._repairing.pop(key, None)
        if landed_at is not None:       # a repair re-transfer just landed
            self.repaired += 1
            done_at = rec.completed if rec.completed is not None else self._now
            self._exposure_days += max(0.0, done_at - landed_at) / DAY
        ds = self.catalog.get(rec.dataset)
        if ds is None:
            return                      # not a scrubbed catalog entry
        offs = self.injector.latent_corrupt_offsets(
            rec.dataset, rec.destination, ds.bytes, self.spec.latent_per_pb,
            incarnation=inc)
        now = rec.completed if rec.completed is not None else self._now
        if len(offs):
            self._at_risk[key] = now
        else:
            self._at_risk.pop(key, None)

    # -------------------------------------------------------------- scheduling
    def step(self, now: float) -> None:
        """Run any due scrub pass.  Called once per driver iteration, before
        the scheduler step, so repair flips are dispatched the same pass."""
        self._now = now
        if not self.spec.scrubbing:
            return
        if self._anchor is None:
            self._anchor = now
            self._next_scan = now + self.spec.interval_days * DAY
            return
        while now >= self._next_scan:
            self._run_pass(now)
            self._next_scan += self.spec.interval_days * DAY

    def next_action(self, now: float) -> float:
        """Absolute sim time of the next scheduled scrub pass (inf when
        scrubbing is off or not yet anchored) — a ``run_world`` next-event
        candidate, so an otherwise-idle world hops straight to the scan."""
        if not self.spec.scrubbing or self._anchor is None:
            return math.inf
        return self._next_scan

    def exhausted(self) -> bool:
        """True when no replica holds undetected or unrepaired bad blocks —
        the campaign-completion condition.  A corruption-only spec
        (``interval_days=0``) is always exhausted: nothing will ever detect
        the rot, and the campaign ends with replicas still at risk (the
        bit-rot ablation's surviving-corruption measurement)."""
        if not self.spec.scrubbing:
            return True
        return not self._at_risk and not self._repairing

    # ------------------------------------------------------------- scrub pass
    def _scan_order(self) -> Tuple[List[Key], np.ndarray]:
        """Every scrubbable SUCCEEDED replica in canonical (site, dataset)
        order, with its byte size — the pass's selection universe."""
        keys: List[Key] = []
        sizes: List[int] = []
        for dest in self.replicas:
            for name in sorted(self.table.succeeded_set(dest)):
                ds = self.catalog.get(name)
                if ds is None:
                    continue
                keys.append((name, dest))
                sizes.append(ds.bytes)
        return keys, np.asarray(sizes, dtype=np.int64)

    def _run_pass(self, now: float) -> None:
        """One byte-budgeted re-verification batch: rotate the cursor over
        the replica universe, cut the batch with cumsum/searchsorted, and
        flip every at-risk replica the batch covers into the repair path."""
        self.scans += 1
        keys, sizes = self._scan_order()
        n = len(keys)
        if n == 0:
            if self.obs_hook is not None:
                self.obs_hook(now, {"pass": self.scans, "scanned": 0,
                                    "detected": 0})
            return
        start = self._cursor % n
        order = (start + np.arange(n)) % n
        csum = np.cumsum(sizes[order])
        budget = (self.spec.scan_tb_per_pass * TB
                  if self.spec.scan_tb_per_pass > 0 else math.inf)
        k = max(1, int(np.searchsorted(csum, budget, side="right")))
        k = min(k, n)
        self._cursor = (start + k) % n
        self.scanned_replicas += k
        self.scanned_bytes += int(csum[k - 1])
        repairs = []
        for i in order[:k]:
            key = keys[int(i)]
            landed_at = self._at_risk.pop(key, None)
            if landed_at is None:
                continue                # verified clean
            self._repairing[key] = landed_at
            self.detected += 1
            nfiles, nbytes = self._localize(key)
            self.corrupt_files += nfiles
            self.corrupt_bytes += nbytes
            repairs.append((key[0], key[1],
                            dict(status=Status.FAILED, retries=0)))
        if repairs:
            # FAILED + retries=0 is the quarantine re-admission shape: the
            # scheduler's row listener re-queues each repair, the relay
            # planner stops using the corrupt copy as a donor, and the
            # replica catalog marks it unserveable until it re-lands
            self.table.update_many(repairs)
        if self.obs_hook is not None:
            self.obs_hook(now, {"pass": self.scans, "scanned": k,
                                "detected": len(repairs),
                                "at_risk": len(self._at_risk)})

    # cached file-partition budget: total file entries held across all
    # cached cumsums.  ~16 MB of int64 — O(active corruptions), not O(files).
    FILE_PART_BUDGET = 2_000_000

    def _file_csum(self, name: str, nf: int, nbytes: int) -> np.ndarray:
        """The dataset's synthesized file-size cumsum (the
        ``BundleComposer._file_cumsum`` treatment, keyed by name so it is
        stable under catalog growth).  Cached under ``FILE_PART_BUDGET``;
        oversized or overflow entries are recomputed per call."""
        csum = self._file_parts.get(name)
        if csum is not None:
            return csum
        rng = np.random.default_rng([self.injector.seed, stable_digest(name)])
        w = rng.lognormal(mean=0.0, sigma=1.2, size=nf)
        w /= w.sum()
        sizes = np.floor(w * nbytes).astype(np.int64)
        sizes[0] += nbytes - int(sizes.sum())
        csum = np.cumsum(sizes)
        if nf <= self.FILE_PART_BUDGET // 4:
            if self._file_part_entries + nf > self.FILE_PART_BUDGET:
                self._file_parts.clear()
                self._file_part_entries = 0
            self._file_parts[name] = csum
            self._file_part_entries += nf
        return csum

    def _localize(self, key: Key) -> Tuple[int, int]:
        """Corrupt (files, bytes) for a detected replica: searchsort the
        draw's byte offsets into the dataset's file-size cumsum — per-block
        array ops charged per run, with the per-file remainder recovered
        exactly from adjacent cumsum entries.  No per-file walk, no
        materialized per-file size array."""
        name, dest = key
        ds = self.catalog[name]
        offs = self.injector.latent_corrupt_offsets(
            name, dest, ds.bytes, self.spec.latent_per_pb,
            incarnation=self._incarnation[key])
        csum = self._file_csum(name, max(1, int(ds.files)), ds.bytes)
        idx = np.unique(np.searchsorted(csum, offs, side="right"))
        idx = idx[idx < len(csum)]
        if not len(idx):
            return 0, 0
        lo = np.where(idx > 0, csum[idx - 1], 0)
        return int(len(idx)), int((csum[idx] - lo).sum())

    # ---------------------------------------------------------------- metrics
    def summary(self) -> dict:
        """The data-at-risk view: live integrity states plus cumulative scrub
        and repair counters.  ``exposure_days`` sums landed->repaired spans
        for repaired replicas and landed->now for replicas still dirty, in
        canonical key order (bit-stable across processes and resumes)."""
        live = dict(self._at_risk)
        live.update(self._repairing)
        exposure = self._exposure_days
        at_risk_bytes = 0
        for key in sorted(live):
            exposure += max(0.0, self._now - live[key]) / DAY
            ds = self.catalog.get(key[0])
            at_risk_bytes += ds.bytes if ds is not None else 0
        return {
            "scans": self.scans,
            "scanned_replicas": self.scanned_replicas,
            "scanned_bytes": self.scanned_bytes,
            "detected": self.detected,
            "repaired": self.repaired,
            "at_risk_replicas": len(self._at_risk),
            "repairing_replicas": len(self._repairing),
            "data_at_risk_bytes": at_risk_bytes,
            "corrupt_files": self.corrupt_files,
            "corrupt_bytes": self.corrupt_bytes,
            "exposure_days": round(exposure, 6),
            "clean": not self._at_risk and not self._repairing,
        }

    # ------------------------------------------------------------ checkpoints
    def state_dict(self) -> dict:
        return {
            "anchor": self._anchor,
            "next_scan": (None if math.isinf(self._next_scan)
                          else self._next_scan),
            "cursor": self._cursor,
            "now": self._now,
            "incarnation": [[d, r, i] for (d, r), i in
                            sorted(self._incarnation.items())],
            "at_risk": [[d, r, t] for (d, r), t in
                        sorted(self._at_risk.items())],
            "repairing": [[d, r, t] for (d, r), t in
                          sorted(self._repairing.items())],
            "counters": {
                "scans": self.scans,
                "scanned_replicas": self.scanned_replicas,
                "scanned_bytes": self.scanned_bytes,
                "detected": self.detected,
                "repaired": self.repaired,
                "corrupt_files": self.corrupt_files,
                "corrupt_bytes": self.corrupt_bytes,
                "exposure_days": self._exposure_days,
            },
        }

    def load_state_dict(self, d: dict) -> None:
        self._anchor = d["anchor"]
        self._next_scan = (math.inf if d["next_scan"] is None
                           else float(d["next_scan"]))
        self._cursor = int(d["cursor"])
        self._now = float(d["now"])
        self._incarnation = {(ds, r): int(i) for ds, r, i in d["incarnation"]}
        self._at_risk = {(ds, r): float(t) for ds, r, t in d["at_risk"]}
        self._repairing = {(ds, r): float(t) for ds, r, t in d["repairing"]}
        c = d["counters"]
        self.scans = int(c["scans"])
        self.scanned_replicas = int(c["scanned_replicas"])
        self.scanned_bytes = int(c["scanned_bytes"])
        self.detected = int(c["detected"])
        self.repaired = int(c["repaired"])
        self.corrupt_files = int(c["corrupt_files"])
        self.corrupt_bytes = int(c["corrupt_bytes"])
        self._exposure_days = float(c["exposure_days"])
