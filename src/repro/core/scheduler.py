"""The replication scheduler — a faithful implementation of paper Figure 4,
generalized to N replica sites.

Figure 4 logic (2291 ESGF paths × 2 destinations):
  1.  populate table with (dataset, LLNL→ALCF) and (dataset, LLNL→OLCF), NULL.
  2a. start source→primary transfers while < 2 active on the route.
  2b. poll actives; mark SUCCEEDED/FAILED.
  2c. if any transfer to primary is PAUSED, start source→secondary instead.
  2d. start replica→replica relays for datasets present at one LCF only.
  2e. symmetric relay in the other direction.
  2f. terminate when no row is NULL/ACTIVE/FAILED/PAUSED.

Key properties preserved from the paper:
  * ≤ ``max_active_per_route`` concurrent transfers per route, so one
    transfer's metadata scan overlaps another's data movement (C5);
  * the slow source is read once per dataset whenever a relay is possible (C2);
  * FAILED rows are retried with bounded retries, then QUARANTINED with a
    notification (C3);
  * re-routing rewrites the row's *source*, never loses the row (C4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.faults import Notifier, RetryPolicy
from repro.core.routes import Dataset, RouteGraph
from repro.core.transfer_table import (RETRYABLE, Status, TransferRecord,
                                       TransferTable)
from repro.core.transport import Transport


@dataclass
class ReplicationPolicy:
    source: str                       # e.g. "LLNL"
    replicas: Sequence[str]           # priority order, e.g. ("ALCF", "OLCF")
    max_active_per_route: int = 2     # paper: two per route (scan/move overlap)


OCCUPYING = (Status.ACTIVE, Status.QUEUED, Status.PAUSED)


class ReplicationScheduler:
    def __init__(self, table: TransferTable, transport: Transport,
                 catalog: Dict[str, Dataset], policy: ReplicationPolicy,
                 retry: RetryPolicy = RetryPolicy(),
                 notifier: Optional[Notifier] = None):
        self.table = table
        self.transport = transport
        self.catalog = catalog
        self.policy = policy
        self.retry = retry
        self.notifier = notifier or Notifier()
        self._backoff_until: Dict[Tuple[str, str], float] = {}

    # ------------------------------------------------------------------ setup
    def populate(self) -> int:
        return self.table.populate(
            sorted(self.catalog), self.policy.source, list(self.policy.replicas))

    # ------------------------------------------------------------------- step
    def step(self, now: float) -> List[str]:
        """One pass of the Figure-4 loop.  Returns human-readable actions."""
        actions: List[str] = []
        self._poll(now, actions)                                  # 2b
        pol = self.policy
        primary = pol.replicas[0]
        self._start_route(pol.source, primary, now, actions)      # 2a
        if self._any_paused(primary):                             # 2c
            for sec in pol.replicas[1:]:
                self._start_route(pol.source, sec, now, actions)
        self._start_relays(now, actions)                          # 2d / 2e
        return actions

    def done(self) -> bool:                                       # 2f
        return self.table.done()

    # ----------------------------------------------------------------- 2b poll
    def _poll(self, now: float, actions: List[str]) -> None:
        updates: List[Tuple[str, str, dict]] = []
        for rec in self.table.by_status(Status.ACTIVE, Status.QUEUED, Status.PAUSED):
            st = self.transport.poll(rec.uuid)
            upd = dict(bytes_transferred=st.bytes_done, files=st.files_done,
                       directories=st.dirs_done, faults=st.faults, rate=st.rate)
            if st.status == Status.SUCCEEDED:
                upd.update(status=Status.SUCCEEDED, completed=now)
                actions.append(f"SUCCEEDED {rec.source}->{rec.destination} {rec.dataset}")
            elif st.status == Status.FAILED:
                retries = rec.retries + 1
                if retries > self.retry.max_retries:
                    upd.update(status=Status.QUARANTINED, retries=retries)
                    self.notifier.notify(
                        f"transfer {rec.dataset} -> {rec.destination} exceeded "
                        f"{self.retry.max_retries} retries ({st.detail})",
                        rec.dataset)
                    actions.append(f"QUARANTINED {rec.dataset} -> {rec.destination}")
                else:
                    upd.update(status=Status.FAILED, retries=retries)
                    self._backoff_until[(rec.dataset, rec.destination)] = (
                        now + self.retry.backoff_s)
                    actions.append(f"FAILED (retry {retries}) {rec.dataset} "
                                   f"-> {rec.destination}: {st.detail}")
            else:
                upd.update(status=st.status)
            updates.append((rec.dataset, rec.destination, upd))
        # one transaction for the whole poll pass, not one commit per live row
        self.table.update_many(updates)

    # ------------------------------------------------------------ route starts
    def _slots(self, src: str, dst: str) -> int:
        used = self.table.count_route(src, dst, *OCCUPYING)
        return max(0, self.policy.max_active_per_route - used)

    def _eligible(self, dst: str, now: float,
                  require_source: Optional[str] = None) -> List[TransferRecord]:
        rows = self.table.by_status(*RETRYABLE, destination=dst)
        # paper §5: quarantined transfers are re-admitted once the human has
        # fixed the underlying problem (permissions, fs config)
        for r in self.table.by_status(Status.QUARANTINED, destination=dst):
            if self.notifier.is_fixed(r.dataset):
                self.table.update(r.dataset, r.destination,
                                  status=Status.FAILED, retries=0)
                r.status = Status.FAILED
                r.retries = 0
                rows.append(r)
        out = []
        for r in rows:
            if require_source is not None and r.source != require_source:
                continue
            if self._backoff_until.get((r.dataset, r.destination), 0.0) > now:
                continue
            out.append(r)
        return out

    def _start(self, rec: TransferRecord, src: str, now: float,
               actions: List[str]) -> None:
        ds = self.catalog[rec.dataset]
        uid = self.transport.submit(ds, src, rec.destination)
        self.table.update(rec.dataset, rec.destination, source=src, uuid=uid,
                          requested=now, status=Status.ACTIVE)
        actions.append(f"START {src}->{rec.destination} {rec.dataset}")

    def _start_route(self, src: str, dst: str, now: float,
                     actions: List[str]) -> None:
        slots = self._slots(src, dst)
        if slots <= 0:
            return
        for rec in self._eligible(dst, now, require_source=src)[:slots]:
            self._start(rec, src, now, actions)

    # -------------------------------------------------------------- 2d/2e relay
    def _start_relays(self, now: float, actions: List[str]) -> None:
        pol = self.policy
        have: Dict[str, set] = {r: set(self.table.succeeded_datasets(r))
                                for r in pol.replicas}
        for dst in pol.replicas:
            # datasets succeeded at some other replica but still outstanding here
            needed = self._eligible(dst, now)
            for rec in needed:
                donors = [r for r in pol.replicas
                          if r != dst and rec.dataset in have[r]]
                if not donors:
                    continue
                donor = donors[0]
                if self._slots(donor, dst) <= 0:
                    continue
                self._start(rec, donor, now, actions)

    # ---------------------------------------------------------------- helpers
    def _any_paused(self, dst: str) -> bool:
        return len(self.table.by_status(Status.PAUSED, destination=dst)) > 0

    # ------------------------------------------------------- next-event hints
    def next_backoff_expiry(self, now: float) -> float:
        """Earliest future retry-backoff expiry (event-driven simulation
        hint); ``inf`` when no failed transfer is waiting out a backoff."""
        ts = [t for t in self._backoff_until.values() if t > now]
        return min(ts) if ts else float("inf")
