"""The replication scheduler — a faithful implementation of paper Figure 4,
generalized to N replica sites.

Figure 4 logic (2291 ESGF paths × 2 destinations):
  1.  populate table with (dataset, LLNL→ALCF) and (dataset, LLNL→OLCF), NULL.
  2a. start source→primary transfers while < 2 active on the route.
  2b. poll actives; mark SUCCEEDED/FAILED.
  2c. if any transfer to primary is PAUSED, start source→secondary instead.
  2d. start replica→replica relays for datasets present at one LCF only.
  2e. symmetric relay in the other direction.
  2f. terminate when no row is NULL/ACTIVE/FAILED/PAUSED.

Key properties preserved from the paper:
  * ≤ ``max_active_per_route`` concurrent transfers per route, so one
    transfer's metadata scan overlaps another's data movement (C5);
  * the slow source is read once per dataset whenever a relay is possible (C2);
  * FAILED rows are retried with bounded retries, then QUARANTINED with a
    notification (C3);
  * re-routing rewrites the row's *source*, never loses the row (C4).

Per-step cost is O(live transfers), not O(catalog): instead of re-SELECTing
the table every pass, the scheduler subscribes to ``TransferTable`` row
transitions and maintains

  * per-destination min-heaps of datasets startable from the source
    (``_direct``), popped lazily in dataset order — the order the old
    ``SELECT ... ORDER BY dataset`` produced;
  * per-(destination, donor) heaps of relay candidates (``_relay``): a
    dataset enters when it SUCCEEDs at some replica while still outstanding
    elsewhere, bucketed by the donor the Figure-4 scan would pick (the
    first succeeded replica in priority order);
  * a retry-backoff min-heap with expired entries pruned on the way out.

Heap entries are validated against the live row when popped (lazy deletion),
so stale entries cost O(log n) once and the common-case step touches only
rows that can actually change state.

Determinism invariants (relied on by snapshots, the engine-equivalence tests,
and the ensemble lanes engine):

* **Submission order is the RNG order.**  Every ``_start`` calls
  ``transport.submit``, which consumes the shared fault stream; therefore
  the order rows are started — direct pops in (priority, dataset) order per
  destination, primary before secondaries, relays in replica/donor priority
  order, re-admitted quarantined rows strictly after the ordinary eligibles
  of the same pass — is part of the trajectory, not an implementation
  detail.
* **Poll order is (dataset, destination) order.**  ``_poll`` walks
  ``by_status`` rows in sorted order and commits one batched transaction,
  so listener-driven queue insertions happen in a reproducible sequence.
* **Retry disposition is a pure function** (``retry_disposition``): a
  FAILED poll result maps to (retries+1, QUARANTINED-vs-FAILED) from the
  row's retry count and the policy alone, with no hidden state.
* **Relay donors are historical.**  A relay candidate is bucketed under the
  donor ``_first_donor`` picked when it was *enqueued* and only migrates
  when popped; with ≤ 2 replicas the donor is unique and the bucketing is a
  pure function of table state — the property the ensemble lanes engine
  asserts before vectorizing.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple,
                    Union)

from repro.core.faults import Notifier, RetryPolicy
from repro.core.routes import Dataset, RouteGraph
from repro.core.transfer_table import (RETRYABLE, Status, TransferRecord,
                                       TransferTable)
from repro.core.transport import Transport


@dataclass
class ReplicationPolicy:
    source: str                       # e.g. "LLNL"
    replicas: Sequence[str]           # priority order, e.g. ("ALCF", "OLCF")
    max_active_per_route: int = 2     # paper: two per route (scan/move overlap)
    # live per-route overrides, written by the control plane's concurrency
    # tuner (repro.control) and serialized in its snapshot block; routes
    # without an entry use the static ``max_active_per_route``
    route_caps: Dict[Tuple[str, str], int] = field(default_factory=dict)

    def cap(self, source: str, destination: str) -> int:
        return self.route_caps.get((source, destination),
                                   self.max_active_per_route)


OCCUPYING = (Status.ACTIVE, Status.QUEUED, Status.PAUSED)
_RETRYABLE_SET = frozenset(RETRYABLE)


def retry_disposition(retries_done, max_retries):
    """Pure retry/quarantine rule for a FAILED poll result: returns
    ``(retries, quarantine)`` where ``retries`` is the incremented count and
    ``quarantine`` is True once it exceeds ``max_retries``.  Elementwise on
    arrays (numpy/jax) so the ensemble lanes engine applies the identical
    rule to a whole batch of worlds at once."""
    retries = retries_done + 1
    return retries, retries > max_retries

# direct-queue heap entry: a bare dataset name (dataset order, the seed
# model) or a (priority, dataset) pair once a priority function is installed
_DirectEntry = Union[str, Tuple[int, str]]


def _entry_ds(entry: _DirectEntry) -> str:
    return entry if isinstance(entry, str) else entry[1]


class ReplicationScheduler:
    def __init__(self, table: TransferTable, transport: Transport,
                 catalog: Dict[str, Dataset], policy: ReplicationPolicy,
                 retry: RetryPolicy = RetryPolicy(),
                 notifier: Optional[Notifier] = None):
        self.table = table
        self.transport = transport
        self.catalog = catalog
        self.policy = policy
        self.retry = retry
        self.notifier = notifier or Notifier()
        self._backoff_until: Dict[Tuple[str, str], float] = {}
        self._backoff_heap: List[Tuple[float, Tuple[str, str]]] = []
        # per-destination queues of datasets startable direct from the source
        self._direct: Dict[str, List[_DirectEntry]] = {}
        self._direct_member: Dict[str, Set[str]] = {}
        # optional dataset -> priority mapping (lower starts first); installed
        # by the demand engine to start popular datasets before catalog order
        self._priority: Optional[Callable[[str], int]] = None
        # per-(destination, donor) relay-candidate queues
        self._relay: Dict[Tuple[str, str], List[str]] = {}
        self._relay_donor: Dict[str, Dict[str, str]] = {}  # dst -> ds -> donor
        # when re-admitting quarantined rows, the listener diverts their
        # queue insertions here: Figure 4's scan considers them *after* the
        # ordinary eligible rows of the same pass (they were appended to the
        # SELECT result), and submit order feeds the shared fault RNG, so the
        # placement must be preserved exactly
        self._defer_queue: Optional[List[str]] = None
        table.add_listener(self._on_row)
        # adopt rows that predate this scheduler (e.g. a table re-opened from
        # disk); normally the table is empty here and this is a no-op
        for rec in table.all():
            self._on_row(rec, None, None)

    # ------------------------------------------------------------------ setup
    def populate(self) -> int:
        return self.table.populate(
            sorted(self.catalog), self.policy.source, list(self.policy.replicas))

    # --------------------------------------------------------------- priority
    def set_priority(self, fn: Optional[Callable[[str], int]]) -> None:
        """Install (or clear, with None) a dataset-priority function for the
        direct-start queues: lower values start first, ties break in dataset
        order via the (priority, dataset) heap entry.  Existing entries are
        re-keyed in place, so this works whether the queues were populated
        before or after installation."""
        self._priority = fn
        self.reprioritize()

    def reprioritize(self) -> None:
        """Rebuild every direct heap under the current priority function —
        the demand engine calls this when popularity drifts.  Entry
        *multiset* is preserved (including lazy-stale entries); only the pop
        order changes."""
        for dst, heap in self._direct.items():
            self._direct[dst] = rebuilt = [
                self._direct_entry(_entry_ds(e)) for e in heap]
            heapq.heapify(rebuilt)

    def _direct_entry(self, ds: str) -> _DirectEntry:
        if self._priority is None:
            return ds
        return (int(self._priority(ds)), ds)

    # ------------------------------------------------------------------- step
    def step(self, now: float) -> List[str]:
        """One pass of the Figure-4 loop.  Returns human-readable actions."""
        actions: List[str] = []
        self._poll(now, actions)                                  # 2b
        pol = self.policy
        primary = pol.replicas[0]
        self._start_route(pol.source, primary, now, actions)      # 2a
        if self._any_paused(primary):                             # 2c
            for sec in pol.replicas[1:]:
                self._start_route(pol.source, sec, now, actions)
        self._start_relays(now, actions)                          # 2d / 2e
        return actions

    def done(self) -> bool:                                       # 2f
        return self.table.done()

    def teardown(self) -> int:
        """Cancel every transfer this scheduler still has in flight
        (slot-occupying rows), releasing their route/site fair shares to
        whoever else is using the transport — the shutdown path a federated
        campaign takes when it ends (completes or times out) while other
        campaigns keep running.  The table rows are left as they are: the
        report shows exactly how far the campaign got.  Returns the number
        of transfers cancelled."""
        n = 0
        for rec in self.table.by_status(*OCCUPYING):
            if rec.uuid is not None:
                self.transport.cancel(rec.uuid)
                n += 1
        return n

    # ----------------------------------------------------- incremental state
    def _on_row(self, rec: TransferRecord, old_status: Optional[Status],
                old_source: Optional[str]) -> None:
        """TransferTable listener: keep the pending queues current.  Heaps
        hold dataset names; entries going stale (row started elsewhere,
        succeeded, quarantined) are dropped lazily when popped."""
        if rec.status in _RETRYABLE_SET:
            if self._defer_queue is not None:
                self._defer_queue.append(rec.dataset)
                return
            self._queue_row(rec)
        elif rec.status == Status.SUCCEEDED and old_status != Status.SUCCEEDED:
            self._on_success(rec.dataset, rec.destination)

    def _queue_row(self, rec: TransferRecord) -> None:
        """Enter a retryable row into the direct and/or relay queues."""
        dst = rec.destination
        if rec.source == self.policy.source:
            member = self._direct_member.setdefault(dst, set())
            if rec.dataset not in member:
                member.add(rec.dataset)
                heapq.heappush(self._direct.setdefault(dst, []),
                               self._direct_entry(rec.dataset))
        donor = self._first_donor(rec.dataset, dst)
        if donor is not None:
            self._relay_add(dst, rec.dataset, donor)

    def _on_success(self, dataset: str, destination: str) -> None:
        """A dataset just landed at ``destination``: every other replica
        still holding a retryable row for it gains a relay candidate."""
        for dst in self.policy.replicas:
            if dst == destination:
                continue
            rec = self.table.peek(dataset, dst)
            if rec is None or rec.status not in _RETRYABLE_SET:
                continue
            donor = self._first_donor(dataset, dst)
            if donor is not None:
                self._relay_add(dst, dataset, donor)

    def _first_donor(self, dataset: str, dst: str) -> Optional[str]:
        """The donor Figure 4's relay scan would pick: the first replica in
        priority order (≠ dst) that already holds the dataset."""
        for r in self.policy.replicas:
            if r != dst and dataset in self.table.succeeded_set(r):
                return r
        return None

    def _relay_add(self, dst: str, dataset: str, donor: str) -> None:
        tracked = self._relay_donor.setdefault(dst, {})
        if tracked.get(dataset) == donor:
            return
        tracked[dataset] = donor
        heapq.heappush(self._relay.setdefault((dst, donor), []), dataset)

    # ----------------------------------------------------------------- 2b poll
    def _poll(self, now: float, actions: List[str]) -> None:
        updates: List[Tuple[str, str, dict]] = []
        for rec in self.table.by_status(Status.ACTIVE, Status.QUEUED, Status.PAUSED):
            st = self.transport.poll(rec.uuid)
            upd = dict(bytes_transferred=st.bytes_done, files=st.files_done,
                       directories=st.dirs_done, faults=st.faults, rate=st.rate)
            if st.status == Status.SUCCEEDED:
                upd.update(status=Status.SUCCEEDED, completed=now)
                actions.append(f"SUCCEEDED {rec.source}->{rec.destination} {rec.dataset}")
            elif st.status == Status.FAILED:
                retries, quarantine = retry_disposition(
                    rec.retries, self.retry.max_retries)
                if quarantine:
                    upd.update(status=Status.QUARANTINED, retries=retries)
                    # release any transport-side residue of the quarantined
                    # transfer (no-op for transports whose FAILED is terminal)
                    self.transport.cancel(rec.uuid)
                    self.notifier.notify(
                        f"transfer {rec.dataset} -> {rec.destination} exceeded "
                        f"{self.retry.max_retries} retries ({st.detail})",
                        rec.dataset)
                    actions.append(f"QUARANTINED {rec.dataset} -> {rec.destination}")
                else:
                    upd.update(status=Status.FAILED, retries=retries)
                    self._set_backoff((rec.dataset, rec.destination),
                                      now + self.retry.backoff_s)
                    actions.append(f"FAILED (retry {retries}) {rec.dataset} "
                                   f"-> {rec.destination}: {st.detail}")
            else:
                upd.update(status=st.status)
            updates.append((rec.dataset, rec.destination, upd))
        # one transaction for the whole poll pass, not one commit per live row;
        # the table listener (_on_row) re-queues failures and registers relay
        # candidates for completions
        self.table.update_many(updates)

    # ------------------------------------------------------------ route starts
    def _slots(self, src: str, dst: str) -> int:
        used = self.table.count_route(src, dst, *OCCUPYING)
        return max(0, self.policy.cap(src, dst) - used)

    def _readmit_quarantined(self, dst: str) -> List[str]:
        """Paper §5: quarantined transfers are re-admitted once the human has
        fixed the underlying problem (permissions, fs config).  One batched
        transaction instead of one commit per re-admitted row.  Returns the
        re-admitted datasets in dataset order; the listener's queue pushes
        are deferred, because this pass must consider them *after* its
        ordinary eligible rows (the caller re-queues whatever it does not
        start)."""
        updates = [(r.dataset, r.destination, dict(status=Status.FAILED,
                                                   retries=0))
                   for r in self.table.by_status(Status.QUARANTINED,
                                                 destination=dst)
                   if self.notifier.is_fixed(r.dataset)]
        if not updates:
            return []
        self._defer_queue = tail = []
        try:
            self.table.update_many(updates)
        finally:
            self._defer_queue = None
        return tail

    def _backoff_active(self, key: Tuple[str, str], now: float) -> bool:
        """True while the row is still waiting out a retry backoff; prunes
        the entry once it has expired."""
        t = self._backoff_until.get(key, 0.0)
        if t > now:
            return True
        if t:
            del self._backoff_until[key]
        return False

    def _set_backoff(self, key: Tuple[str, str], until: float) -> None:
        self._backoff_until[key] = until
        heapq.heappush(self._backoff_heap, (until, key))

    def _start(self, rec: TransferRecord, src: str, now: float,
               actions: List[str]) -> None:
        ds = self.catalog[rec.dataset]
        uid = self.transport.submit(ds, src, rec.destination)
        self.table.update(rec.dataset, rec.destination, source=src, uuid=uid,
                          requested=now, status=Status.ACTIVE)
        actions.append(f"START {src}->{rec.destination} {rec.dataset}")

    def _start_route(self, src: str, dst: str, now: float,
                     actions: List[str]) -> None:
        slots = self._slots(src, dst)
        if slots <= 0:
            return
        heap = self._direct.get(dst)
        if heap:
            member = self._direct_member[dst]
            deferred: List[_DirectEntry] = []
            while heap and slots > 0:
                entry = heapq.heappop(heap)
                ds = _entry_ds(entry)
                rec = self.table.peek(ds, dst)
                if (rec is None or rec.status not in _RETRYABLE_SET
                        or rec.source != src):
                    member.discard(ds)             # stale entry
                    continue
                if self._backoff_active((ds, dst), now):
                    deferred.append(entry)         # still backing off
                    continue
                member.discard(ds)
                self._start(rec, src, now, actions)
                slots -= 1
            for entry in deferred:
                heapq.heappush(heap, entry)
            if not heap:
                # fully drained: drop the key so dispatch passes (and
                # ``reprioritize``) stop iterating dead destinations —
                # ``_queue_row`` recreates it on the next retryable row
                del self._direct[dst]
                self._direct_member.pop(dst, None)
        # freshly re-admitted quarantined rows come after the ordinary
        # eligibles, exactly where Figure 4's scan would see them
        for ds in self._readmit_quarantined(dst):
            rec = self.table.peek(ds, dst)
            if rec is None or rec.status not in _RETRYABLE_SET:
                continue
            if (slots > 0 and rec.source == src
                    and not self._backoff_active((ds, dst), now)):
                self._start(rec, src, now, actions)
                slots -= 1
            else:
                self._queue_row(rec)               # for later passes

    # -------------------------------------------------------------- 2d/2e relay
    def _start_relays(self, now: float, actions: List[str]) -> None:
        pol = self.policy
        for dst in pol.replicas:
            tracked = self._relay_donor.get(dst)
            if tracked:
                for donor in pol.replicas:
                    if donor == dst:
                        continue
                    heap = self._relay.get((dst, donor))
                    if not heap:
                        continue
                    slots = self._slots(donor, dst)
                    deferred: List[str] = []
                    while heap and slots > 0:
                        ds = heapq.heappop(heap)
                        if tracked.get(ds) != donor:
                            continue                # migrated or dropped
                        rec = self.table.peek(ds, dst)
                        if rec is None or rec.status not in _RETRYABLE_SET:
                            del tracked[ds]         # stale entry
                            continue
                        best = self._first_donor(ds, dst)
                        if best != donor:           # an earlier-priority
                            del tracked[ds]         # replica now holds it
                            if best is not None:
                                self._relay_add(dst, ds, best)
                            continue
                        if self._backoff_active((ds, dst), now):
                            deferred.append(ds)
                            continue
                        del tracked[ds]
                        self._start(rec, donor, now, actions)
                        slots -= 1
                    for ds in deferred:
                        heapq.heappush(heap, ds)
                    if not heap:
                        # drained relay bucket: drop the (dst, donor) key —
                        # ``_relay_add`` recreates it on the next candidate
                        del self._relay[(dst, donor)]
                if not tracked:
                    del self._relay_donor[dst]
            # freshly re-admitted rows are scanned after the ordinary
            # eligibles (Figure 4 ordering; see _start_route)
            for ds in self._readmit_quarantined(dst):
                rec = self.table.peek(ds, dst)
                if rec is None or rec.status not in _RETRYABLE_SET:
                    continue
                donor = self._first_donor(ds, dst)
                if (donor is not None and self._slots(donor, dst) > 0
                        and not self._backoff_active((ds, dst), now)):
                    self._start(rec, donor, now, actions)
                else:
                    self._queue_row(rec)            # for later passes

    # ---------------------------------------------------------------- helpers
    def _any_paused(self, dst: str) -> bool:
        return self.table.count_status(Status.PAUSED) > 0 and len(
            self.table.by_status(Status.PAUSED, destination=dst)) > 0

    # ------------------------------------------------------------ checkpoints
    def state_dict(self) -> dict:
        """JSON-serializable copy of the mutable scheduling state: retry
        backoffs (their heap order included), the per-destination direct
        queues, and the relay-candidate queues with their donor tracking.
        Restoring this verbatim — rather than re-deriving queues from the
        table — preserves heap entry order and lazy-stale entries, so a
        resumed campaign pops datasets in exactly the order the killed run
        would have."""
        assert self._defer_queue is None, "snapshot during re-admission pass"
        return {
            "backoff_until": [[ds, dst, t]
                              for (ds, dst), t in self._backoff_until.items()],
            "backoff_heap": [[t, ds, dst]
                             for t, (ds, dst) in self._backoff_heap],
            "direct": {dst: [e if isinstance(e, str) else list(e) for e in h]
                       for dst, h in self._direct.items()},
            "direct_member": {dst: sorted(m)
                              for dst, m in self._direct_member.items()},
            "relay": [[dst, donor, list(h)]
                      for (dst, donor), h in self._relay.items()],
            "relay_donor": {dst: dict(m)
                            for dst, m in self._relay_donor.items()},
        }

    def load_state_dict(self, d: dict) -> None:
        """Overwrite the queue state (normally right after construction over a
        restored table, replacing the constructor's adoption-derived queues
        with the exact serialized ones)."""
        self._backoff_until = {(ds, dst): t for ds, dst, t in d["backoff_until"]}
        self._backoff_heap = [(t, (ds, dst)) for t, ds, dst in d["backoff_heap"]]
        self._direct = {
            dst: [e if isinstance(e, str) else (int(e[0]), e[1]) for e in h]
            for dst, h in d["direct"].items()}
        self._direct_member = {dst: set(m)
                               for dst, m in d["direct_member"].items()}
        self._relay = {(dst, donor): list(h) for dst, donor, h in d["relay"]}
        self._relay_donor = {dst: dict(m)
                             for dst, m in d["relay_donor"].items()}

    # ------------------------------------------------------- next-event hints
    def next_backoff_expiry(self, now: float) -> float:
        """Earliest future retry-backoff expiry (event-driven simulation
        hint); ``inf`` when no failed transfer is waiting out a backoff.
        Expired and superseded heap entries are pruned on the way out."""
        heap = self._backoff_heap
        while heap:
            t, key = heap[0]
            current = self._backoff_until.get(key)
            if current != t:                        # superseded entry
                heapq.heappop(heap)
                continue
            if t <= now:                            # expired: prune
                heapq.heappop(heap)
                del self._backoff_until[key]
                continue
            return t
        return float("inf")

    # ------------------------------------------------------- observability
    def backoff_depth(self) -> int:
        """Failed transfers currently waiting out a retry backoff (read-only
        O(1) — the flight recorder samples this every metrics interval)."""
        return len(self._backoff_until)

    def queue_depth(self) -> int:
        """Datasets still queued for direct dispatch across destinations
        (read-only; the flight recorder samples this on cadence)."""
        return sum(len(h) for h in self._direct.values())
