"""The transfer table — paper Table 1, backed by a real database (sqlite3).

One row per (dataset, source→destination) transfer.  The scheduler
(`core.scheduler`) is a pure state machine over this table, exactly as the
paper's replication tool tracked its 2×2291 transfers.
"""
from __future__ import annotations

import dataclasses
import enum
import sqlite3
import threading
from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple


class Status(str, enum.Enum):
    NULL = "NULL"            # not yet requested
    QUEUED = "QUEUED"        # submitted, not yet started by transport
    ACTIVE = "ACTIVE"
    PAUSED = "PAUSED"        # collection manager paused the endpoint
    SUCCEEDED = "SUCCEEDED"
    FAILED = "FAILED"        # transient — eligible for retry
    QUARANTINED = "QUARANTINED"  # persistent failure, human notified (paper §5)


TERMINAL = (Status.SUCCEEDED, Status.QUARANTINED)
RETRYABLE = (Status.NULL, Status.FAILED)


@dataclass
class TransferRecord:
    """Schema of paper Table 1 (+ retry bookkeeping)."""
    dataset: str                      # directory path to be transferred
    source: str                       # e.g. LLNL / ALCF / OLCF
    destination: str
    uuid: Optional[str] = None        # transport transfer identifier
    requested: Optional[float] = None
    completed: Optional[float] = None
    status: Status = Status.NULL
    directories: int = 0
    files: int = 0
    rate: float = 0.0                 # bytes/s
    faults: int = 0
    bytes_transferred: int = 0
    retries: int = 0

    @property
    def route(self) -> Tuple[str, str]:
        return (self.source, self.destination)


_SCHEMA = """
CREATE TABLE IF NOT EXISTS transfer (
  dataset TEXT NOT NULL,
  source TEXT NOT NULL,
  destination TEXT NOT NULL,
  uuid TEXT,
  requested REAL,
  completed REAL,
  status TEXT NOT NULL DEFAULT 'NULL',
  directories INTEGER NOT NULL DEFAULT 0,
  files INTEGER NOT NULL DEFAULT 0,
  rate REAL NOT NULL DEFAULT 0,
  faults INTEGER NOT NULL DEFAULT 0,
  bytes_transferred INTEGER NOT NULL DEFAULT 0,
  retries INTEGER NOT NULL DEFAULT 0,
  PRIMARY KEY (dataset, destination)
);
CREATE INDEX IF NOT EXISTS idx_status ON transfer (status);
CREATE INDEX IF NOT EXISTS idx_route ON transfer (source, destination, status);
"""

_FIELDS = [f.name for f in dataclasses.fields(TransferRecord)]


class TransferTable:
    """sqlite3-backed transfer table.

    Note the primary key is (dataset, destination): the *source* of a row may
    be rewritten by the scheduler when it re-routes (e.g. LLNL→OLCF relay
    becomes ALCF→OLCF once the dataset lands at ALCF) — exactly the
    flexibility the paper calls out as important.
    """

    def __init__(self, path: str = ":memory:"):
        self._conn = sqlite3.connect(path, check_same_thread=False)
        self._lock = threading.Lock()
        with self._lock:
            self._conn.executescript(_SCHEMA)
            self._conn.commit()

    # ------------------------------------------------------------------ CRUD
    def populate(self, datasets: Iterable[str], source: str,
                 destinations: Sequence[str]) -> int:
        """Step 1 of Figure 4: two rows per path, status NULL."""
        n = 0
        with self._lock:
            for ds in datasets:
                for dst in destinations:
                    self._conn.execute(
                        "INSERT OR IGNORE INTO transfer "
                        "(dataset, source, destination, status) VALUES (?,?,?,?)",
                        (ds, source, dst, Status.NULL.value))
                    n += 1
            self._conn.commit()
        return n

    def upsert(self, rec: TransferRecord) -> None:
        with self._lock:
            self._conn.execute(
                "INSERT OR REPLACE INTO transfer "
                f"({','.join(_FIELDS)}) VALUES ({','.join('?' * len(_FIELDS))})",
                self._row(rec))
            self._conn.commit()

    def update(self, dataset: str, destination: str, **kw) -> None:
        if "status" in kw and isinstance(kw["status"], Status):
            kw["status"] = kw["status"].value
        cols = ", ".join(f"{k}=?" for k in kw)
        with self._lock:
            self._conn.execute(
                f"UPDATE transfer SET {cols} WHERE dataset=? AND destination=?",
                (*kw.values(), dataset, destination))
            self._conn.commit()

    def update_many(
            self, updates: Sequence[Tuple[str, str, dict]]) -> None:
        """Apply many ``(dataset, destination, columns)`` updates in ONE
        transaction.  Rows sharing a column set go through ``executemany``;
        the scheduler's per-step poll uses this instead of committing once
        per live row."""
        if not updates:
            return
        groups: dict = {}
        for dataset, destination, kw in updates:
            kw = dict(kw)
            if isinstance(kw.get("status"), Status):
                kw["status"] = kw["status"].value
            groups.setdefault(tuple(kw), []).append(
                (*kw.values(), dataset, destination))
        with self._lock:
            for cols, rows in groups.items():
                self._conn.executemany(
                    "UPDATE transfer SET %s WHERE dataset=? AND destination=?"
                    % ", ".join(f"{c}=?" for c in cols), rows)
            self._conn.commit()

    # ---------------------------------------------------------------- queries
    def get(self, dataset: str, destination: str) -> Optional[TransferRecord]:
        rows = self._select(
            "WHERE dataset=? AND destination=?", (dataset, destination))
        return rows[0] if rows else None

    def by_status(self, *statuses: Status, destination: Optional[str] = None,
                  source: Optional[str] = None, limit: int = 0
                  ) -> List[TransferRecord]:
        q = "WHERE status IN (%s)" % ",".join("?" * len(statuses))
        args: list = [s.value for s in statuses]
        if destination is not None:
            q += " AND destination=?"
            args.append(destination)
        if source is not None:
            q += " AND source=?"
            args.append(source)
        q += " ORDER BY dataset"
        if limit:
            q += f" LIMIT {int(limit)}"
        return self._select(q, tuple(args))

    def count_route(self, source: str, destination: str, *statuses: Status) -> int:
        with self._lock:
            cur = self._conn.execute(
                "SELECT COUNT(*) FROM transfer WHERE source=? AND destination=? "
                "AND status IN (%s)" % ",".join("?" * len(statuses)),
                (source, destination, *[s.value for s in statuses]))
            return cur.fetchone()[0]

    def count_status(self, *statuses: Status) -> int:
        with self._lock:
            cur = self._conn.execute(
                "SELECT COUNT(*) FROM transfer WHERE status IN (%s)"
                % ",".join("?" * len(statuses)),
                tuple(s.value for s in statuses))
            return cur.fetchone()[0]

    def succeeded_datasets(self, destination: str) -> List[str]:
        with self._lock:
            cur = self._conn.execute(
                "SELECT dataset FROM transfer WHERE destination=? AND status=?",
                (destination, Status.SUCCEEDED.value))
            return [r[0] for r in cur.fetchall()]

    def all(self) -> List[TransferRecord]:
        return self._select("", ())

    def done(self) -> bool:
        """Figure 4 step 2f: terminate when nothing is outstanding."""
        return self.count_status(Status.NULL, Status.QUEUED, Status.ACTIVE,
                                 Status.PAUSED, Status.FAILED) == 0

    # ---------------------------------------------------------------- helpers
    def _select(self, where: str, args: tuple) -> List[TransferRecord]:
        with self._lock:
            cur = self._conn.execute(
                f"SELECT {','.join(_FIELDS)} FROM transfer {where}", args)
            rows = cur.fetchall()
        out = []
        for r in rows:
            d = dict(zip(_FIELDS, r))
            d["status"] = Status(d["status"])
            out.append(TransferRecord(**d))
        return out

    @staticmethod
    def _row(rec: TransferRecord) -> tuple:
        vals = []
        for f in _FIELDS:
            v = getattr(rec, f)
            vals.append(v.value if isinstance(v, Status) else v)
        return tuple(vals)
