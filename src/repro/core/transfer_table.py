"""The transfer table — paper Table 1, backed by a real database (sqlite3).

One row per (dataset, source→destination) transfer.  The scheduler
(`core.scheduler`) is a pure state machine over this table, exactly as the
paper's replication tool tracked its 2×2291 transfers.

sqlite stays the durable store, but every query is answered from an
in-memory row cache with status/route indexes, so the scheduler's per-step
cost is proportional to the rows *matched* (live transfers), not to the
catalog.  All mutations go through this class; they update the cache
immediately, while the sqlite write for the hot-path ``update_many`` is
*write-behind*: dirty keys are coalesced and flushed as full-row
INSERT OR REPLACE before any durable copy (``dump``), connection close, or
direct database read (``_select_db``) — the only points where sqlite
contents are observable.  Because the cache mirrors the database row-for-row
between flushes, replaying only each dirty row's *final* state reproduces
exactly the database the per-update writes would have built.  Registered
listeners observe every row transition, which lets the scheduler maintain
its own incremental state (pending queues, relay donor sets) without
re-scanning the table.
"""
from __future__ import annotations

import dataclasses
import enum
import os
import sqlite3
import threading
from dataclasses import dataclass
from typing import (Callable, Dict, Iterable, List, Optional, Sequence, Set,
                    Tuple)


class Status(str, enum.Enum):
    NULL = "NULL"            # not yet requested
    QUEUED = "QUEUED"        # submitted, not yet started by transport
    ACTIVE = "ACTIVE"
    PAUSED = "PAUSED"        # collection manager paused the endpoint
    SUCCEEDED = "SUCCEEDED"
    FAILED = "FAILED"        # transient — eligible for retry
    QUARANTINED = "QUARANTINED"  # persistent failure, human notified (paper §5)


TERMINAL = (Status.SUCCEEDED, Status.QUARANTINED)
RETRYABLE = (Status.NULL, Status.FAILED)
OUTSTANDING = (Status.NULL, Status.QUEUED, Status.ACTIVE, Status.PAUSED,
               Status.FAILED)


@dataclass
class TransferRecord:
    """Schema of paper Table 1 (+ retry bookkeeping)."""
    dataset: str                      # directory path to be transferred
    source: str                       # e.g. LLNL / ALCF / OLCF
    destination: str
    uuid: Optional[str] = None        # transport transfer identifier
    requested: Optional[float] = None
    completed: Optional[float] = None
    status: Status = Status.NULL
    directories: int = 0
    files: int = 0
    rate: float = 0.0                 # bytes/s
    faults: int = 0
    bytes_transferred: int = 0
    retries: int = 0

    @property
    def route(self) -> Tuple[str, str]:
        return (self.source, self.destination)


_SCHEMA = """
CREATE TABLE IF NOT EXISTS transfer (
  dataset TEXT NOT NULL,
  source TEXT NOT NULL,
  destination TEXT NOT NULL,
  uuid TEXT,
  requested REAL,
  completed REAL,
  status TEXT NOT NULL DEFAULT 'NULL',
  directories INTEGER NOT NULL DEFAULT 0,
  files INTEGER NOT NULL DEFAULT 0,
  rate REAL NOT NULL DEFAULT 0,
  faults INTEGER NOT NULL DEFAULT 0,
  bytes_transferred INTEGER NOT NULL DEFAULT 0,
  retries INTEGER NOT NULL DEFAULT 0,
  PRIMARY KEY (dataset, destination)
);
CREATE INDEX IF NOT EXISTS idx_status ON transfer (status);
CREATE INDEX IF NOT EXISTS idx_route ON transfer (source, destination, status);
"""

_FIELDS = [f.name for f in dataclasses.fields(TransferRecord)]

Key = Tuple[str, str]                         # (dataset, destination)
# listener(record, old_status, old_source); old_status None == new row
Listener = Callable[[TransferRecord, Optional[Status], Optional[str]], None]


class TransferTable:
    """sqlite3-backed transfer table with a write-through row cache.

    Note the primary key is (dataset, destination): the *source* of a row may
    be rewritten by the scheduler when it re-routes (e.g. LLNL→OLCF relay
    becomes ALCF→OLCF once the dataset lands at ALCF) — exactly the
    flexibility the paper calls out as important.
    """

    def __init__(self, path: str = ":memory:"):
        self._conn = sqlite3.connect(path, check_same_thread=False)
        self._lock = threading.Lock()
        self._rows: Dict[Key, TransferRecord] = {}
        self._by_status: Dict[Status, Set[Key]] = {s: set() for s in Status}
        self._route_counts: Dict[Tuple[str, str, Status], int] = {}
        self._succeeded: Dict[str, Set[str]] = {}   # destination -> datasets
        self._bytes_ok: Dict[str, int] = {}         # destination -> bytes
        self._listeners: List[Listener] = []
        # keys whose cached row is newer than its sqlite row; flushed (sorted,
        # one executemany) before dump/close/_select_db
        self._dirty: Set[Key] = set()
        with self._lock:
            self._conn.executescript(_SCHEMA)
            self._conn.commit()
            self._rebuild_cache()                   # resume from a disk store

    def close(self) -> None:
        """Release the sqlite connection (a disk-backed table's file is then
        safe to reopen or copy; pending write-behind rows are flushed
        first)."""
        with self._lock:
            self._flush_locked()
            self._conn.close()

    # --------------------------------------------------------- durable copies
    def dump(self, path: str) -> None:
        """Write a consistent copy of the whole database to ``path``
        atomically (temp file + rename): readers either see the previous
        complete table or the new one, never a torn write.  Campaign
        checkpoints call this once per snapshot."""
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        tmp = f"{path}.tmp"
        with self._lock:
            self._flush_locked()
            dst = sqlite3.connect(tmp)
            try:
                self._conn.backup(dst)
                dst.commit()
            finally:
                dst.close()
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "TransferTable":
        """An in-memory table initialized from a copy of the sqlite file at
        ``path``.  The file itself is left untouched, so a checkpoint can be
        resumed any number of times; cache/index/counter state is rebuilt
        from the copied rows."""
        if not os.path.exists(path):
            raise FileNotFoundError(path)
        table = cls()
        src = sqlite3.connect(path)
        try:
            with table._lock:
                src.backup(table._conn)
                table._rebuild_cache()
        finally:
            src.close()
        return table

    def add_listener(self, fn: Listener) -> None:
        """Observe every row mutation: ``fn(record, old_status, old_source)``
        is called after the cache/database update (``old_status is None`` for
        newly inserted rows).  The record passed is the live cached row —
        treat it as read-only."""
        self._listeners.append(fn)

    # ------------------------------------------------------------------ CRUD
    def populate(self, datasets: Iterable[str], source: str,
                 destinations: Sequence[str]) -> int:
        """Step 1 of Figure 4: two rows per path, status NULL."""
        n = 0
        fresh: List[TransferRecord] = []
        with self._lock:
            for ds in datasets:
                for dst in destinations:
                    n += 1
                    if (ds, dst) in self._rows:     # INSERT OR IGNORE
                        continue
                    self._conn.execute(
                        "INSERT OR IGNORE INTO transfer "
                        "(dataset, source, destination, status) VALUES (?,?,?,?)",
                        (ds, source, dst, Status.NULL.value))
                    rec = TransferRecord(ds, source, dst)
                    self._index_insert(rec)
                    fresh.append(rec)
            self._conn.commit()
        for rec in fresh:
            self._notify(rec, None, None)
        return n

    def upsert(self, rec: TransferRecord) -> None:
        key = (rec.dataset, rec.destination)
        with self._lock:
            self._conn.execute(
                "INSERT OR REPLACE INTO transfer "
                f"({','.join(_FIELDS)}) VALUES ({','.join('?' * len(_FIELDS))})",
                self._row(rec))
            self._conn.commit()
            old = self._rows.get(key)
            old_status = old.status if old else None
            old_source = old.source if old else None
            if old is not None:
                self._index_remove(old)
            rec = dataclasses.replace(rec)
            self._index_insert(rec)
        self._notify(rec, old_status, old_source)

    def update(self, dataset: str, destination: str, **kw) -> None:
        self.update_many([(dataset, destination, kw)])

    def update_many(
            self, updates: Sequence[Tuple[str, str, dict]]) -> None:
        """Apply many ``(dataset, destination, columns)`` updates to the
        cache, deferring the sqlite writes: each touched key is marked dirty
        and its *final* row is flushed (one INSERT OR REPLACE executemany, in
        sorted key order) the next time the database itself must be current
        — a durable ``dump``, ``close``, or ``_select_db``.  An update whose
        key matches no row is a no-op in cache and database alike, exactly
        as the former per-update SQL was."""
        if not updates:
            return
        events: List[Tuple[TransferRecord, Optional[Status], Optional[str]]] = []
        with self._lock:
            for dataset, destination, kw in updates:
                rec = self._rows.get((dataset, destination))
                if rec is None:
                    continue                         # UPDATE matches no row
                old_status, old_source = rec.status, rec.source
                self._index_remove(rec)
                for k, v in kw.items():
                    setattr(rec, k,
                            v if k != "status" or isinstance(v, Status)
                            else Status(v))
                self._index_insert(rec)
                self._dirty.add((dataset, destination))
                events.append((rec, old_status, old_source))
        for rec, old_status, old_source in events:
            self._notify(rec, old_status, old_source)

    # ---------------------------------------------------------------- queries
    @staticmethod
    def _copy(rec: TransferRecord) -> TransferRecord:
        """Shallow field copy, several times faster than
        ``dataclasses.replace`` (which re-runs the generated ``__init__``).
        Equivalent because ``TransferRecord`` has no ``__post_init__``."""
        new = TransferRecord.__new__(TransferRecord)
        new.__dict__.update(rec.__dict__)
        return new

    def get(self, dataset: str, destination: str) -> Optional[TransferRecord]:
        with self._lock:
            rec = self._rows.get((dataset, destination))
            return self._copy(rec) if rec is not None else None

    def peek(self, dataset: str, destination: str) -> Optional[TransferRecord]:
        """The live cached row (no copy) — read-only, O(1).  The scheduler's
        hot path uses this instead of ``get`` to avoid per-step allocation."""
        return self._rows.get((dataset, destination))

    def by_status(self, *statuses: Status, destination: Optional[str] = None,
                  source: Optional[str] = None, limit: int = 0
                  ) -> List[TransferRecord]:
        """Matching rows in dataset order.  Served from the status index:
        cost is O(matched · log matched), independent of table size."""
        with self._lock:
            keys: List[Key] = []
            for s in statuses:
                bucket = self._by_status.get(s, ())
                if destination is not None:
                    keys.extend(k for k in bucket if k[1] == destination)
                else:
                    keys.extend(bucket)
            keys.sort()
            out = []
            for k in keys:
                rec = self._rows[k]
                if source is not None and rec.source != source:
                    continue
                out.append(self._copy(rec))
                if limit and len(out) >= limit:
                    break
            return out

    def count_route(self, source: str, destination: str, *statuses: Status) -> int:
        with self._lock:
            return sum(self._route_counts.get((source, destination, s), 0)
                       for s in statuses)

    def count_status(self, *statuses: Status) -> int:
        with self._lock:
            return sum(len(self._by_status.get(s, ())) for s in statuses)

    def status_counts(self) -> Dict[str, int]:
        """Row count per status, keyed by status value in enum order —
        served from the status index (O(#statuses), the flight recorder
        samples this every metrics interval)."""
        with self._lock:
            return {s.value: len(self._by_status.get(s, ()))
                    for s in Status}

    def succeeded_datasets(self, destination: str) -> List[str]:
        with self._lock:
            return list(self._succeeded.get(destination, ()))

    def succeeded_set(self, destination: str) -> Set[str]:
        """Live set of datasets SUCCEEDED at ``destination`` (read-only view,
        O(1)); the scheduler's relay planner keys off this."""
        return self._succeeded.setdefault(destination, set())

    def bytes_at(self, destination: str) -> int:
        """Total bytes_transferred over SUCCEEDED rows at ``destination``,
        maintained incrementally (O(1) — the per-day timeline snapshot and
        dashboards poll this every iteration)."""
        with self._lock:
            return self._bytes_ok.get(destination, 0)

    def all(self) -> List[TransferRecord]:
        with self._lock:
            return [self._copy(self._rows[k])
                    for k in sorted(self._rows)]

    def done(self) -> bool:
        """Figure 4 step 2f: terminate when nothing is outstanding.  O(1)."""
        with self._lock:
            return all(not self._by_status[s] for s in OUTSTANDING)

    # ------------------------------------------------------ cache maintenance
    def _rebuild_cache(self) -> None:
        """Repopulate the row cache and every derived index/counter from the
        database (lock held).  Used at construction — including cold-opening
        a populated disk store — and after ``load`` replaces the db."""
        self._dirty.clear()     # the database is the authority here
        self._rows.clear()
        self._by_status = {s: set() for s in Status}
        self._route_counts.clear()
        self._succeeded.clear()
        self._bytes_ok.clear()
        for rec in self._select_db("", ()):
            self._index_insert(rec)

    def _index_insert(self, rec: TransferRecord) -> None:
        key = (rec.dataset, rec.destination)
        self._rows[key] = rec
        self._by_status[rec.status].add(key)
        rkey = (rec.source, rec.destination, rec.status)
        self._route_counts[rkey] = self._route_counts.get(rkey, 0) + 1
        if rec.status == Status.SUCCEEDED:
            self._succeeded.setdefault(rec.destination, set()).add(rec.dataset)
            self._bytes_ok[rec.destination] = (
                self._bytes_ok.get(rec.destination, 0) + rec.bytes_transferred)

    def _index_remove(self, rec: TransferRecord) -> None:
        key = (rec.dataset, rec.destination)
        self._by_status[rec.status].discard(key)
        rkey = (rec.source, rec.destination, rec.status)
        n = self._route_counts.get(rkey, 0) - 1
        if n > 0:
            self._route_counts[rkey] = n
        else:
            self._route_counts.pop(rkey, None)
        if rec.status == Status.SUCCEEDED:
            self._succeeded.get(rec.destination, set()).discard(rec.dataset)
            self._bytes_ok[rec.destination] = (
                self._bytes_ok.get(rec.destination, 0) - rec.bytes_transferred)

    def _notify(self, rec: TransferRecord, old_status: Optional[Status],
                old_source: Optional[str]) -> None:
        for fn in self._listeners:
            fn(rec, old_status, old_source)

    # ---------------------------------------------------------------- helpers
    def _flush_locked(self) -> None:
        """Write every dirty cached row to sqlite (caller holds the lock, or
        is single-threaded): one INSERT OR REPLACE executemany in sorted key
        order, one commit.  Restores the cache == database invariant."""
        if not self._dirty:
            return
        rows = [self._row(self._rows[k])
                for k in sorted(self._dirty) if k in self._rows]
        self._dirty.clear()
        if rows:
            self._conn.executemany(
                "INSERT OR REPLACE INTO transfer "
                f"({','.join(_FIELDS)}) VALUES ({','.join('?' * len(_FIELDS))})",
                rows)
            self._conn.commit()

    def _select_db(self, where: str, args: tuple) -> List[TransferRecord]:
        """Read rows straight from sqlite (cache bootstrap + consistency
        tests).  Flushes pending write-behind rows first, so the database
        read is always current."""
        self._flush_locked()
        cur = self._conn.execute(
            f"SELECT {','.join(_FIELDS)} FROM transfer {where}", args)
        rows = cur.fetchall()
        out = []
        for r in rows:
            d = dict(zip(_FIELDS, r))
            d["status"] = Status(d["status"])
            out.append(TransferRecord(**d))
        return out

    @staticmethod
    def _row(rec: TransferRecord) -> tuple:
        vals = []
        for f in _FIELDS:
            v = getattr(rec, f)
            vals.append(v.value if isinstance(v, Status) else v)
        return tuple(vals)
