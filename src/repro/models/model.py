"""Unified decoder LM: init, train loss, prefill, decode — all 10 architectures.

Layer stacks lower via ``jax.lax.scan`` over stacked parameter banks so 62-layer
models compile quickly and HLO stays small.  Heterogeneous patterns use group
scans (gemma3 5-local:1-global; zamba2 6-mamba2-then-shared-attn).

Modes
-----
* train:   ``loss_fn(params, batch)`` — full-sequence causal LM loss.
* prefill: ``prefill(params, tokens, cache)`` — fills a zero-initialized cache.
* decode:  ``decode_step(params, cache, token, t)`` — one token, cache update.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.axes import constrain
from repro.models.config import ModelConfig

Params = Dict[str, Any]
Cache = Dict[str, Any]


# ===================================================================== blocks
def init_attn_block(key, cfg: ModelConfig, use_moe: bool, dense_ff: int = 0,
                    dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
                 "ln2": L.init_rmsnorm(cfg.d_model, dtype)}
    if cfg.mla is not None:
        p["attn"] = L.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if use_moe:
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, dense_ff or cfg.d_ff, dtype)
    return p


def attn_block(p: Params, cfg: ModelConfig, x, positions, cache=None,
               cache_index=None, window=None, positions3=None, use_moe=False):
    """Pre-norm transformer block.  Returns (x, new_cache, aux_loss)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = L.mla_attention(p["attn"], cfg, h, positions, cache, cache_index)
    else:
        a, new_cache = L.attention(p["attn"], cfg, h, positions, cache,
                                   cache_index, window, positions3)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        f, aux = MOE.moe_forward(p["moe"], cfg, h)
    else:
        f, aux = L.mlp(p["mlp"], h), jnp.float32(0.0)
    x = x + f
    x = constrain(x, ("batch", "seq", None))
    return x, new_cache, aux


def init_ssm_layer(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln": L.init_rmsnorm(cfg.d_model, dtype),
            "ssm": SSM.init_ssm_block(k1, cfg, dtype)}


def ssm_layer(p: Params, cfg: ModelConfig, x, state=None, return_state=False):
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    y, new_state = SSM.ssm_block(p["ssm"], cfg, h, state, return_state)
    x = x + y
    x = constrain(x, ("batch", "seq", None))
    return x, new_state


# ============================================================ cache structures
def _kv_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.mla is not None:
        m = cfg.mla
        return L.MLACache(
            c_kv=jnp.zeros((batch, seq, m.kv_lora_rank), jnp.bfloat16),
            k_rope=jnp.zeros((batch, seq, m.qk_rope_head_dim), jnp.bfloat16))
    return L.KVCache(
        k=jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        v=jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16))


def _ssm_state_shape(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    if s.version == 1:
        return SSM.Mamba1State(
            conv=jnp.zeros((batch, s.d_conv - 1, d_in), jnp.bfloat16),
            h=jnp.zeros((batch, d_in, s.d_state), jnp.float32))
    H = d_in // s.headdim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return SSM.Mamba2State(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), jnp.bfloat16),
        h=jnp.zeros((batch, H, s.headdim, s.d_state), jnp.float32))


def _stack(n: int, leaf_fn):
    """Stack n zero-caches along a new leading axis."""
    proto = leaf_fn()
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((n,) + a.shape, a.dtype), proto)


# ===================================================================== pattern
class Pattern(NamedTuple):
    """Static description of the layer stack (derived from cfg)."""
    kind: str            # uniform_attn | local_global | moe | ssm | hybrid
    n_scan: int          # layers in the main scanned bank
    n_lead: int = 0
    n_groups: int = 0
    group_local: int = 0  # local layers per group (gemma3) / ssm per group (zamba2)
    n_tail: int = 0


def derive_pattern(cfg: ModelConfig) -> Pattern:
    if cfg.family == "ssm":
        return Pattern("ssm", n_scan=cfg.n_layers)
    if cfg.hybrid is not None:
        e = cfg.hybrid.shared_attn_every
        g = cfg.n_layers // e
        return Pattern("hybrid", n_scan=0, n_groups=g, group_local=e,
                       n_tail=cfg.n_layers - g * e)
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        g = cfg.n_layers // (r + 1)
        return Pattern("local_global", n_scan=0, n_groups=g, group_local=r,
                       n_tail=cfg.n_layers - g * (r + 1))
    if cfg.moe is not None:
        lead = cfg.moe.first_dense_layers
        return Pattern("moe", n_scan=cfg.n_layers - lead, n_lead=lead)
    return Pattern("uniform_attn", n_scan=cfg.n_layers)


# ======================================================================== model
class LM:
    def __init__(self, cfg: ModelConfig, dtype=jnp.bfloat16, remat: bool = True):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.pattern = derive_pattern(cfg)

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        cfg, dtype = self.cfg, self.dtype
        pat = self.pattern
        keys = jax.random.split(key, 8)
        p: Params = {}
        if cfg.embed_inputs:
            if cfg.n_codebooks > 1:
                p["embed"] = L._dense_init(
                    keys[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model),
                    dtype, scale=0.02)
            else:
                p["embed"] = L._dense_init(
                    keys[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02)
        else:
            # decode path still needs a text-token embedding (frontend supplies
            # merged embeddings for train/prefill)
            p["embed"] = L._dense_init(
                keys[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02)
        p["final_norm"] = L.init_rmsnorm(cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            if cfg.n_codebooks > 1:
                p["lm_head"] = L._dense_init(
                    keys[1], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size), dtype)
            else:
                p["lm_head"] = L._dense_init(
                    keys[1], (cfg.d_model, cfg.vocab_size), dtype)

        def stack_init(n, fn):
            ks = jax.random.split(keys[2], max(n, 1))
            return jax.vmap(fn)(ks[:n]) if n > 0 else None

        if pat.kind == "uniform_attn":
            p["blocks"] = stack_init(
                pat.n_scan, lambda k: init_attn_block(k, cfg, False, dtype=dtype))
        elif pat.kind == "moe":
            m = cfg.moe
            if pat.n_lead:
                ks = jax.random.split(keys[3], pat.n_lead)
                p["lead"] = [init_attn_block(k, cfg, False, dense_ff=m.d_ff_dense,
                                             dtype=dtype) for k in ks]
            p["blocks"] = stack_init(
                pat.n_scan, lambda k: init_attn_block(k, cfg, True, dtype=dtype))
        elif pat.kind == "ssm":
            p["blocks"] = stack_init(
                pat.n_scan, lambda k: init_ssm_layer(k, cfg, dtype))
        elif pat.kind == "local_global":
            def group_init(k):
                k1, k2 = jax.random.split(k)
                lk = jax.random.split(k1, pat.group_local)
                return {
                    "local": jax.vmap(
                        lambda kk: init_attn_block(kk, cfg, False, dtype=dtype))(lk),
                    "global": init_attn_block(k2, cfg, False, dtype=dtype),
                }
            gk = jax.random.split(keys[3], pat.n_groups)
            p["groups"] = jax.vmap(group_init)(gk)
            p["tail"] = stack_init(
                pat.n_tail, lambda k: init_attn_block(k, cfg, False, dtype=dtype))
        elif pat.kind == "hybrid":
            def group_init(k):
                lk = jax.random.split(k, pat.group_local)
                return jax.vmap(lambda kk: init_ssm_layer(kk, cfg, dtype))(lk)
            gk = jax.random.split(keys[3], pat.n_groups)
            p["groups"] = jax.vmap(group_init)(gk)
            p["shared"] = init_attn_block(keys[4], cfg, False, dtype=dtype)
            p["tail"] = stack_init(
                pat.n_tail, lambda k: init_ssm_layer(k, cfg, dtype))
        else:
            raise ValueError(pat.kind)
        return p

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_seq: int) -> Cache:
        cfg, pat = self.cfg, self.pattern
        c: Cache = {}
        if pat.kind in ("uniform_attn", "moe"):
            c["blocks"] = _stack(pat.n_scan, lambda: _kv_cache_shape(cfg, batch, max_seq))
            if pat.n_lead:
                c["lead"] = [_kv_cache_shape(cfg, batch, max_seq)
                             for _ in range(pat.n_lead)]
        elif pat.kind == "ssm":
            c["blocks"] = _stack(pat.n_scan, lambda: _ssm_state_shape(cfg, batch))
        elif pat.kind == "local_global":
            w = min(cfg.sliding_window or max_seq, max_seq)
            c["groups"] = {
                "local": _stack(pat.n_groups * pat.group_local,
                                lambda: _kv_cache_shape(cfg, batch, w)),
                "global": _stack(pat.n_groups,
                                 lambda: _kv_cache_shape(cfg, batch, max_seq)),
            }
            # reshape local to (G, R, ...)
            c["groups"]["local"] = jax.tree_util.tree_map(
                lambda a: a.reshape((pat.n_groups, pat.group_local) + a.shape[1:]),
                c["groups"]["local"])
            if pat.n_tail:
                c["tail"] = _stack(pat.n_tail, lambda: _kv_cache_shape(cfg, batch, w))
        elif pat.kind == "hybrid":
            c["groups"] = _stack(pat.n_groups * pat.group_local,
                                 lambda: _ssm_state_shape(cfg, batch))
            c["groups"] = jax.tree_util.tree_map(
                lambda a: a.reshape((pat.n_groups, pat.group_local) + a.shape[1:]),
                c["groups"])
            c["shared"] = _stack(pat.n_groups, lambda: _kv_cache_shape(cfg, batch, max_seq))
            if pat.n_tail:
                c["tail"] = _stack(pat.n_tail, lambda: _ssm_state_shape(cfg, batch))
        return c

    # ------------------------------------------------------------- embedding
    def embed(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        if not cfg.embed_inputs and "embeds" in batch:
            return batch["embeds"].astype(self.dtype)
        tokens = batch["tokens"]
        if cfg.n_codebooks > 1:
            # (B, T, K) -> sum_k embed[k][tok]
            xs = [jnp.take(params["embed"][k], tokens[..., k], axis=0)
                  for k in range(cfg.n_codebooks)]
            return functools.reduce(jnp.add, xs)
        return jnp.take(params["embed"], tokens, axis=0)

    def unembed(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        if cfg.n_codebooks > 1:
            if cfg.tie_embeddings:
                logits = jnp.einsum("btd,kvd->btkv", x, head)
            else:
                logits = jnp.einsum("btd,kdv->btkv", x, head)
        else:
            if cfg.tie_embeddings:
                logits = x @ head.T
            else:
                logits = x @ head
        return constrain(logits, ("batch", "seq", None, "vocab")
                         if cfg.n_codebooks > 1 else ("batch", "seq", "vocab"))

    # ------------------------------------------------------------- backbone
    def _maybe_remat(self, fn, mode: str):
        # nothing_saveable = full per-layer recompute: the backward pass holds
        # one layer's activations at a time (scan carries only layer inputs).
        # dots_with_no_batch_dims_saveable would store every projection output
        # (~300 GB/device for gemma3-27b at train_4k — measured in the dry-run).
        if self.remat and mode == "train":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn

    def backbone(self, params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cache: Optional[Cache] = None, t: Optional[jnp.ndarray] = None,
                 positions3: Optional[jnp.ndarray] = None, mode: str = "train",
                 ) -> Tuple[jnp.ndarray, Optional[Cache], jnp.ndarray]:
        cfg, pat = self.cfg, self.pattern
        aux0 = jnp.float32(0.0)
        serving = cache is not None
        new_cache: Cache = {}

        if pat.kind in ("uniform_attn", "moe"):
            use_moe = pat.kind == "moe"
            if pat.n_lead:
                lead_caches = cache["lead"] if serving else [None] * pat.n_lead
                new_lead = []
                for i, lp in enumerate(params["lead"]):
                    x, nc, a = attn_block(lp, cfg, x, positions, lead_caches[i],
                                          t, None, positions3, use_moe=False)
                    aux0 = aux0 + a
                    new_lead.append(nc)
                if serving:
                    new_cache["lead"] = new_lead

            if serving and x.shape[1] == 1:
                # single-token decode: python-unrolled layers with in-place
                # dynamic-update-slice on the donated stacked cache.  A scan
                # would return fresh ys buffers (a full cache copy per step —
                # +6.4 GB/device for musicgen-large at decode_32k, measured).
                stacked = cache["blocks"]
                for i in range(pat.n_scan):
                    bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                    bc = jax.tree_util.tree_map(lambda a: a[i], stacked)
                    x, nc, a = attn_block(bp, cfg, x, positions, bc, t,
                                          cfg.sliding_window, positions3,
                                          use_moe)
                    aux0 = aux0 + a
                    stacked = jax.tree_util.tree_map(
                        lambda full, upd, i=i: full.at[i].set(
                            upd.astype(full.dtype)), stacked, nc)
                new_cache["blocks"] = stacked
            elif serving:
                def body(carry, layer):
                    xx, aux = carry
                    bp, bc = layer
                    y, nc, a = attn_block(bp, cfg, xx, positions, bc, t,
                                          cfg.sliding_window, positions3, use_moe)
                    return (y, aux + a), nc
                (x, aux0), ncs = jax.lax.scan(
                    body, (x, aux0), (params["blocks"], cache["blocks"]))
                new_cache["blocks"] = ncs
            else:
                def body(carry, bp):
                    xx, aux = carry
                    y, _, a = attn_block(bp, cfg, xx, positions, None, None,
                                         cfg.sliding_window, positions3, use_moe)
                    return (y, aux + a), None
                (x, aux0), _ = jax.lax.scan(
                    self._maybe_remat(body, mode), (x, aux0), params["blocks"])

        elif pat.kind == "ssm":
            if serving:
                def body(xx, layer):
                    bp, st = layer
                    y, ns = ssm_layer(bp, cfg, xx, st)
                    return y, ns
                x, ncs = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
                new_cache["blocks"] = ncs
            else:
                def body(xx, bp):
                    y, _ = ssm_layer(bp, cfg, xx)
                    return y, None
                x, _ = jax.lax.scan(self._maybe_remat(body, mode), x, params["blocks"])

        elif pat.kind == "local_global":
            w = cfg.sliding_window
            if serving:
                def group(carry, layer):
                    xx, aux = carry
                    gp, gc = layer
                    def local_body(c2, lay2):
                        xx2, aux2 = c2
                        lp, lc = lay2
                        y, nc, a = attn_block(lp, cfg, xx2, positions, lc, t, w)
                        return (y, aux2 + a), nc
                    (xx, aux), nlc = jax.lax.scan(
                        local_body, (xx, aux), (gp["local"], gc["local"]))
                    xx, ngc, a = attn_block(gp["global"], cfg, xx, positions,
                                            gc["global"], t, None)
                    return (xx, aux + a), {"local": nlc, "global": ngc}
                (x, aux0), ncs = jax.lax.scan(
                    group, (x, aux0), (params["groups"], cache["groups"]))
                new_cache["groups"] = ncs
                if pat.n_tail:
                    def tail_body(c2, lay2):
                        xx2, aux2 = c2
                        lp, lc = lay2
                        y, nc, a = attn_block(lp, cfg, xx2, positions, lc, t, w)
                        return (y, aux2 + a), nc
                    (x, aux0), ntc = jax.lax.scan(
                        tail_body, (x, aux0), (params["tail"], cache["tail"]))
                    new_cache["tail"] = ntc
            else:
                def group(carry, gp):
                    xx, aux = carry
                    def local_body(c2, lp):
                        xx2, aux2 = c2
                        y, _, a = attn_block(lp, cfg, xx2, positions, None, None, w)
                        return (y, aux2 + a), None
                    (xx, aux), _ = jax.lax.scan(local_body, (xx, aux), gp["local"])
                    xx, _, a = attn_block(gp["global"], cfg, xx, positions, None, None, None)
                    return (xx, aux + a), None
                (x, aux0), _ = jax.lax.scan(
                    self._maybe_remat(group, mode), (x, aux0), params["groups"])
                if pat.n_tail:
                    def tail_body(c2, lp):
                        xx2, aux2 = c2
                        y, _, a = attn_block(lp, cfg, xx2, positions, None, None, w)
                        return (y, aux2 + a), None
                    (x, aux0), _ = jax.lax.scan(
                        self._maybe_remat(tail_body, mode), (x, aux0), params["tail"])

        elif pat.kind == "hybrid":
            shared_p = params["shared"]
            if serving:
                def group(carry, layer):
                    xx = carry
                    gp, gst, sc = layer
                    def ssm_body(xx2, lay2):
                        lp, st = lay2
                        y, ns = ssm_layer(lp, cfg, xx2, st)
                        return y, ns
                    xx, nst = jax.lax.scan(ssm_body, xx, (gp, gst))
                    xx, nsc, _ = attn_block(shared_p, cfg, xx, positions, sc, t)
                    return xx, (nst, nsc)
                x, (nst, nsc) = jax.lax.scan(
                    group, x, (params["groups"], cache["groups"], cache["shared"]))
                new_cache["groups"] = nst
                new_cache["shared"] = nsc
                if pat.n_tail:
                    def tail_body(xx2, lay2):
                        lp, st = lay2
                        y, ns = ssm_layer(lp, cfg, xx2, st)
                        return y, ns
                    x, ntc = jax.lax.scan(tail_body, x, (params["tail"], cache["tail"]))
                    new_cache["tail"] = ntc
            else:
                def group(xx, gp):
                    def ssm_body(xx2, lp):
                        y, _ = ssm_layer(lp, cfg, xx2)
                        return y, None
                    xx, _ = jax.lax.scan(ssm_body, xx, gp)
                    xx, _, _ = attn_block(shared_p, cfg, xx, positions, None)
                    return xx, None
                x, _ = jax.lax.scan(self._maybe_remat(group, mode), x, params["groups"])
                if pat.n_tail:
                    def tail_body(xx2, lp):
                        y, _ = ssm_layer(lp, cfg, xx2)
                        return y, None
                    x, _ = jax.lax.scan(
                        self._maybe_remat(tail_body, mode), x, params["tail"])

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, (new_cache if serving else None), aux0

    # ------------------------------------------------------------------ loss
    def loss_fn(self, params: Params, batch: Dict[str, jnp.ndarray],
                aux_weight: float = 0.01) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        x = self.embed(params, batch)
        x = constrain(x, ("batch", "seq", None))
        B, T = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        positions3 = batch.get("positions3")
        x, _, aux = self.backbone(params, x, positions, positions3=positions3,
                                  mode="train")
        logits = self.unembed(params, x)
        labels = batch["labels"]
        ce = softmax_xent(logits, labels)
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    # --------------------------------------------------------------- serving
    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                cache: Cache) -> Tuple[jnp.ndarray, Cache]:
        """Run the prompt through the model, writing cache at positions 0..T."""
        cfg = self.cfg
        x = self.embed(params, batch)
        B, T = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        positions3 = batch.get("positions3")
        x, new_cache, _ = self.backbone(
            params, x, positions, cache=cache, t=jnp.int32(0),
            positions3=positions3, mode="prefill")
        logits = self.unembed(params, x[:, -1:])
        return logits, new_cache

    def decode_step(self, params: Params, cache: Cache, token: jnp.ndarray,
                    t: jnp.ndarray) -> Tuple[jnp.ndarray, Cache]:
        """token: (B, 1) int32 (or (B, 1, K) for multi-codebook); t: scalar."""
        cfg = self.cfg
        batch: Dict[str, jnp.ndarray] = {"tokens": token}
        x = self.embed(params, batch)
        B = x.shape[0]
        positions = jnp.full((B, 1), t, jnp.int32)
        positions3 = None
        if cfg.mrope:
            positions3 = jnp.broadcast_to(
                jnp.full((1, B, 1), t, jnp.int32), (3, B, 1))
        x, new_cache, _ = self.backbone(
            params, x, positions, cache=cache, t=t,
            positions3=positions3, mode="decode")
        logits = self.unembed(params, x)
        return logits, new_cache


# ------------------------------------------------------------------ loss util
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; partition-friendly over a vocab-sharded last dim.

    logits: (..., V) ; labels: (...) int32.  Uses a one-hot pick (elementwise,
    partitionable) instead of take_along_axis (gather over a sharded dim).
    """
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    V = logits.shape[-1]
    onehot = (labels[..., None] == jnp.arange(V, dtype=labels.dtype)).astype(jnp.float32)
    picked = jnp.sum(lf * onehot, axis=-1)
    return jnp.mean(lse - picked)
