"""Unified model configuration covering all assigned architectures.

One dataclass describes every architecture in the pool (dense GQA, MLA+MoE,
sliding-window/global hybrids, Mamba1/2 SSMs, Zamba2-style shared-attention
hybrids, multi-codebook audio LMs, M-RoPE VLM backbones).  The block pattern is
derived from the config; models are built by ``repro.models.model``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None  # V2-Lite: no q compression


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 64
    top_k: int = 6
    n_shared: int = 0              # shared (always-on) experts
    d_ff_expert: int = 1408
    capacity_factor: float = 1.25
    first_dense_layers: int = 0    # leading dense layers (deepseek-v2)
    d_ff_dense: int = 0            # ffn width of those dense layers
    router_norm_topk: bool = True  # normalize top-k weights to sum to 1


@dataclass(frozen=True)
class SSMConfig:
    version: int = 1               # 1 = Mamba (S6), 2 = Mamba2 (SSD)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64              # mamba2 only
    n_groups: int = 1              # mamba2 B/C groups
    chunk: int = 256               # SSD chunk length


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style weight-shared attention block interleaved with SSM layers."""
    shared_attn_every: int = 6     # invoke the shared block after every N ssm layers


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention options -------------------------------------------------
    rope_theta: float = 10000.0
    qk_norm: bool = False
    sliding_window: Optional[int] = None      # window size for local layers
    local_global_ratio: int = 0               # N local : 1 global (0 = all global)
    mla: Optional[MLAConfig] = None
    mrope: bool = False                       # 3-section M-RoPE (qwen2-vl)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- mixture / ssm / hybrid -------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # --- io ----------------------------------------------------------------
    n_codebooks: int = 1                      # musicgen: 4 parallel EnCodec books
    tie_embeddings: bool = False
    embed_inputs: bool = True                 # False -> frontend supplies embeddings
    # --- numerics / misc ----------------------------------------------------
    norm_eps: float = 1e-5
    max_seq_len: int = 8192
    subquadratic: bool = False                # eligible for long_500k decode
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------ util
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length n_layers.

        Kinds: 'attn' (global), 'local' (sliding window), 'ssm', 'shared_attn'
        (zamba2 shared block call-site marker — not counted in n_layers; see
        blocks.py which inserts call-sites between ssm layers).
        """
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        if self.hybrid is not None:
            return ("ssm",) * self.n_layers
        if self.local_global_ratio > 0:
            r = self.local_global_ratio
            kinds = []
            for i in range(self.n_layers):
                kinds.append("attn" if (i % (r + 1)) == r else "local")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced config of the same family for CPU smoke tests.
    def smoke(self) -> "ModelConfig":
        kw = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads // max(1, self.n_heads // 4))) if self.n_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            max_seq_len=128,
        )
        if self.local_global_ratio > 0:
            kw["n_layers"] = self.local_global_ratio + 1  # one full pattern group
            kw["sliding_window"] = 16
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_routed=8, top_k=2,
                d_ff_expert=64,
                d_ff_dense=128 if self.moe.d_ff_dense else 0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8, headdim=16, chunk=32)
        if self.hybrid is not None:
            kw["n_layers"] = 4
            kw["hybrid"] = dataclasses.replace(self.hybrid, shared_attn_every=2)
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32)
        if self.mrope:
            hd2 = kw["head_dim"] // 2
            s = hd2 // 4
            kw["mrope_sections"] = (hd2 - 2 * s, s, s)
        return self.with_(**kw, name=self.name + "-smoke")


def param_count(cfg: ModelConfig) -> Tuple[int, int]:
    """(total_params, active_params) — analytic, for roofline MODEL_FLOPS."""
    d = cfg.d_model
    total = 0
    active = 0
    # embeddings
    # the token embedding exists even for stub-frontend archs (decode path)
    emb = cfg.vocab_size * d * cfg.n_codebooks
    unemb = 0 if cfg.tie_embeddings else cfg.vocab_size * d * cfg.n_codebooks
    total += emb + unemb
    active += emb + unemb

    def attn_params() -> int:
        if cfg.mla is not None:
            m = cfg.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * cfg.n_heads * qk_hd                       # W_q
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)    # W_dkv (+ rope k)
            p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += cfg.n_heads * m.v_head_dim * d               # W_o
            return p
        hd = cfg.head_dim
        return d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)

    def mlp_params(ff: int) -> int:
        return 3 * d * ff  # gated (SwiGLU): up, gate, down

    def ssm_params() -> int:
        s = cfg.ssm
        d_in = s.expand * d
        if s.version == 1:
            dt_rank = max(1, d // 16)
            p = d * 2 * d_in                    # in_proj (x, z)
            p += s.d_conv * d_in                # conv
            p += d_in * (dt_rank + 2 * s.d_state)  # x -> (dt, B, C)
            p += dt_rank * d_in                 # dt_proj
            p += d_in * s.d_state               # A
            p += d_in                           # D
            p += d_in * d                       # out_proj
            return p
        nheads = d_in // s.headdim
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
        p += s.d_conv * conv_dim
        p += nheads * 2                         # A, D
        p += d_in * d                           # out_proj
        return p

    kinds = cfg.layer_kinds()
    for k in kinds:
        if k in ("attn", "local"):
            total += attn_params()
            active += attn_params()
        elif k == "ssm":
            total += ssm_params()
            active += ssm_params()
    # MLP / MoE per layer (attention archs only; ssm archs have no separate mlp)
    for i, k in enumerate(kinds):
        if k == "ssm":
            continue
        if cfg.moe is not None and i >= cfg.moe.first_dense_layers:
            m = cfg.moe
            routed = m.n_routed * 3 * d * m.d_ff_expert
            shared = m.n_shared * 3 * d * m.d_ff_expert
            router = d * m.n_routed
            total += routed + shared + router
            active += (m.top_k + m.n_shared) * 3 * d * m.d_ff_expert + router
        elif cfg.moe is not None:
            total += mlp_params(cfg.moe.d_ff_dense)
            active += mlp_params(cfg.moe.d_ff_dense)
        else:
            total += mlp_params(cfg.d_ff)
            active += mlp_params(cfg.d_ff)
    # zamba2 shared attention+mlp block (one set of weights)
    if cfg.hybrid is not None:
        shared = attn_params() + mlp_params(cfg.d_ff)
        total += shared
        n_sites = cfg.n_layers // cfg.hybrid.shared_attn_every
        active += shared * max(1, n_sites)  # executed at every call-site
    # final norm ~ negligible
    return total, active
