"""Stub modality frontends.

Per the assignment, ``[audio]``/``[vlm]`` entries are transformer BACKBONES;
the modality frontend is a stub whose only job is to provide shape-correct
inputs:

* qwen2-vl: the vision tower + merger is stubbed — ``input_specs`` yields
  precomputed, already-merged patch/text embeddings (B, T, d) plus the 3-stream
  M-RoPE position ids (temporal, height, width).
* musicgen: EnCodec is stubbed — the LM consumes its 4 discrete codebook token
  streams directly (B, T, 4), which is the real MusicGen interface.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def mrope_position_ids(batch: int, seq: int) -> np.ndarray:
    """Deterministic stand-in M-RoPE ids: a leading image patch grid followed
    by text (t = h = w advancing together), shape (3, B, T)."""
    grid = min(seq // 4, 256)
    side = max(1, int(np.sqrt(grid)))
    t = np.zeros((seq,), np.int32)
    h = np.zeros((seq,), np.int32)
    w = np.zeros((seq,), np.int32)
    n_img = side * side
    idx = np.arange(n_img)
    t[:n_img] = 0
    h[:n_img] = idx // side
    w[:n_img] = idx % side
    text = np.arange(seq - n_img, dtype=np.int32) + side
    t[n_img:] = text
    h[n_img:] = text
    w[n_img:] = text
    out = np.stack([t, h, w])[:, None, :]
    return np.broadcast_to(out, (3, batch, seq)).copy()


def synth_embeddings(key, batch: int, seq: int, d: int) -> jnp.ndarray:
    return jax.random.normal(key, (batch, seq, d), jnp.bfloat16) * 0.02


def train_batch_stub(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
                     ) -> Dict[str, jnp.ndarray]:
    """Concrete (allocated) batch for smoke tests."""
    rng = np.random.default_rng(seed)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.n_codebooks > 1:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq, cfg.n_codebooks)), jnp.int32)
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq, cfg.n_codebooks)), jnp.int32)
    elif not cfg.embed_inputs:
        key = jax.random.PRNGKey(seed)
        out["embeds"] = synth_embeddings(key, batch, seq, cfg.d_model)
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    if cfg.mrope:
        out["positions3"] = jnp.asarray(mrope_position_ids(batch, seq))
    return out
