"""State-space blocks: Mamba1 (S6 selective scan) and Mamba2 (SSD).

Hardware adaptation (see DESIGN.md): the CUDA selective-scan kernel is
re-thought for TPU as a *chunked* scan — sequential ``lax.scan`` over chunks
(bounding the materialized (B, Lc, d_in, d_state) working set to VMEM-friendly
sizes) with a parallel associative scan inside each chunk.  The Pallas kernel
in ``kernels/mamba_scan`` implements the same chunking with explicit BlockSpecs;
this module is the pure-jnp reference path used for dry-run lowering.

All scan math in f32; projections bf16.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.axes import constrain
from repro.models.config import ModelConfig, SSMConfig
from repro.models.layers import Params, _dense_init, init_rmsnorm, rmsnorm


# ------------------------------------------------------------------ conv1d
def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray],
                  state: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x: (B, T, C); w: (d_conv, C).

    state: (B, d_conv-1, C) trailing inputs from the previous call (decode).
    Returns (y (B,T,C), new_state (B, d_conv-1, C)).
    """
    B, T, C = x.shape
    dk = w.shape[0]
    if state is None:
        state = jnp.zeros((B, dk - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)              # (B, T+dk-1, C)
    y = jnp.zeros((B, T, C), jnp.float32)
    for i in range(dk):                                    # dk is 4: unrolled
        y = y + xp[:, i:i + T, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    new_state = xp[:, T:, :]
    return y.astype(x.dtype), new_state


# ================================================================== Mamba1
class Mamba1State(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, d_in)
    h: jnp.ndarray      # (B, d_in, d_state) f32


def init_mamba1(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 8)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None], (d_in, 1))
    return {
        "in_x": _dense_init(ks[0], (d, d_in), dtype),
        "in_z": _dense_init(ks[1], (d, d_in), dtype),
        "conv_w": _dense_init(ks[2], (s.d_conv, d_in), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": _dense_init(ks[3], (d_in, dt_rank + 2 * s.d_state), dtype),
        "dt_proj": _dense_init(ks[4], (dt_rank, d_in), dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(A),                               # (d_in, d_state) f32
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _dense_init(ks[5], (d_in, d), dtype),
    }


def _selective_scan_chunked(u, dt, B_, C_, A, h0, chunk: int):
    """u, dt: (B, T, d_in) f32; B_, C_: (B, T, n) f32; A: (d_in, n) f32;
    h0: (B, d_in, n) f32.  Returns (y (B,T,d_in) f32, hT).

    Sequential over T/chunk chunks; parallel associative scan within a chunk.
    """
    Bsz, T, d_in = u.shape
    n = A.shape[1]
    Lc = min(chunk, T)
    assert T % Lc == 0, (T, Lc)
    nc = T // Lc

    def chunk_step(h, args):
        uc, dtc, Bc, Cc = args                     # (B, Lc, ...)
        a = jnp.exp(dtc[..., None] * A)            # (B, Lc, d_in, n)
        b = (dtc * uc)[..., None] * Bc[:, :, None, :]

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_sc, b_sc = jax.lax.associative_scan(comb, (a, b), axis=1)
        hs = a_sc * h[:, None] + b_sc              # (B, Lc, d_in, n)
        y = jnp.einsum("bldn,bln->bld", hs, Cc)
        return hs[:, -1], y

    u_c = u.reshape(Bsz, nc, Lc, d_in)
    dt_c = dt.reshape(Bsz, nc, Lc, d_in)
    B_c = B_.reshape(Bsz, nc, Lc, n)
    C_c = C_.reshape(Bsz, nc, Lc, n)
    hT, ys = jax.lax.scan(
        chunk_step, h0,
        (u_c.transpose(1, 0, 2, 3), dt_c.transpose(1, 0, 2, 3),
         B_c.transpose(1, 0, 2, 3), C_c.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, T, d_in)
    return y, hT


def mamba1_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 state: Optional[Mamba1State] = None,
                 return_state: bool = False,
                 ) -> Tuple[jnp.ndarray, Optional[Mamba1State]]:
    """x: (B, T, d).  Train: state=None.  Prefill: return_state=True.
    Decode: state given (T may be 1)."""
    s = cfg.ssm
    B, T, d = x.shape
    d_in = s.expand * d
    dt_rank = max(1, d // 16)

    # TP: the expanded channel dim (d_in) stays sharded through conv/silu/scan
    xz = constrain(x @ p["in_x"], ("batch", "seq", "ssm_ch"))   # (B,T,d_in)
    z = constrain(x @ p["in_z"], ("batch", "seq", "ssm_ch"))
    conv_state = state.conv if state is not None else None
    xc, new_conv = causal_conv1d(xz, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32))

    proj = (xc.astype(x.dtype) @ p["x_proj"]).astype(jnp.float32)
    dt, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    dt = constrain(dt, ("batch", "seq", "ssm_ch"))
    A = -jnp.exp(p["A_log"])                               # (d_in, n)

    h0 = state.h if state is not None else jnp.zeros((B, d_in, s.d_state), jnp.float32)
    if T == 1 and state is not None:
        # recurrent single step
        a = jnp.exp(dt[:, 0, :, None] * A)                 # (B, d_in, n)
        h = a * h0 + (dt[:, 0] * xc[:, 0])[..., None] * B_[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_[:, 0])[:, None]
        hT = h
    else:
        y, hT = _selective_scan_chunked(xc, dt, B_, C_, A, h0, s.chunk)
    y = y + p["D"] * xc
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    new_state = Mamba1State(new_conv, hT) if (return_state or state is not None) else None
    return out, new_state


# ================================================================== Mamba2
class Mamba2State(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, conv_dim)
    h: jnp.ndarray      # (B, nheads, headdim, d_state) f32


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nheads = d_in // s.headdim
    G = s.n_groups
    ks = jax.random.split(key, 10)
    return {
        "in_z": _dense_init(ks[0], (d, d_in), dtype),
        "in_x": _dense_init(ks[1], (d, d_in), dtype),
        "in_B": _dense_init(ks[2], (d, G * s.d_state), dtype),
        "in_C": _dense_init(ks[3], (d, G * s.d_state), dtype),
        "in_dt": _dense_init(ks[4], (d, nheads), dtype),
        # separate depthwise convs for x / B / C: concatenating the 'model'-
        # sharded x with replicated B/C would force a gather at every use
        # (§Perf cell B iteration 3); depthwise conv is channelwise so the
        # split is mathematically identical
        "conv_x_w": _dense_init(ks[5], (s.d_conv, d_in), dtype, scale=0.5),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_B_w": _dense_init(ks[7], (s.d_conv, G * s.d_state), dtype, scale=0.5),
        "conv_B_b": jnp.zeros((G * s.d_state,), dtype),
        "conv_C_w": _dense_init(ks[8], (s.d_conv, G * s.d_state), dtype, scale=0.5),
        "conv_C_b": jnp.zeros((G * s.d_state,), dtype),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "A_log": jnp.zeros((nheads,), jnp.float32),        # A = -exp(A_log) = -1
        "D": jnp.ones((nheads,), jnp.float32),
        "norm": init_rmsnorm(d_in, dtype),
        "out_proj": _dense_init(ks[6], (d_in, d), dtype),
    }


def _segsum(x):
    """x: (..., L) -> (..., L, L) lower-triangular cumulative sums
    segsum[..., i, j] = sum_{k=j+1..i} x[..., k]  (i >= j), -inf above diag."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xh, dt, B_, C_, A, h0, chunk: int):
    """SSD (Mamba2) chunked algorithm.

    xh: (B, T, H, P) f32; dt: (B, T, H) f32 (post-softplus);
    B_, C_: (B, T, G, N) f32; A: (H,) f32 (negative); h0: (B, H, P, N) f32.
    Returns (y (B,T,H,P), hT).
    """
    Bsz, T, H, P = xh.shape
    G, N = B_.shape[2], B_.shape[3]
    Lc = min(chunk, T)
    assert T % Lc == 0
    nc = T // Lc
    rep = H // G

    def to_chunks(t):
        return t.reshape(Bsz, nc, Lc, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xc, dtc = to_chunks(xh), to_chunks(dt)
    Bc, Cc = to_chunks(B_), to_chunks(C_)

    def chunk_step(h, args):
        x_, dt_, b_, c_ = args                   # (B, Lc, H, P), (B, Lc, H), (B, Lc, G, N)
        da = dt_ * A                             # (B, Lc, H)
        # intra-chunk (diagonal blocks)
        L = jnp.exp(_segsum(da.transpose(0, 2, 1)))          # (B, H, Lc, Lc)
        bg = jnp.repeat(b_, rep, axis=2)                     # (B, Lc, H, N)
        cg = jnp.repeat(c_, rep, axis=2)
        scores = jnp.einsum("blhn,bshn->bhls", cg, bg)       # (B,H,Lc,Lc)
        M = scores * L
        y_diag = jnp.einsum("bhls,bsh,bshp->blhp", M, dt_, x_)
        # chunk state contribution from h (carry)
        a_cum = jnp.exp(jnp.cumsum(da, axis=1))              # (B, Lc, H)
        y_off = jnp.einsum("blhn,bhpn->blhp", cg, h) * a_cum[..., None]
        # new carry
        a_tail = jnp.exp(jnp.cumsum(da, axis=1)[:, -1:, :] - jnp.cumsum(da, axis=1))  # prod a_{s+1..Lc}
        S = jnp.einsum("bshn,bsh,bshp->bhpn", bg * a_tail[..., None], dt_, x_)
        a_all = jnp.exp(jnp.sum(da, axis=1))                 # (B, H)
        h_new = h * a_all[..., None, None] + S
        return h_new, y_diag + y_off

    hT, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, T, H, P)
    return y, hT


def mamba2_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 state: Optional[Mamba2State] = None,
                 return_state: bool = False,
                 ) -> Tuple[jnp.ndarray, Optional[Mamba2State]]:
    s = cfg.ssm
    B, T, d = x.shape
    d_in = s.expand * d
    H = d_in // s.headdim
    P, G, N = s.headdim, s.n_groups, s.d_state

    z = constrain(x @ p["in_z"], ("batch", "seq", "ssm_ch"))
    xx = constrain(x @ p["in_x"], ("batch", "seq", "ssm_ch"))
    xB = x @ p["in_B"]
    xC = x @ p["in_C"]
    dt_raw = (x @ p["in_dt"]).astype(jnp.float32)
    # decode conv state holds the concatenated (x|B|C) trailing window; the
    # slices are tiny so splitting it is free
    cs = state.conv if state is not None else None
    cs_x = cs[..., :d_in] if cs is not None else None
    cs_B = cs[..., d_in:d_in + G * N] if cs is not None else None
    cs_C = cs[..., d_in + G * N:] if cs is not None else None
    x_c, ncv_x = causal_conv1d(xx, p["conv_x_w"], p["conv_x_b"], cs_x)
    B_c, ncv_B = causal_conv1d(xB, p["conv_B_w"], p["conv_B_b"], cs_B)
    C_c, ncv_C = causal_conv1d(xC, p["conv_C_w"], p["conv_C_b"], cs_C)
    new_conv = jnp.concatenate([ncv_x, ncv_B, ncv_C], axis=-1)
    # heads stay sharded through the SSD scan (B/C are per-group, replicated)
    xh = constrain(jax.nn.silu(x_c.astype(jnp.float32)).reshape(B, T, H, P),
                   ("batch", "seq", "ssm_heads", None))
    B_ = jax.nn.silu(B_c.astype(jnp.float32)).reshape(B, T, G, N)
    C_ = jax.nn.silu(C_c.astype(jnp.float32)).reshape(B, T, G, N)
    dt = constrain(jax.nn.softplus(dt_raw + p["dt_bias"]),
                   ("batch", "seq", "ssm_heads"))                 # (B,T,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)

    h0 = state.h if state is not None else jnp.zeros((B, H, P, N), jnp.float32)
    if T == 1 and state is not None:
        a = jnp.exp(dt[:, 0] * A)                                  # (B,H)
        rep = H // G
        bg = jnp.repeat(B_[:, 0], rep, axis=1)                     # (B,H,N)
        cg = jnp.repeat(C_[:, 0], rep, axis=1)
        dbx = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, 0], xh[:, 0], bg)
        h = a[..., None, None] * h0 + dbx
        y = jnp.einsum("bhpn,bhn->bhp", h, cg)[:, None]            # (B,1,H,P)
        hT = h
    else:
        y, hT = _ssd_chunked(xh, dt, B_, C_, A, h0, s.chunk)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, T, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = y @ p["out_proj"]
    new_state = Mamba2State(new_conv, hT) if (return_state or state is not None) else None
    return out, new_state


def init_ssm_block(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    return init_mamba1(key, cfg, dtype) if cfg.ssm.version == 1 else init_mamba2(key, cfg, dtype)


def ssm_block(p, cfg, x, state=None, return_state=False):
    fn = mamba1_block if cfg.ssm.version == 1 else mamba2_block
    return fn(p, cfg, x, state, return_state)
