"""Mixture-of-Experts layer with sort-based capacity dispatch.

Design notes
------------
* Dispatch is scatter/gather based (argsort by expert id), NOT one-hot einsum:
  the one-hot formulation adds O(T * E * C * d) fake FLOPs that would dominate
  the roofline for 64-128 expert models.  Here compute is exactly
  ``2 * 3 * E * C * d * ff`` with ``E*C ~= top_k * T * capacity_factor``
  (the true active-FLOPs of a capacity-bounded MoE).
* Experts are stacked on a leading E axis -> sharded over the "model" mesh axis
  (expert parallelism).  Tokens routed over capacity are dropped (standard
  capacity-factor semantics); the load-balancing auxiliary loss keeps routing
  near-uniform.
* Router math in f32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.axes import constrain
from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import Params, _dense_init, init_mlp, mlp


def moe_capacity(m: MoEConfig, n_tokens: int) -> int:
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.n_routed)
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.n_routed
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "w_gate": _dense_init(ks[1], (E, d, ff), dtype),
        "w_up": _dense_init(ks[2], (E, d, ff), dtype),
        "w_down": _dense_init(ks[3], (E, ff, d), dtype),
    }
    if m.n_shared > 0:
        p["shared"] = init_mlp(ks[4], d, m.n_shared * ff, dtype)
    return p


def _dispatch_ffn_combine(xf, top_w, top_i, w_gate, w_up, w_down,
                          m: MoEConfig, C: int, e0: int) -> jnp.ndarray:
    """Sort-based dispatch + expert FFN + weighted combine for the LOCAL
    expert block [e0, e0+Eb) over the LOCAL token shard.

    xf: (N, d); top_w/top_i: (N, K); w_*: (Eb, d, f)/(Eb, f, d).
    Returns the partial output (N, d) f32 (zeros for tokens whose expert is
    outside this block) — the caller sums partials over the expert axis.
    """
    N, d = xf.shape
    K = top_w.shape[1]
    Eb = w_gate.shape[0]
    E = m.n_routed

    flat_e = top_i.reshape(-1)                                          # (N*K,)
    flat_w = top_w.reshape(-1)
    tok = jnp.arange(N * K, dtype=jnp.int32) // K
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                                # (E,)
    pos_sorted = jnp.arange(N * K, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((N * K,), jnp.int32).at[order].set(pos_sorted)      # slot in expert
    local_e = flat_e - e0
    keep = (pos < C) & (local_e >= 0) & (local_e < Eb)
    slot = jnp.where(keep, local_e * C + pos, Eb * C)                   # OOB -> dropped

    buf = jnp.zeros((Eb * C, d), xf.dtype).at[slot].set(xf[tok], mode="drop")
    eb = buf.reshape(Eb, C, d)

    # ---- expert FFN (active FLOPs only) ------------------------------------
    g = jnp.einsum("ecd,edf->ecf", eb, w_gate)
    u = jnp.einsum("ecd,edf->ecf", eb, w_up)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xf.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(Eb * C, d)

    # ---- combine ------------------------------------------------------------
    safe_slot = jnp.where(keep, slot, 0)
    gathered = y[safe_slot].astype(jnp.float32) * (flat_w * keep)[:, None]
    return jnp.zeros((N, d), jnp.float32).at[tok].add(gathered)


def _routing(p: Params, m: MoEConfig, xf: jnp.ndarray):
    """Router softmax + top-k + Switch-style load-balancing aux loss."""
    N = xf.shape[0]
    E, K = m.n_routed, m.top_k
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                             # (N, K)
    if m.router_norm_topk:
        top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)
    me = jnp.mean(probs, axis=0)                                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (N * K)
    aux_loss = E * jnp.sum(me * ce)
    return top_w, top_i, aux_loss


def moe_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar f32).

    Two paths:
      * sharded (production, active when a mesh/logical-rules context is
        installed): explicit shard_map — tokens stay sharded over the DP axes,
        experts over "model" (EP).  Each device routes ITS tokens, builds the
        dispatch buffer for ITS expert block only (capacity is per token
        shard), runs the block's FFN, and the partial outputs are psum'd over
        the expert axis.  No full-batch buffer is ever replicated — under
        plain GSPMD the scatter/gather dispatch was replicated per device
        (measured 145 GB/device at prefill_32k).
      * dense (single-device tests): same math on the full batch.
    """
    m = cfg.moe
    B, T, d = x.shape
    N = B * T

    sharded = _sharded_moe_context(N)
    if sharded is not None:
        mesh, dp_axes = sharded
        out, aux_loss = _moe_forward_shardmap(p, cfg, x, mesh, dp_axes)
    else:
        xf = x.reshape(N, d)
        top_w, top_i, aux_loss = _routing(p, m, xf)
        C = moe_capacity(m, N)
        out = _dispatch_ffn_combine(xf, top_w, top_i, p["w_gate"], p["w_up"],
                                    p["w_down"], m, C, e0=0)
        out = out.astype(x.dtype).reshape(B, T, d)

    if m.n_shared > 0:
        out = out + mlp(p["shared"], x)
    return out, aux_loss


def _sharded_moe_context(n_tokens: int):
    """Use the shard_map path iff logical rules are installed, the mesh has a
    'model' axis, and the token count divides evenly over the DP axes."""
    from repro.models import axes as AX
    active = AX.current_rules()
    if active is None:
        return None
    mesh, rules = active
    if "model" not in mesh.shape:
        return None
    bax = rules.get("batch")
    dp_axes = tuple() if bax is None else (
        bax if isinstance(bax, tuple) else (bax,))
    import numpy as _np
    dp = int(_np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    if n_tokens % max(dp, 1):
        return None
    return mesh, dp_axes


def _moe_forward_shardmap(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                          mesh, dp_axes) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    B, T, d = x.shape
    N = B * T
    E = m.n_routed
    ep = mesh.shape["model"]
    assert E % ep == 0, (E, ep)
    Eb = E // ep
    import numpy as _np
    dp = int(_np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    C_local = moe_capacity(m, N // dp)
    dp_spec = dp_axes if len(dp_axes) != 1 else dp_axes[0]

    def inner(xf, router, w_gate, w_up, w_down):
        # xf: (N/dp, d) local tokens; w_*: (Eb, ...) local expert block
        top_w, top_i, aux = _routing({"router": router}, m, xf)
        e0 = jax.lax.axis_index("model") * Eb
        partial = _dispatch_ffn_combine(xf, top_w, top_i, w_gate, w_up,
                                        w_down, m, C_local, e0)
        out = jax.lax.psum(partial, "model")            # combine expert blocks
        # aux identical across 'model' (same tokens); average over DP shards
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return out, aux

    from repro.compat import shard_map as _shard_map
    fn = _shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp_spec, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp_spec, None), P()),
        check=False)
    out, aux = fn(x.reshape(N, d), p["router"], p["w_gate"], p["w_up"],
                  p["w_down"])
    return out.astype(x.dtype).reshape(B, T, d), aux
