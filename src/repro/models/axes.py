"""Logical-axis sharding context.

Model code annotates activations with *logical* axis names; the launcher
installs a rules table mapping logical names -> mesh axes.  Without an active
context (CPU unit tests), ``constrain`` is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: Optional[Tuple[Mesh, dict]] = None


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: dict):
    """rules: logical name -> mesh axis (str | tuple | None)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = (mesh, dict(rules))
    try:
        yield
    finally:
        _ACTIVE = prev


def current_rules() -> Optional[Tuple[Mesh, dict]]:
    return _ACTIVE


def resolve(names: Sequence[Optional[str]]) -> Optional[P]:
    if _ACTIVE is None:
        return None
    _, rules = _ACTIVE
    return P(*[rules.get(n) if n is not None else None for n in names])


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Attach a sharding constraint using logical axis names (no-op w/o rules).

    Divisibility-guarded: an axis whose mesh size does not divide the tensor
    dim is dropped (e.g. "heads"->model on a 9-head model with tp=16)."""
    if _ACTIVE is None:
        return x
    mesh, rules = _ACTIVE
    spec = resolve(names)
    fixed = []
    for dim, ax in zip(x.shape, tuple(spec)):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(ax if (dim % size == 0 and dim >= size) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
