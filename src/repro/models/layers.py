"""Core transformer layers: norms, RoPE/M-RoPE, GQA / MLA attention, gated MLP.

Conventions
-----------
* All functions are pure; params are dicts of jnp arrays (bf16 by default).
* ``x``: (B, T, D) activations.  ``segment positions``: (B, T) int32.
* Attention supports: full causal, sliding-window causal, decode-with-KV-cache.
* Norms and softmax computed in f32, cast back to input dtype.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.axes import constrain
from repro.models.config import ModelConfig, MLAConfig

Params = dict
NEG_INF = -1e30


# --------------------------------------------------------------------------- init
def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_rmsnorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, T, H, hd); positions: (B, T) -> rotated x (same dtype)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): positions3 (3, B, T) = (t, h, w) ids.

    Frequency dims are split into 3 sections, each rotated by its own position
    stream.  ``sections`` counts frequency *pairs* per section and must sum to
    head_dim // 2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # per-frequency position selection
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=hd // 2)
    pos = positions3.astype(jnp.float32)                # (3, B, T)
    pos_per_freq = pos[sec_id]                          # (hd/2, B, T)
    ang = jnp.einsum("fbt,f->btf", pos_per_freq, freqs)  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- attention
class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, S, Hkv, hd)
    v: jnp.ndarray   # (B, S, Hkv, hd)
    # cache write index is carried by the caller (same for all layers)


def causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                window: Optional[int] = None) -> jnp.ndarray:
    """(B, Tq, Tk) boolean mask: True = attend."""
    m = q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        m &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    return m


# Query-chunk size for the scan-based attention path.  Chosen so the live
# (B/dp, H, CHUNK_Q, S) f32 logits block stays O(1 GB) per device for the
# assigned shapes (see DESIGN.md §8); the Pallas flash kernel replaces this
# entirely on real TPU.  Env-tunable for the §Perf chunk-size sweeps.
import os as _os
CHUNK_Q = int(_os.environ.get("REPRO_CHUNK_Q", "128"))
_CHUNK_THRESHOLD = 1 << int(_os.environ.get("REPRO_CHUNK_THRESHOLD_LOG2", "22"))


def _sdpa_block(q, k, v, q_pos, k_pos, window, valid, scale) -> jnp.ndarray:
    """One (possibly full) query block.  q: (B,T,H,hd); k/v: (B,S,Hkv,hd)."""
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32) * scale
    qg = qf.reshape(B, T, Hkv, g, hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k.astype(jnp.float32))
    mask = causal_mask(q_pos, k_pos, window)
    if valid is not None:
        mask = mask & valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def sdpa(q, k, v, q_pos, k_pos, window: Optional[int] = None,
         valid: Optional[jnp.ndarray] = None,
         scale: Optional[float] = None) -> jnp.ndarray:
    """Causal attention; scans over query chunks when T*S is large so the
    lowered HLO never materializes the full (T, S) score tensor.

    q: (B,T,H,hd); k/v: (B,S,Hkv,hd); q_pos: (B,T); k_pos: (B,S);
    valid: (B,S) cache-slot validity (decode/prefill-into-cache).
    """
    B, T, H, hd = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    if T * S <= _CHUNK_THRESHOLD or T % CHUNK_Q or T <= CHUNK_Q:
        return _sdpa_block(q, k, v, q_pos, k_pos, window, valid, scale)
    nc = T // CHUNK_Q

    # remat each chunk: backward recomputes the chunk's scores instead of
    # keeping nc stacked (B, H, CHUNK_Q, S) softmax residuals alive
    blk = jax.checkpoint(
        lambda qc, qpc: _sdpa_block(qc, k, v, qpc, k_pos, window, valid, scale),
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(_, xs):
        qc, qpc = xs
        return None, blk(qc, qpc)

    q_c = q.reshape(B, nc, CHUNK_Q, H, hd).transpose(1, 0, 2, 3, 4)
    qp_c = q_pos.reshape(B, nc, CHUNK_Q).transpose(1, 0, 2)
    _, outs = jax.lax.scan(body, None, (q_c, qp_c))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd)


def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense_init(ks[0], (d, H * hd), dtype),
        "wk": _dense_init(ks[1], (d, Hkv * hd), dtype),
        "wv": _dense_init(ks[2], (d, Hkv * hd), dtype),
        "wo": _dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray,
              cache: Optional[KVCache] = None,
              cache_index: Optional[jnp.ndarray] = None,
              window: Optional[int] = None,
              positions3: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """GQA attention.  Training: cache=None.  Decode: cache + cache_index.

    positions: (B, T) absolute positions of the query tokens.  Windowed layers
    use ring-buffer caches (cache length == window): slot = pos % W; stored keys
    carry RoPE at their absolute positions.
    """
    B, T, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # Megatron TP: heads stay sharded through rope/norm/attention; only wo's
    # row-parallel contraction reduces over 'model' (rules drop the axis when
    # head counts don't divide the TP axis).  Single-token decode skips the
    # constraints: there the layout must follow the donated cache, and the
    # extra reshard copies cost +9 GB/device (musicgen decode_32k, measured).
    def _maybe(t, names):
        return constrain(t, names) if T > 1 else t
    q = _maybe((x @ p["wq"]).reshape(B, T, H, hd),
               ("batch", "seq", "heads", None))
    k = _maybe((x @ p["wk"]).reshape(B, T, Hkv, hd),
               ("batch", "seq", "kv", None))
    v = _maybe((x @ p["wv"]).reshape(B, T, Hkv, hd),
               ("batch", "seq", "kv", None))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.mrope and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = sdpa(q, k, v, positions, positions, window)
        new_cache = None
        y = out.reshape(B, T, H * hd) @ p["wo"]
        return y, new_cache

    S = cache.k.shape[1]
    ring = window is not None and S <= window
    if ring and T > 1:
        # prefill into a ring buffer: attend full-sequence with window mask,
        # then store the last min(T, S) k/v at slots pos % S.
        out = sdpa(q, k, v, positions, positions, window)
        W = min(T, S)
        import numpy as _np
        slots = _np.arange(T - W, T) % S                  # static permutation
        ck = cache.k.at[:, slots].set(k[:, -W:].astype(cache.k.dtype))
        cv = cache.v.at[:, slots].set(v[:, -W:].astype(cache.v.dtype))
        new_cache = KVCache(ck, cv)
    elif ring:
        # decode with ring buffer
        slot = jnp.mod(cache_index, S)
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, slot, 0, 0))
        j = jnp.arange(S, dtype=jnp.int32)
        t_now = positions[:, -1:]                          # (B, 1)
        k_pos = t_now - jnp.mod(t_now - j[None, :], S)     # (B, S) abs pos of slot
        out = sdpa(q, ck, cv, positions, k_pos, window,
                   valid=(k_pos >= 0))
        new_cache = KVCache(ck, cv)
    else:
        # full cache: write new k/v at cache_index, attend over filled slots
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, cache_index, 0, 0))
        k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        valid = k_pos <= positions[:, -1:]  # (B, S): only filled slots
        out = sdpa(q, ck, cv, positions, k_pos, window, valid=valid)
        new_cache = KVCache(ck, cv)
    y = out.reshape(B, T, H * hd) @ p["wo"]
    return y, new_cache


# --------------------------------------------------------------------------- MLA
def init_mla(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d, H * qk_hd), dtype),
        "w_dkv": _dense_init(ks[1], (d, m.kv_lora_rank), dtype),
        "w_krope": _dense_init(ks[2], (d, m.qk_rope_head_dim), dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "w_uk": _dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "w_uv": _dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "wo": _dense_init(ks[5], (H * m.v_head_dim, d), dtype),
    }


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, S, kv_lora_rank) — compressed latent
    k_rope: jnp.ndarray  # (B, S, rope_dim) — shared rope key


def mla_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray,
                  cache: Optional[MLACache] = None,
                  cache_index: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, Optional[MLACache]]:
    """Multi-head Latent Attention (DeepSeek-V2).  Caches the 512-d latent
    + shared rope key instead of per-head K/V (the paper's KV-cache saving)."""
    m: MLAConfig = cfg.mla
    B, T, D = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = constrain((x @ p["wq"]).reshape(B, T, H, nope + rope_d),
                  ("batch", "seq", "heads", None))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)   # (B,T,r)
    k_rope_new = apply_rope((x @ p["w_krope"])[:, :, None, :],
                            positions, cfg.rope_theta)[:, :, 0, :]  # (B,T,rope_d)

    if cache is not None:
        c_kv_full = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache_index, 0))
        k_rope_full = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), (0, cache_index, 0))
        new_cache = MLACache(c_kv_full, k_rope_full)
        S = c_kv_full.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        valid = k_pos <= positions[:, -1:]
    else:
        c_kv_full, k_rope_full = c_kv, k_rope_new
        new_cache = None
        S = T
        k_pos = positions
        valid = None

    # expand latent to per-head K (nope part) and V
    k_nope = constrain((c_kv_full @ p["w_uk"]).reshape(B, S, H, nope),
                       ("batch", "seq", "heads", None))
    vv = constrain((c_kv_full @ p["w_uv"]).reshape(B, S, H, vd),
                   ("batch", "seq", "heads", None))
    scale = (nope + rope_d) ** -0.5

    def mla_block(qn, qr, qp):
        Tq = qn.shape[1]
        lg = jnp.einsum("bthn,bshn->bhts", qn.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
        lg += jnp.einsum("bthr,bsr->bhts", qr.astype(jnp.float32),
                         k_rope_full.astype(jnp.float32))
        mask = causal_mask(qp, k_pos)
        if valid is not None:
            mask = mask & valid[:, None, :]
        lg = jnp.where(mask[:, None, :, :], lg * scale, NEG_INF)
        w = jax.nn.softmax(lg, axis=-1)
        return jnp.einsum("bhts,bshv->bthv", w, vv.astype(jnp.float32))

    if T * S <= _CHUNK_THRESHOLD or T % CHUNK_Q or T <= CHUNK_Q:
        out = mla_block(q_nope, q_rope, positions)
    else:
        nc = T // CHUNK_Q
        blk = jax.checkpoint(mla_block,
                             policy=jax.checkpoint_policies.nothing_saveable)

        def body(_, xs):
            qn, qr, qp = xs
            return None, blk(qn, qr, qp)

        qn_c = q_nope.reshape(B, nc, CHUNK_Q, H, nope).transpose(1, 0, 2, 3, 4)
        qr_c = q_rope.reshape(B, nc, CHUNK_Q, H, rope_d).transpose(1, 0, 2, 3, 4)
        qp_c = positions.reshape(B, nc, CHUNK_Q).transpose(1, 0, 2)
        _, outs = jax.lax.scan(body, None, (qn_c, qr_c, qp_c))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, vd)
    y = out.reshape(B, T, H * vd).astype(x.dtype) @ p["wo"]
    return y, new_cache


# --------------------------------------------------------------------------- mlp
def init_mlp(key, d: int, ff: int, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, ff), dtype),
        "w_up": _dense_init(ks[1], (d, ff), dtype),
        "w_down": _dense_init(ks[2], (ff, d), dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    # Megatron TP: the hidden (ff) dim stays sharded through the elementwise
    # silu — only w_down's row-parallel contraction reduces over 'model'
    g = constrain(x @ p["w_gate"], ("batch", "seq", "ff"))
    u = constrain(x @ p["w_up"], ("batch", "seq", "ff"))
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32))
    return (h.astype(x.dtype)) @ p["w_down"]
