"""Online transfer-tuning controllers.

Each controller observes per-route flow telemetry from the simulated
transport at a fixed control interval (sim-clock driven, so every decision
is a pure function of the trajectory — trajectories stay bit-reproducible)
and adjusts either the schedulers' live per-route concurrency caps
(``ConcurrencyTuner``) or the bundle composer's soft size targets for
future cuts (``BundleSizeTuner``).

The lineage is the congestion-control family GridFTP adopted for WAN
transfers: additive-increase / multiplicative-decrease concurrency probing,
and hill-climbing on observed throughput for batch sizing.  ``StaticPolicy``
is represented by the *absence* of controllers — the control plane builds
none, and the declared caps/targets hold for the whole campaign.
"""
from __future__ import annotations

import abc
from typing import Dict, List, Optional, Tuple

Route = Tuple[str, str]


class Controller(abc.ABC):
    """One online tuner; ``act`` runs once per control interval."""
    kind: str = "?"

    @abc.abstractmethod
    def act(self, now: float, dt: float,
            telemetry: Dict[Route, Tuple[float, int]],
            plane) -> List[dict]:
        """Observe the interval's telemetry and apply adjustments through
        ``plane`` (the ControlPlane owning scheduler/composer access).
        Returns ledger entries for every decision taken."""

    @abc.abstractmethod
    def state_dict(self) -> dict: ...

    @abc.abstractmethod
    def load_state_dict(self, d: dict) -> None: ...


class ConcurrencyTuner(Controller):
    """AIMD per-route concurrency: probe upward one slot at a time while a
    route's throughput holds; halve toward the floor when throughput drops
    or the route's fault count spikes (the scheduler drains excess actives
    naturally — a lowered cap stops new starts, it never aborts transfers).
    """
    kind = "aimd"

    def __init__(self, policy):
        self.policy = policy
        self._last: Dict[Route, Tuple[float, int]] = {}  # route -> (bytes, faults)
        self._last_tput: Dict[Route, float] = {}

    def act(self, now, dt, telemetry, plane):
        entries: List[dict] = []
        pol = self.policy
        for route in sorted(telemetry):
            nbytes, nfaults = telemetry[route]
            lb, lf = self._last.get(route, (0.0, 0))
            self._last[route] = (nbytes, nfaults)
            tput = (nbytes - lb) / max(dt, 1e-9)
            dfaults = nfaults - lf
            prev = self._last_tput.get(route)
            self._last_tput[route] = tput
            cap = plane.route_cap(route)
            if dfaults > pol.fault_budget or (
                    prev is not None and prev > 0
                    and tput < prev * (1.0 - pol.drop_fraction)):
                new = max(pol.min_active_per_route, cap // 2)
            elif tput > 0:
                new = min(pol.max_active_per_route, cap + 1)
            else:
                continue                    # idle route: leave it alone
            if new == cap:
                continue
            plane.set_route_cap(route, new)
            entries.append({"controller": self.kind,
                            "route": list(route),
                            "cap": new, "prev_cap": cap,
                            "gbps": tput / 1024 ** 3,
                            "faults": dfaults})
        return entries

    def state_dict(self):
        return {"last": [[s, d, b, f]
                         for (s, d), (b, f) in self._last.items()],
                "last_tput": [[s, d, t]
                              for (s, d), t in self._last_tput.items()]}

    def load_state_dict(self, d):
        self._last = {(s, dst): (float(b), int(f))
                      for s, dst, b, f in d["last"]}
        self._last_tput = {(s, dst): float(t) for s, dst, t in d["last_tput"]}


class BundleSizeTuner(Controller):
    """Throughput-gradient bundle sizing: scale the composer's soft targets
    by ``bundle_growth`` in the current direction; reverse direction when
    aggregate throughput fell since the last interval.  Only affects bundles
    not yet cut — in-flight tasks are never resized."""
    kind = "gradient"

    def __init__(self, policy):
        self.policy = policy
        self._dir = 1.0
        self._last_bytes: Optional[float] = None
        self._last_tput: Optional[float] = None

    def act(self, now, dt, telemetry, plane):
        composer = plane.composer
        if composer is None or composer.done:
            return []
        total = sum(b for b, _ in telemetry.values())
        if self._last_bytes is None:
            self._last_bytes = total
            return []
        tput = (total - self._last_bytes) / max(dt, 1e-9)
        self._last_bytes = total
        prev, self._last_tput = self._last_tput, tput
        if prev is not None and tput < prev:
            self._dir = -self._dir
        g = self.policy.bundle_growth ** self._dir
        pol = self.policy
        composer.target_files = int(
            min(pol.max_files,
                max(pol.min_target_files, composer.target_files * g)))
        composer.target_bytes = int(
            min(pol.max_bytes,
                max(pol.min_target_bytes, composer.target_bytes * g)))
        return [{"controller": self.kind,
                 "target_files": composer.target_files,
                 "target_bytes": composer.target_bytes,
                 "gbps": tput / 1024 ** 3,
                 "direction": self._dir}]

    def state_dict(self):
        return {"dir": self._dir, "last_bytes": self._last_bytes,
                "last_tput": self._last_tput}

    def load_state_dict(self, d):
        self._dir = float(d["dir"])
        self._last_bytes = d["last_bytes"]
        self._last_tput = d["last_tput"]


def make_controllers(policy) -> List[Controller]:
    """Instantiate the policy's controller chain (empty for static)."""
    made: List[Controller] = []
    for name in policy.controller_names():
        if name == "aimd":
            made.append(ConcurrencyTuner(policy))
        elif name == "gradient":
            made.append(BundleSizeTuner(policy))
        else:                               # pragma: no cover - validated
            raise ValueError(f"unknown controller {name!r}")
    return made
