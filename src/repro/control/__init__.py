"""Control plane: file-level bundling and adaptive transfer-tuning policies.

``TransferPolicySpec`` declares the policy on a scenario; ``BundleComposer``
bin-packs the catalog into transfer tasks; ``ConcurrencyTuner`` /
``BundleSizeTuner`` steer live concurrency caps and future bundle sizing
from per-route flow telemetry; ``ControlPlane`` wires it all onto one
campaign runtime, checkpointable down to the cursor.
"""
from repro.control.bundles import (BUNDLE_PREFIX, BalancedPacker,
                                   BundleCaps, BundleComposer, BundleItem,
                                   BundlePolicy, GreedyPacker, make_packer)
from repro.control.controllers import (BundleSizeTuner, ConcurrencyTuner,
                                       Controller, make_controllers)
from repro.control.plane import ControlPlane, PolicyLedger
from repro.control.policy import STATIC_POLICY, TransferPolicySpec

__all__ = [
    "BUNDLE_PREFIX", "BalancedPacker", "BundleCaps", "BundleComposer",
    "BundleItem", "BundlePolicy", "BundleSizeTuner", "ConcurrencyTuner",
    "ControlPlane", "Controller", "GreedyPacker", "PolicyLedger",
    "STATIC_POLICY", "TransferPolicySpec", "make_controllers", "make_packer",
]
