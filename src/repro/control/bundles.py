"""Bundle composition: bin-packing a catalog's files/datasets into transfer
tasks under ``max_files``/``max_bytes`` caps.

The paper's replication tool moved 28.9 M files as a few thousand *large*
Globus tasks — never one task per file — because every task carries fixed
dispatch/scan overhead that tiny transfers cannot amortize.  The
``BundleComposer`` reproduces that: it walks the catalog in deterministic
(sorted-path) order and cuts it into **bundles**, synthetic ``Dataset``s the
scheduler treats exactly like ordinary catalog entries (one transfer-table
row per (bundle, destination), relays and retries included).

Two packers sit behind one ``BundlePolicy`` interface:

  * ``GreedyPacker``   — first-fit in catalog order: accumulate items until
    the next one would exceed the current soft targets or hard caps;
  * ``BalancedPacker`` — LPT batches: pull the next window of items (sized
    for ``balance_batch`` bundles), sort by bytes descending, and assign
    each to the lightest open bundle the hard caps allow.

Composition is **lazy**: bundles are cut on demand (the control plane keeps
``lookahead`` bundles ahead of the scheduler), so an online bundle-size
tuner can steer the targets for *future* cuts mid-campaign.  The cursor —
(dataset index, intra-dataset file index) plus the already-cut bundle
definitions — serializes into the campaign snapshot, and re-cutting from a
restored cursor is bit-deterministic: the item stream is a pure function of
the catalog and the scenario seed.

Invariants (pinned by a hypothesis property test): every item lands in
exactly one bundle; no bundle exceeds ``max_files``/``max_bytes`` unless a
single item already does; packing is deterministic for a fixed seed.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.routes import Dataset

BUNDLE_PREFIX = "/bundle/"


@dataclass(frozen=True)
class BundleItem:
    """One packable unit: a whole dataset, or a run of consecutive files of
    one (``<path>#<start>:<end>`` manifest indices).  File items come as
    runs — never one Python object per file — so composing a 29M-file
    catalog costs O(bundles) interpreter work, not O(files)."""
    key: str                  # dataset path, or "<dataset path>#<a>:<b>"
    bytes: int
    files: int
    dirs: float               # fractional for file items; summed per bundle
    unreadable: bool


@dataclass
class BundleCaps:
    """Effective per-bundle limits at cut time: the policy's hard caps
    min'd with the tuner's current soft targets."""
    max_files: int
    max_bytes: int


class BundlePolicy(abc.ABC):
    """A packer: consume items from the composer's cursor, emit bundles."""

    @abc.abstractmethod
    def pack(self, composer: "BundleComposer",
             caps: BundleCaps) -> List[List[BundleItem]]:
        """Cut the next bundle(s) from the cursor; each inner list is one
        bundle's membership, in emission order.  Must consume at least one
        item when any remain."""


class GreedyPacker(BundlePolicy):
    def pack(self, composer, caps):
        items: List[BundleItem] = []
        nbytes = nfiles = 0
        while True:
            it = composer.peek()
            if it is None:
                break
            if items and (nfiles + it.files > caps.max_files
                          or nbytes + it.bytes > caps.max_bytes):
                break
            composer.advance()
            items.append(it)
            nbytes += it.bytes
            nfiles += it.files
        return [items] if items else []


class BalancedPacker(BundlePolicy):
    """Longest-processing-time packing over a bounded item window: spreads
    the heavy tail of the (lognormal) size distribution across bundles so no
    single bundle serializes the route behind one giant task."""

    def __init__(self, batch: int):
        self.batch = max(1, batch)

    def pack(self, composer, caps):
        window: List[BundleItem] = []
        budget = caps.max_bytes * self.batch
        nbytes = 0
        while True:
            it = composer.peek()
            if it is None:
                break
            if window and nbytes + it.bytes > budget:
                break
            composer.advance()
            window.append(it)
            nbytes += it.bytes
        if not window:
            return []
        # LPT: largest first into the lightest bundle the caps allow;
        # ties break on window order (stable sort), so packing is a pure
        # function of the item stream
        order = sorted(range(len(window)),
                       key=lambda i: (-window[i].bytes, i))
        bundles: List[List[int]] = [[] for _ in range(self.batch)]
        loads = [0] * self.batch
        counts = [0] * self.batch
        for i in order:
            it = window[i]
            fit = [b for b in range(len(bundles))
                   if not bundles[b]
                   or (loads[b] + it.bytes <= caps.max_bytes
                       and counts[b] + it.files <= caps.max_files)]
            if not fit:
                bundles.append([])
                loads.append(0)
                counts.append(0)
                fit = [len(bundles) - 1]
            b = min(fit, key=lambda j: (loads[j], j))
            bundles[b].append(i)
            loads[b] += it.bytes
            counts[b] += it.files
        # emit in window order of each bundle's earliest item, so bundle
        # numbering (and hence table-row order) is deterministic
        out = [sorted(b) for b in bundles if b]
        out.sort(key=lambda idxs: idxs[0])
        return [[window[i] for i in idxs] for idxs in out]


def make_packer(policy) -> BundlePolicy:
    if policy.bundling == "greedy":
        return GreedyPacker()
    if policy.bundling == "balanced":
        return BalancedPacker(policy.balance_batch)
    raise ValueError(f"bundling {policy.bundling!r} has no packer")


class BundleComposer:
    """Lazy, checkpointable composition of a catalog into bundle datasets.

    ``bundle_catalog`` is the live dict the scheduler resolves transfer rows
    against; it grows as bundles are cut.  ``members`` maps each bundle path
    to its item keys for introspection (dashboards, tests) — it is NOT part
    of the snapshot; a resumed composer re-derives only what the trajectory
    needs (the bundle datasets themselves plus the cursor)."""

    def __init__(self, catalog: Dict[str, Dataset], policy, seed: int = 0,
                 namespace: str = ""):
        policy.validate()
        self.policy = policy
        self.seed = seed
        # bundle paths are namespaced per campaign so N federated members
        # bundling over one shared transport can never collide
        self.namespace = namespace
        self._catalog = catalog
        self._paths = sorted(catalog)
        self._packer = make_packer(policy)
        self.target_files = int(policy.target_files)
        self.target_bytes = int(policy.target_bytes)
        self.bundle_catalog: Dict[str, Dataset] = {}
        self.members: Dict[str, List[str]] = {}
        self._ds_i = 0                      # cursor: dataset index
        self._file_i = 0                    # cursor: file index within it
        self._emitted = 0
        self._sizes_cache: Tuple[int, Optional[np.ndarray]] = (-1, None)

    # file runs are bounded at 1/RUN_DIVISOR of the effective caps, so a
    # bundle still packs several items (LPT has something to balance) while
    # composition stays O(bundles)
    RUN_DIVISOR = 4

    # ------------------------------------------------------------ item stream
    def _file_cumsum(self, ds_i: int) -> np.ndarray:
        """Cumulative synthesized per-file byte sizes for dataset ``ds_i``
        (its manifest): lognormal weights, integer-partitioned to sum
        exactly to the dataset's bytes.  Pure function of
        (seed, ds_i, catalog)."""
        if self._sizes_cache[0] == ds_i:
            return self._sizes_cache[1]
        ds = self._catalog[self._paths[ds_i]]
        n = max(1, ds.files)
        rng = np.random.default_rng([self.seed, ds_i])
        w = rng.lognormal(mean=0.0, sigma=1.2, size=n)
        w = w / w.sum()
        sizes = np.floor(w * ds.bytes).astype(np.int64)
        sizes[0] += ds.bytes - int(sizes.sum())
        csum = np.cumsum(sizes)
        self._sizes_cache = (ds_i, csum)
        return csum

    def _file_run_end(self, ds_i: int, i: int) -> int:
        """End index (exclusive) of the file run starting at manifest index
        ``i``: as many consecutive files as fit under 1/RUN_DIVISOR of the
        current effective caps — always at least one file."""
        caps = self._caps()
        csum = self._file_cumsum(ds_i)
        base = int(csum[i - 1]) if i else 0
        limit = base + max(1, caps.max_bytes // self.RUN_DIVISOR)
        j = int(np.searchsorted(csum, limit, side="right"))
        j = min(j, i + max(1, caps.max_files // self.RUN_DIVISOR), len(csum))
        return max(j, i + 1)

    def peek(self) -> Optional[BundleItem]:
        """The item at the cursor, or None when the catalog is consumed."""
        if self._ds_i >= len(self._paths):
            return None
        path = self._paths[self._ds_i]
        ds = self._catalog[path]
        if self.policy.granularity == "dataset":
            return BundleItem(path, ds.bytes, ds.files,
                              float(ds.directories), ds.unreadable)
        csum = self._file_cumsum(self._ds_i)
        i = self._file_i
        j = self._file_run_end(self._ds_i, i)
        base = int(csum[i - 1]) if i else 0
        return BundleItem(f"{path}#{i}:{j}", int(csum[j - 1]) - base, j - i,
                          ds.directories * (j - i) / max(1, ds.files),
                          ds.unreadable)

    def advance(self) -> None:
        if self._ds_i >= len(self._paths):
            return
        if self.policy.granularity == "dataset":
            self._ds_i += 1
            return
        ds = self._catalog[self._paths[self._ds_i]]
        self._file_i = self._file_run_end(self._ds_i, self._file_i)
        if self._file_i >= max(1, ds.files):
            self._ds_i += 1
            self._file_i = 0

    @property
    def done(self) -> bool:
        return self._ds_i >= len(self._paths)

    # ------------------------------------------------------------------- cuts
    def _caps(self) -> BundleCaps:
        return BundleCaps(
            max_files=min(self.policy.max_files, max(1, self.target_files)),
            max_bytes=min(self.policy.max_bytes, max(1, self.target_bytes)))

    def _emit(self, items: List[BundleItem]) -> Dataset:
        ns = f"{self.namespace}/" if self.namespace else ""
        path = f"{BUNDLE_PREFIX}{ns}b-{self._emitted:06d}"
        self._emitted += 1
        ds = Dataset(
            path=path,
            bytes=sum(it.bytes for it in items),
            files=sum(it.files for it in items),
            directories=max(1, int(sum(it.dirs for it in items))),
            unreadable=any(it.unreadable for it in items))
        self.bundle_catalog[path] = ds
        self.members[path] = [it.key for it in items]
        return ds

    def cut_next(self) -> List[Dataset]:
        """Cut the next bundle (greedy) or batch of bundles (balanced) at
        the current targets; returns the emitted bundle datasets (empty only
        when the catalog is consumed)."""
        return [self._emit(items)
                for items in self._packer.pack(self, self._caps())]

    def compose_all(self) -> List[Dataset]:
        """Cut until the catalog is consumed (eager mode: tests, one-shot
        composition studies)."""
        out: List[Dataset] = []
        while not self.done:
            cut = self.cut_next()
            if not cut:
                break
            out.extend(cut)
        return out

    # ------------------------------------------------------------ checkpoints
    def state_dict(self) -> dict:
        """JSON-serializable cursor + targets + the already-cut bundle
        datasets (the scheduler's rows refer to them by path; memberships
        are derivable and not needed to continue the trajectory)."""
        return {
            "ds_i": self._ds_i,
            "file_i": self._file_i,
            "emitted": self._emitted,
            "target_files": self.target_files,
            "target_bytes": self.target_bytes,
            "bundles": [[d.path, d.bytes, d.files, d.directories,
                         d.unreadable]
                        for d in self.bundle_catalog.values()],
        }

    def load_state_dict(self, d: dict) -> None:
        self._ds_i = int(d["ds_i"])
        self._file_i = int(d["file_i"])
        self._emitted = int(d["emitted"])
        self.target_files = int(d["target_files"])
        self.target_bytes = int(d["target_bytes"])
        self.bundle_catalog.clear()
        self.members.clear()
        for path, nbytes, nfiles, dirs, unreadable in d["bundles"]:
            self.bundle_catalog[path] = Dataset(
                path=path, bytes=int(nbytes), files=int(nfiles),
                directories=int(dirs), unreadable=bool(unreadable))
        self._sizes_cache = (-1, None)
