"""Declarative transfer-tuning policies (paper §3: "Globus organized the
transfers to make efficient use of ESnet"; GridFTP 2001: bundle composition
and online concurrency control dominate achieved throughput for
many-small-file workloads).

A ``TransferPolicySpec`` declares, on a ``ScenarioSpec`` (or for every member
of a ``FederationSpec``), how the control plane should turn a catalog into
transfer tasks and how it should steer them while they run:

  * **bundling** — how files/datasets are bin-packed into transfer tasks
    (the paper's tool moved 29 M files by submitting *large bundles* as
    Globus tasks, never one task per file):

      - ``"dataset"``  — the pre-control-plane model: one task per catalog
        dataset (the bit-identity baseline);
      - ``"greedy"``   — first-fit in catalog order up to the size targets;
      - ``"balanced"`` — LPT batches: the next window of items is packed
        into size-balanced bundles (largest item to the lightest bundle).

  * **granularity** — what the packer's items are: whole ``"dataset"``
    trees, or individual ``"file"``s from per-dataset manifests
    (synthesized deterministically from the scenario seed).

  * **controller** — the online tuner observing per-route flow telemetry
    each control interval: ``"static"`` (no adjustment — the declared caps
    and targets hold for the whole campaign), ``"aimd"`` (additive-increase
    / multiplicative-decrease concurrency tuning), ``"gradient"``
    (hill-climbing bundle-size tuning), or a ``"+"``-joined combination
    such as ``"aimd+gradient"``.

The default spec — per-dataset tasks, static everything — compiles to **no
control plane at all**: a scenario that does not opt in runs exactly the
code path (and trajectory) it ran before this subsystem existed.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.routes import GB, TB

KNOWN_BUNDLING = ("dataset", "greedy", "balanced")
KNOWN_GRANULARITY = ("dataset", "file")
KNOWN_CONTROLLERS = ("static", "aimd", "gradient")


@dataclass(frozen=True)
class TransferPolicySpec:
    """How a campaign composes transfer tasks and tunes them online."""
    # ---- bundle composition
    bundling: str = "dataset"          # dataset | greedy | balanced
    granularity: str = "dataset"       # dataset | file (per-dataset manifests)
    max_files: int = 1_000_000         # hard cap per bundle (scan-memory safe)
    max_bytes: int = 100 * TB          # hard cap per bundle
    target_files: int = 50_000         # initial soft target per bundle
    target_bytes: int = 20 * TB        # initial soft target per bundle
    lookahead: int = 4                 # bundles kept composed ahead of the scheduler
    balance_batch: int = 4             # bundles per LPT batch ("balanced" only)
    # ---- online control
    controller: str = "static"         # static | aimd | gradient | a+b
    control_interval_s: float = 6 * 3600.0
    min_active_per_route: int = 1      # AIMD floor
    max_active_per_route: int = 8      # AIMD ceiling
    fault_budget: int = 8              # faults/route/interval before backoff
    drop_fraction: float = 0.15        # tput drop triggering AIMD decrease
    bundle_growth: float = 1.3         # gradient tuner step factor
    min_target_files: int = 1_000     # gradient tuner floor
    min_target_bytes: int = 64 * GB    # gradient tuner floor

    # ------------------------------------------------------------- helpers
    @property
    def enabled(self) -> bool:
        """True when this policy needs a live control plane (any deviation
        from the implicit one-dataset-one-task / fixed-caps model)."""
        return self.bundling != "dataset" or self.controller != "static"

    def controller_names(self):
        names = tuple(n for n in self.controller.split("+") if n != "static")
        return names

    def validate(self) -> None:
        if self.bundling not in KNOWN_BUNDLING:
            raise ValueError(f"unknown bundling {self.bundling!r}; "
                             f"expected one of {KNOWN_BUNDLING}")
        if self.granularity not in KNOWN_GRANULARITY:
            raise ValueError(f"unknown granularity {self.granularity!r}; "
                             f"expected one of {KNOWN_GRANULARITY}")
        for name in self.controller.split("+"):
            if name not in KNOWN_CONTROLLERS:
                raise ValueError(f"unknown controller {name!r}; expected "
                                 f"'+'-joined {KNOWN_CONTROLLERS}")
        if self.granularity == "file" and self.bundling == "dataset":
            raise ValueError("granularity='file' requires a bundling packer "
                             "(greedy or balanced)")
        if self.max_files < 1 or self.max_bytes < 1:
            raise ValueError("bundle hard caps must be positive")
        if self.min_active_per_route < 1 \
                or self.max_active_per_route < self.min_active_per_route:
            raise ValueError("need 1 <= min_active_per_route "
                             "<= max_active_per_route")


# the naive pre-control-plane baseline, usable anywhere a policy is expected
STATIC_POLICY = TransferPolicySpec()
