"""The control plane: one per campaign runtime, wiring a bundle composer
and a controller chain onto the scheduler/transport pair.

Responsibilities, all driven from the run loop at iteration boundaries (so
every action lands at a deterministic point of the trajectory):

  * **bundle feed** — keep roughly ``lookahead`` bundles composed ahead of
    the scheduler (each cut bundle occupies one pending row per replica):
    cut from the composer's cursor and insert the fresh
    (bundle, destination) rows into the transfer table, which routes them
    into the scheduler's pending queues through the ordinary row-listener
    path (exactly how incremental top-ups enter a campaign);
  * **online control** — every ``control_interval_s`` of sim time, hand the
    transport's per-route telemetry to the controller chain, which adjusts
    live per-route concurrency caps (``ReplicationPolicy.route_caps``) and
    the composer's future-bundle targets;
  * **policy telemetry ledger** — record every decision with its observed
    throughput, feeding the dashboard's policy view and
    ``benchmarks/campaign_replay.py --policy-bench``.

Everything here serializes: the composer cursor, controller internals, live
route caps, the control clock, and the ledger all land in the (version-
bumped) campaign snapshot, so a kill-at-any-iteration resume continues the
controlled trajectory bit-identically.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.control.bundles import BundleComposer
from repro.control.controllers import make_controllers
from repro.control.policy import TransferPolicySpec
from repro.core.routes import DAY
from repro.core.transfer_table import Status

Route = Tuple[str, str]


class PolicyLedger:
    """Append-only record of control decisions (bounded by the number of
    control intervals, not by catalog size)."""

    def __init__(self):
        self.entries: List[dict] = []

    def record(self, now: float, entry: dict) -> None:
        self.entries.append(dict(entry, t_day=round(now / DAY, 6)))

    def state_dict(self) -> list:
        return [dict(e) for e in self.entries]

    def load_state_dict(self, entries: list) -> None:
        self.entries = [dict(e) for e in entries]


class ControlPlane:
    def __init__(self, policy: TransferPolicySpec, sched, transport,
                 source: str, replicas,
                 composer: Optional[BundleComposer] = None,
                 label: str = "campaign"):
        policy.validate()
        self.policy = policy
        self.sched = sched
        self.transport = transport
        self.source = source
        self.replicas = tuple(replicas)
        self.composer = composer
        self.label = label
        self.controllers = make_controllers(policy)
        self.ledger = PolicyLedger()
        self._next_control: Optional[float] = None
        self._last_control: Optional[float] = None

    # ------------------------------------------------------------ cap access
    def route_cap(self, route: Route) -> int:
        return self.sched.policy.cap(*route)

    def set_route_cap(self, route: Route, cap: int) -> None:
        self.sched.policy.route_caps[route] = int(cap)

    # ---------------------------------------------------------------- stepping
    def step(self, now: float) -> None:
        """One control-plane pass at a run-loop boundary: top up the bundle
        feed, then run the controller chain if a control interval elapsed."""
        self._feed_bundles()
        if not self.controllers:
            return
        if self._next_control is None:       # first boundary anchors the clock
            self._last_control = now
            self._next_control = now + self.policy.control_interval_s
            return
        if now + 1e-9 < self._next_control:
            return
        dt = now - self._last_control
        telemetry = self._own_routes(self.transport.route_telemetry())
        for c in self.controllers:
            for entry in c.act(now, dt, telemetry, self):
                self.ledger.record(now, entry)
        self._last_control = now
        self._next_control = now + self.policy.control_interval_s

    def _own_routes(self, telemetry: Dict[Route, Tuple[float, int]]
                    ) -> Dict[Route, Tuple[float, int]]:
        """Restrict shared-transport telemetry to routes THIS campaign can
        schedule on (source→replica and replica→replica relays).  In a
        federation the transport's counters cover every member's traffic;
        without the filter a member's tuner would write caps and ledger
        entries for routes its scheduler never starts."""
        mine = {self.source, *self.replicas}
        return {(src, dst): v for (src, dst), v in telemetry.items()
                if dst in self.replicas and src in mine}

    def _feed_bundles(self) -> None:
        if self.composer is None or self.composer.done:
            return
        table = self.sched.table
        want = max(1, self.policy.lookahead) * len(self.replicas)
        while not self.composer.done and table.count_status(Status.NULL) < want:
            cut = self.composer.cut_next()
            if not cut:
                break
            for b in cut:
                table.populate([b.path], self.source, list(self.replicas))

    def exhausted(self) -> bool:
        """True when no future work can still originate here (the run loop's
        completion check: a campaign is done only when its table is drained
        AND its composer has nothing left to cut)."""
        return self.composer is None or self.composer.done

    def next_action(self, now: float) -> float:
        """Next sim time this plane must run regardless of transfer events
        (the controllers' interval boundary); ``inf`` for pure bundling."""
        if not self.controllers:
            return float("inf")
        if self._next_control is None:
            return now                       # anchor on the next boundary
        return self._next_control

    # ------------------------------------------------------------ checkpoints
    def state_dict(self) -> dict:
        return {
            "composer": (self.composer.state_dict()
                         if self.composer is not None else None),
            "controllers": {c.kind: c.state_dict() for c in self.controllers},
            "route_caps": [[s, d, c]
                           for (s, d), c in
                           sorted(self.sched.policy.route_caps.items())],
            "next_control": self._next_control,
            "last_control": self._last_control,
            "ledger": self.ledger.state_dict(),
        }

    def load_state_dict(self, d: dict) -> None:
        if (d["composer"] is None) != (self.composer is None):
            raise ValueError("snapshot/world disagree about bundle "
                             "composition — policy mismatch")
        if self.composer is not None:
            self.composer.load_state_dict(d["composer"])
        kinds = {c.kind: c for c in self.controllers}
        if set(kinds) != set(d["controllers"]):
            raise ValueError(
                f"snapshot controllers {sorted(d['controllers'])} do not "
                f"match the policy's {sorted(kinds)}")
        for kind, state in d["controllers"].items():
            kinds[kind].load_state_dict(state)
        self.sched.policy.route_caps.clear()
        self.sched.policy.route_caps.update(
            {(s, dst): int(c) for s, dst, c in d["route_caps"]})
        self._next_control = d["next_control"]
        self._last_control = d["last_control"]
        self.ledger.load_state_dict(d["ledger"])
