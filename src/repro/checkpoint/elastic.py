"""Elastic rescaling: restore a checkpoint onto a different mesh.

Checkpoints store canonical full arrays (chunked files), so resharding is a
placement decision, not a data transformation: ``load_for_mesh`` device_puts
every leaf with the sharding derived for the *new* mesh.  Combined with the
relay broadcast (core/relay_collectives.py) a joining pod receives parameters
from a peer pod over fast links instead of re-reading the store — the paper's
relay insight applied to elastic scale-up.

``plan_reshard`` reports, per leaf, bytes moved per device for the new layout
(useful to size the rescale pause).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def load_for_mesh(tree: PyTree, mesh: Mesh, spec_tree: PyTree) -> PyTree:
    """device_put every leaf with its NamedSharding on the new mesh."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, tree, spec_tree)


def plan_reshard(tree: PyTree, old_mesh_shape: Dict[str, int],
                 new_mesh_shape: Dict[str, int], spec_tree: PyTree) -> Dict:
    """Analytic reshard plan: per-device bytes before/after and total moved."""
    def leaf_bytes(x):
        return int(np.prod(x.shape)) * x.dtype.itemsize if hasattr(x, "shape") else 0

    def shards(spec, mesh_shape):
        n = 1
        for axis in jax.tree_util.tree_leaves(tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            for a in axes:
                n *= mesh_shape.get(a, 1)
        return max(1, n)

    total = moved = 0
    for x, spec in zip(jax.tree_util.tree_leaves(tree),
                       jax.tree_util.tree_leaves(spec_tree, is_leaf=lambda s: isinstance(s, P))):
        b = leaf_bytes(x)
        total += b
        old_per = b // shards(spec, old_mesh_shape)
        new_per = b // shards(spec, new_mesh_shape)
        moved += abs(new_per - old_per)
    return {"total_bytes": total, "approx_bytes_moved_per_device": moved}
