"""Sharded checkpointing with integrity manifests.

Layout of a checkpoint directory::

    step-000123/
      tree.json          # pytree structure + per-leaf dtype/shape/chunking
      leaf-00000.c00.npy # leaf payload, chunked on the leading axis so a
      leaf-00000.c01.npy #   large cluster restores in parallel reads
      ...
      data_state.npz     # data-pipeline iterator state
      MANIFEST.json      # per-file (size, checksum) — verified on restore
      COMMITTED          # written last: crash-safe atomicity marker

Save is atomic (tmp dir + rename + COMMITTED marker); restore refuses
uncommitted or corrupt checkpoints and falls back to the previous step —
the checkpoint/restart half of fault tolerance.  Checksums use the same
hash as the replication integrity layer (kernels/checksum).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.integrity import Manifest

PyTree = Any
_LEAF_RE = re.compile(r"leaf-(\d{5})\.c(\d{2})\.npy$")


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_root: str, step: int, tree: PyTree,
                    data_state_path: Optional[str] = None,
                    n_chunks: int = 4, keep: int = 3) -> str:
    """Write checkpoint for ``step``; returns the committed directory."""
    final = os.path.join(ckpt_root, f"step-{step:06d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    meta: List[Dict] = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype; persist as uint16 view + dtype tag
        dtype_tag = str(leaf.dtype) if hasattr(leaf, "dtype") else str(arr.dtype)
        if dtype_tag == "bfloat16":
            arr = arr.view(np.uint16)
        chunks = max(1, min(n_chunks, arr.shape[0] if arr.ndim else 1))
        bounds = np.linspace(0, arr.shape[0] if arr.ndim else 1,
                             chunks + 1).astype(int) if arr.ndim else [0, 1]
        files = []
        for c in range(chunks):
            name = f"leaf-{i:05d}.c{c:02d}.npy"
            if arr.ndim:
                np.save(os.path.join(tmp, name), arr[bounds[c]:bounds[c + 1]])
            else:
                np.save(os.path.join(tmp, name), arr)
            files.append(name)
        meta.append({"dtype": dtype_tag, "shape": list(arr.shape),
                     "files": files})
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"treedef": _treedef_token(treedef), "step": step,
                   "leaves": meta}, f)
    if data_state_path and os.path.exists(data_state_path):
        shutil.copy(data_state_path, os.path.join(tmp, "data_state.npz"))

    manifest = Manifest.scan(tmp)
    manifest.save(os.path.join(tmp, "MANIFEST.json"))
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_root, keep)
    return final


def restore_checkpoint(ckpt_root: str, example_tree: PyTree,
                       step: Optional[int] = None,
                       ) -> Optional[Tuple[int, PyTree, str]]:
    """Restore the latest committed+verified checkpoint (or a given step).

    Returns (step, tree, dir) or None.  Corrupt/uncommitted candidates are
    skipped with a warning — restart never loads bad state.
    """
    for cand_step, d in _candidates(ckpt_root, step):
        manifest_path = os.path.join(d, "MANIFEST.json")
        if not (os.path.exists(os.path.join(d, "COMMITTED"))
                and os.path.exists(manifest_path)):
            continue
        manifest = Manifest.load(manifest_path)
        problems = {k: v for k, v in manifest.verify(d).items()
                    if k not in ("MANIFEST.json", "COMMITTED")}
        if problems:
            print(f"[ckpt] skipping corrupt {d}: {problems}")
            continue
        with open(os.path.join(d, "tree.json")) as f:
            info = json.load(f)
        leaves = []
        for m in info["leaves"]:
            parts = [np.load(os.path.join(d, fn)) for fn in m["files"]]
            arr = parts[0] if len(parts) == 1 else np.concatenate(parts, 0)
            if m["dtype"] == "bfloat16":
                import jax.numpy as jnp
                arr = arr.view(np.uint16)
                leaves.append(jnp.asarray(arr).view(jnp.bfloat16))
            else:
                leaves.append(arr.astype(m["dtype"]))
        _, treedef = _flatten(example_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return info["step"], tree, d
    return None


def latest_step(ckpt_root: str) -> Optional[int]:
    cands = _candidates(ckpt_root, None)
    return cands[0][0] if cands else None


# ---------------------------------------------------------------------- util
def _candidates(root: str, step: Optional[int]):
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        m = re.match(r"step-(\d+)$", name)
        if not m:
            continue
        s = int(m.group(1))
        if step is not None and s != step:
            continue
        out.append((s, os.path.join(root, name)))
    return sorted(out, reverse=True)


def _gc(root: str, keep: int) -> None:
    cands = _candidates(root, None)
    for s, d in cands[keep:]:
        shutil.rmtree(d, ignore_errors=True)


def _treedef_token(treedef) -> str:
    return str(treedef)
