"""Cross-site checkpoint replication — the paper's scheduler guarding training
state.

After each checkpoint commit, the directory is registered as a *dataset* with
the Figure-4 scheduler and replicated to every replica site (pods / regions /
cold store) over ``LocalFSTransport`` with checksum verification.  A pod loss
then never costs more than the steps since the last commit: restart verifies
the local manifest, and if the local copy is corrupt or gone, restores from
the nearest replica (relay order, slow store last — C2 applied to recovery).
"""
from __future__ import annotations

import os
import shutil
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.checkpoint.ckpt import restore_checkpoint
from repro.core.faults import Notifier, RetryPolicy
from repro.core.routes import Dataset
from repro.core.scheduler import ReplicationPolicy, ReplicationScheduler
from repro.core.transfer_table import Status, TransferTable
from repro.core.transport import LocalFSTransport


@dataclass
class CheckpointReplicator:
    root: str                           # parent of site dirs
    primary: str = "POD0"               # where training writes checkpoints
    replicas: tuple = ("POD1", "STORE")

    def __post_init__(self):
        self.transport = LocalFSTransport(self.root)
        self.table = TransferTable()
        self.notifier = Notifier()
        self.catalog: Dict[str, Dataset] = {}
        self.scheduler = ReplicationScheduler(
            self.table, self.transport, self.catalog,
            ReplicationPolicy(self.primary, self.replicas),
            RetryPolicy(max_retries=3, backoff_s=0.0), self.notifier)
        for site in (self.primary, *self.replicas):
            os.makedirs(os.path.join(self.root, site), exist_ok=True)

    def site_dir(self, site: str) -> str:
        return os.path.join(self.root, site)

    # ------------------------------------------------------------------- api
    def replicate(self, ckpt_rel: str, max_steps: int = 1000) -> bool:
        """Replicate ``<primary>/<ckpt_rel>`` to all replicas; True if all
        copies verified."""
        base = os.path.join(self.site_dir(self.primary), ckpt_rel.lstrip("/"))
        nbytes = nfiles = ndirs = 0
        for dirpath, _, files in os.walk(base):
            ndirs += 1
            for fn in files:
                nfiles += 1
                nbytes += os.path.getsize(os.path.join(dirpath, fn))
        self.catalog[ckpt_rel] = Dataset(ckpt_rel, nbytes, nfiles, ndirs)
        self.table.populate([ckpt_rel], self.primary, list(self.replicas))
        now = 0.0
        for _ in range(max_steps):
            self.scheduler.step(now)
            now += 1.0
            if all((self.table.get(ckpt_rel, r) or None) is not None
                   and self.table.get(ckpt_rel, r).status
                   in (Status.SUCCEEDED, Status.QUARANTINED)
                   for r in self.replicas):
                break
        return all(self.table.get(ckpt_rel, r).status == Status.SUCCEEDED
                   for r in self.replicas)

    def restore_anywhere(self, ckpt_rel: str, example_tree,
                         step: Optional[int] = None):
        """Restore from the primary if its copy verifies, else walk replicas
        in relay-priority order (fast pods first, slow store last)."""
        for site in (self.primary, *self.replicas):
            root = os.path.join(self.site_dir(site), ckpt_rel.lstrip("/"))
            if not os.path.isdir(root):
                continue
            got = restore_checkpoint(root, example_tree, step=step)
            if got is not None:
                return got + (site,)
        return None
