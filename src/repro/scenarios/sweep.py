"""Parameter-sweep runner: fan scenario variants across processes.

Capacity planning for continental-scale replication means asking many
what-ifs at once — N seeds of the fault storm, the degraded source at three
bandwidths, every registered scenario side by side.  ``sweep()`` runs each
variant in its own worker process (event-driven engine, so each run is
seconds), aggregates the resulting ``CampaignReport``s into flat comparison
rows, and ``emit_bench`` merges them into ``BENCH_scenarios.json``.

    PYTHONPATH=src python -m repro.scenarios.sweep \
        --scenarios paper-2022,fault-storm --seeds 0,1 --datasets 40 --scale 0.02
"""
from __future__ import annotations

import argparse
import json
import os
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

BENCH_PATH = "BENCH_scenarios.json"


@dataclass(frozen=True)
class Variant:
    """One sweep cell: a registered scenario plus build overrides."""
    scenario: str
    n_datasets: Optional[int] = None
    scale: float = 1.0
    seed: int = 0
    engine: str = "events"

    @property
    def label(self) -> str:
        nd = self.n_datasets if self.n_datasets is not None else "full"
        return f"{self.scenario}[n={nd},scale={self.scale},seed={self.seed}]"


def _run_variant(v: Variant) -> Dict:
    """Worker: build + run one variant, flatten the report (module-level so
    it pickles across process boundaries)."""
    from repro.scenarios.events import EngineStats, run_scenario
    stats = EngineStats()
    t0 = time.time()
    rep = run_scenario(v.scenario, engine=v.engine, scale=v.scale,
                       seed=v.seed, n_datasets=v.n_datasets, stats=stats)
    wall = time.time() - t0
    complete = (rep.quarantined == 0
                and all(b >= rep.total_bytes * 0.999
                        for b in rep.bytes_at.values()))
    return {
        "variant": v.label,
        "scenario": v.scenario,
        "seed": v.seed,
        "scale": v.scale,
        "n_datasets": v.n_datasets,
        "engine": v.engine,
        "wall_s": round(wall, 3),
        "iterations": stats.iterations,
        "events_per_s": round(stats.iterations / max(wall, 1e-9), 1),
        "duration_days": round(rep.duration_days, 3),
        "floor_days": round(rep.floor_days, 3),
        "total_tb": round(rep.total_bytes / 1024 ** 4, 3),
        "complete": complete,
        "faults_total": rep.faults_total,
        "faults_max": rep.faults_per_transfer_max,
        "quarantined": rep.quarantined,
        "notifications": len(rep.notifications),
        "per_route_gbps": {f"{a}->{b}": round(g, 3)
                           for (a, b), g in rep.per_route_gbps.items()},
        "per_route_transfers": {f"{a}->{b}": n
                                for (a, b), n in rep.per_route_transfers.items()},
    }


def sweep(variants: Sequence[Variant],
          processes: Optional[int] = None) -> List[Dict]:
    """Run all variants, multi-process when possible, and return comparison
    rows in input order.  Workers use the ``spawn`` start method (fork is
    unsafe once jax's thread pools exist); any pool-level failure falls back
    to in-process execution, where a genuine variant error re-raises."""
    variants = list(variants)
    if processes is None:
        processes = min(len(variants), os.cpu_count() or 1)
    if processes > 1 and len(variants) > 1:
        import multiprocessing as mp
        import pickle
        from concurrent.futures.process import BrokenProcessPool
        try:
            ctx = mp.get_context("spawn")
            with ProcessPoolExecutor(max_workers=processes,
                                     mp_context=ctx) as ex:
                return list(ex.map(_run_variant, variants))
        except (OSError, ImportError, pickle.PicklingError,
                BrokenProcessPool):
            pass    # pool infrastructure unavailable (sandbox, sys.path,
            #         semaphores) — genuine variant errors re-raise below
    return [_run_variant(v) for v in variants]


def to_frame(rows: Sequence[Dict]) -> Dict[str, list]:
    """Column-oriented view of the comparison rows (a minimal 'frame' —
    ready for tabulation or pandas ingestion without depending on pandas)."""
    cols: Dict[str, list] = {}
    for row in rows:
        for k, v in row.items():
            cols.setdefault(k, []).append(v)
    return cols


def emit_bench(rows: Sequence[Dict], path: str = BENCH_PATH,
               extra: Optional[Dict] = None) -> Dict:
    """Merge sweep rows (and optional extra sections, e.g. the engine
    comparison from ``benchmarks/campaign_replay.py``) into ``path``."""
    doc: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            doc = {}
    if rows:
        doc["sweep"] = list(rows)
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return doc


def main(argv: Optional[Sequence[str]] = None) -> None:
    from repro.scenarios.registry import list_scenarios
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenarios", default="all",
                    help="comma-separated scenario names, or 'all'")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated seeds per scenario")
    ap.add_argument("--datasets", type=int, default=60)
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--engine", choices=("events", "step"), default="events")
    ap.add_argument("--processes", type=int, default=None)
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)

    names = (list_scenarios() if args.scenarios == "all"
             else args.scenarios.split(","))
    unknown = [n for n in names if n not in list_scenarios()]
    if unknown:
        ap.error(f"unknown scenario(s): {', '.join(unknown)}; "
                 f"available: {', '.join(list_scenarios())}")
    seeds = [int(s) for s in args.seeds.split(",")]
    variants = [Variant(n, n_datasets=args.datasets, scale=args.scale,
                        seed=s, engine=args.engine)
                for n in names for s in seeds]
    t0 = time.time()
    rows = sweep(variants, processes=args.processes)
    emit_bench(rows, path=args.out,
               extra={"sweep_wall_s": round(time.time() - t0, 2)})
    for row in rows:
        print(f"{row['variant']:58} {row['duration_days']:8.2f} d "
              f"(floor {row['floor_days']:6.2f}) faults={row['faults_total']:5d} "
              f"quarantined={row['quarantined']:3d} wall={row['wall_s']:.2f}s")
    print(f"\n{len(rows)} variants -> {args.out}")


if __name__ == "__main__":
    main()
