"""Declarative campaign scenarios on an event-driven simulation core.

``spec``     — ``ScenarioSpec``: sites, routes, maintenance calendars, fault
               profiles, catalog shape, and incidents, compiled onto the
               existing ``CampaignConfig``/``RouteGraph``/``PauseManager``
               wiring.
``registry`` — named what-if scenarios (the paper-2022 baseline plus
               counterfactuals: degraded source, fault storm, four-site mesh,
               flaky network, incremental top-up, cold-start relay).
``events``   — next-event time advance replacing blind fixed-step ticking:
               a 77-simulated-day campaign replays in seconds.
``sweep``    — multi-process parameter sweeps aggregating ``CampaignReport``s
               into comparison frames (``BENCH_scenarios.json``).
``run``      — ``python -m repro.scenarios.run --scenario <name>`` CLI.
"""
from repro.scenarios.spec import (CatalogSpec, FaultProfileSpec, OutageSpec,
                                  RouteSpec, ScenarioSpec, SiteSpec,
                                  TopUpSpec)
from repro.scenarios.registry import get_scenario, list_scenarios

__all__ = [
    "CatalogSpec", "FaultProfileSpec", "OutageSpec", "RouteSpec",
    "ScenarioSpec", "SiteSpec", "TopUpSpec", "get_scenario", "list_scenarios",
]
