"""Event-driven simulation core: next-event time advance for N federated
campaigns over one shared world.

The seed campaign driver ticks a fixed 1800-second step for the whole
simulated campaign — thousands of scheduler passes where nothing changes.
This module instead advances the clock straight to the next *event*:

  * the next projected transfer completion / permission halt / scan finish
    (``SimulatedTransport.next_event_hint``, which folds pending fault-stall
    time into each estimate);
  * the next maintenance-window boundary of any site
    (``PauseManager.next_boundary``);
  * the next retry-backoff expiry (``ReplicationScheduler.next_backoff_expiry``)
    of any campaign;
  * the next scheduled human permission fix, incremental publication
    (top-up) check, or staggered campaign start.

``run_world`` drives either a single-campaign ``ScenarioWorld`` or a
``FederationWorld`` of N ``CampaignRuntime``s attached to one
``SharedWorld``: every runtime's candidates fold into one ``_next_event_dt``,
one clock advance, and one transport tick, so concurrent campaigns contend
through the shared fair-share rate allocator.  A 1-element federation
performs exactly the operations the single-campaign loop always performed —
the bit-identity anchor the determinism tests pin down.

Because ``SimulatedTransport._advance_mover`` is segment-exact (the transfer
trajectory is independent of how wall time is sliced into ticks), jumping
between events is behavior-preserving: the paper-2022 scenario reproduces the
step-driven duration and fault statistics within tolerance while replaying a
77-simulated-day campaign in a few hundred iterations instead of thousands.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.campaign import (CampaignReport, FederationReport, _bytes_at,
                                 aggregate_report, apply_human_fixes)
from repro.core.pause import DAY
from repro.core.snapshot import FederationLoopState, LoopState
from repro.core.transport import SimClock
from repro.scenarios.spec import FederationWorld

# guards: never advance by less than MIN_STEP_S (numerical safety), never by
# more than MAX_STEP_S (bounds drift if a hint source under-estimates)
MIN_STEP_S = 1.0
MAX_STEP_S = 12 * 3600.0


@dataclass
class EngineStats:
    """Driver telemetry: how many scheduler/transport iterations were spent."""
    iterations: int = 0
    sim_days: float = 0.0


def _next_event_dt(shared, runtimes, members, finished_at,
                   now: float) -> float:
    """Seconds until the next thing that can change scheduler-visible state
    in ANY attached campaign runtime."""
    cand = [shared.transport.next_event_hint()]
    cand.append(shared.pause.next_change(now) - now)
    for i, rt in enumerate(runtimes):
        if finished_at[i] is not None:
            continue
        if now < rt.start_s:
            cand.append(rt.start_s - now)  # staggered campaign start
            continue
        cand.append(rt.sched.next_backoff_expiry(now) - now)
        if rt.control is not None:
            cand.append(rt.control.next_action(now) - now)
        if rt.demand is not None:
            cand.append(rt.demand.next_wave(now) - now)
        if rt.scrub is not None:
            cand.append(rt.scrub.next_action(now) - now)
        if rt.obs is not None:
            cand.append(rt.obs.next_action(now) - now)
        for t in members[i].fix_at.values():
            if t > now:
                cand.append(t - now)
        if rt.incremental is not None:
            for t in rt.top_up_times:
                if t > now:
                    cand.append(t - now)
    dt = min((c for c in cand if c > 0), default=MAX_STEP_S)
    return max(MIN_STEP_S, min(dt, MAX_STEP_S))


def _outstanding_top_ups(rt) -> set:
    """Published datasets not yet admitted to the catalog (membership, not
    time comparison: the daily incremental check can lag an event that lands
    exactly on a publication timestamp).  Computed once per run; the driver
    shrinks the set as ``maybe_check`` admits paths, instead of rescanning
    the feed every iteration."""
    if rt.incremental is None:
        return set()
    return {d.path for _, d in rt.incremental.feed.all_events()
            if d.path not in rt.catalog}


def _fresh_loop_state(rt) -> LoopState:
    return LoopState(
        iterations=0, fix_at={},
        next_snap_day=float(int(rt.start_day)) + 1.0,
        timeline=[],
        pending_top_ups=_outstanding_top_ups(rt),
        feed_cursor=(rt.incremental.feed.count()
                     if rt.incremental is not None else 0))


def _copy_loop_state(ls: LoopState) -> LoopState:
    """Resume normalization: same copies the pre-federation loop made."""
    return LoopState(iterations=ls.iterations, fix_at=ls.fix_at,
                     next_snap_day=ls.next_snap_day, timeline=ls.timeline,
                     pending_top_ups=set(ls.pending_top_ups),
                     feed_cursor=ls.feed_cursor)


def run_world(world, engine: str = "events",
              stats: Optional[EngineStats] = None,
              on_iteration=None, checkpointer=None,
              resume=None):
    """Drive a compiled ``ScenarioWorld`` or ``FederationWorld`` to
    completion.

    ``engine="step"`` reproduces the seed driver (fixed ``cfg.step_s``
    cadence); ``engine="events"`` uses next-event time advance.  Both share
    the same transport/scheduler/human-fix code and the same aggregation.
    ``on_iteration(world, now)``, if given, is called once per driver
    iteration (after the scheduler passes, before the clock advances) — the
    observer hook the interactive example uses for progress display.

    ``checkpointer`` (a ``repro.core.snapshot.Checkpointer``) is consulted at
    the top of every iteration — the loop's consistency boundary — and may
    write a durable snapshot and/or raise ``CampaignKilled`` after one.
    ``resume`` is the ``LoopState`` (single campaign) or
    ``FederationLoopState`` (federation) from
    ``repro.core.snapshot.resume_world``; the loop then continues the killed
    campaign's trajectory bit-for-bit.

    Returns a ``CampaignReport`` for a ``ScenarioWorld`` and a
    ``FederationReport`` (one ``CampaignReport`` per member) for a
    ``FederationWorld``.  Federation members step only between their
    ``start_day`` and their own ``max_days`` deadline; a member that
    completes or times out is torn down (its in-flight transfers cancelled),
    releasing its fair-share slots to the surviving members.
    """
    if engine not in ("events", "step"):
        raise ValueError(f"unknown engine {engine!r}")
    fed = isinstance(world, FederationWorld)
    runtimes = world.runtimes if fed else [world.runtime]
    shared = world.shared
    clock, transport = shared.clock, shared.transport
    stats = stats if stats is not None else EngineStats()
    n = len(runtimes)
    if resume is not None:
        if fed:
            members = [_copy_loop_state(ls) for ls in resume.members]
            finished_at: List[Optional[float]] = list(resume.finished_at)
        else:
            members = [_copy_loop_state(resume)]
            finished_at = [None]
        stats.iterations = resume.iterations
    else:
        members = [_fresh_loop_state(rt) for rt in runtimes]
        finished_at = [None] * n
        stats.iterations = 0
    step_s = min(rt.cfg.step_s for rt in runtimes)
    horizon = max(rt.deadline_s for rt in runtimes)

    def _loop_state():
        if fed:
            return FederationLoopState(iterations=stats.iterations,
                                       members=members,
                                       finished_at=list(finished_at))
        ls = members[0]
        return LoopState(iterations=stats.iterations, fix_at=ls.fix_at,
                         next_snap_day=ls.next_snap_day,
                         timeline=ls.timeline,
                         pending_top_ups=ls.pending_top_ups,
                         feed_cursor=ls.feed_cursor)

    def _finish(i: int) -> None:
        finished_at[i] = clock.now
        # a finished campaign (done or timed out) releases whatever it still
        # holds in flight; trajectory-neutral for a lone campaign (the report
        # reads the table, not the transport archive)
        runtimes[i].sched.teardown()
        if runtimes[i].demand is not None:
            runtimes[i].demand.teardown()
        if runtimes[i].obs is not None:
            runtimes[i].obs.finalize(clock.now)

    while clock.now < horizon:
        # members past their own deadline time out and hand their capacity
        # back (a lone campaign's deadline IS the horizon — handled below)
        for i, rt in enumerate(runtimes):
            if finished_at[i] is None and clock.now >= rt.deadline_s:
                _finish(i)
        if all(f is not None for f in finished_at):
            break
        if checkpointer is not None:
            checkpointer.on_boundary(world, _loop_state(), engine)
        stats.iterations += 1
        active = [i for i, rt in enumerate(runtimes)
                  if finished_at[i] is None and clock.now >= rt.start_s]
        for i in active:
            # demand first: an admission wave re-keys priorities and updates
            # read load, then the control plane tops up the bundle feed and
            # tunes caps, so this pass's scheduler step sees both
            if runtimes[i].demand is not None:
                runtimes[i].demand.step(clock.now)
            if runtimes[i].control is not None:
                runtimes[i].control.step(clock.now)
            # scrub after the control plane, before the scheduler: a due
            # scan's repair flips land as FAILED rows this same pass, so the
            # scheduler step dispatches re-transfers alongside live work
            if runtimes[i].scrub is not None:
                runtimes[i].scrub.step(clock.now)
            runtimes[i].sched.step(clock.now)
            # observe last: the flight recorder samples the state this
            # pass produced, and never feeds anything back
            if runtimes[i].obs is not None:
                runtimes[i].obs.step(clock.now)
        for i in active:
            rt, ls = runtimes[i], members[i]
            apply_human_fixes(rt.notifier, ls.fix_at, clock.now,
                              rt.cfg.human_fix_days)
            if rt.incremental is not None:
                ls.pending_top_ups.difference_update(
                    rt.incremental.maybe_check(clock.now))
        if on_iteration is not None:
            on_iteration(world, clock.now)
        just_done: List[int] = []
        for i in active:
            rt, ls = runtimes[i], members[i]
            if rt.incremental is not None:
                feed = rt.incremental.feed
                if feed.count() > ls.feed_cursor:  # published mid-run (e.g.
                    ls.pending_top_ups.update(     # by the observer hook):
                        d.path                     # keep running
                        for _, d in feed.events_since(ls.feed_cursor)
                        if d.path not in rt.catalog)
                    ls.feed_cursor = feed.count()
            if (rt.sched.done() and not ls.pending_top_ups
                    and (rt.control is None or rt.control.exhausted())
                    and (rt.scrub is None or rt.scrub.exhausted())):
                _finish(i)
                just_done.append(i)
        done = all(f is not None for f in finished_at)
        if done and engine == "events":
            break           # stop exactly at the last event's timestamp
        dt = (step_s if engine == "step"
              else _next_event_dt(shared, runtimes, members, finished_at,
                                  clock.now))
        clock.advance(dt)
        transport.tick()
        if engine == "step":
            # the step driver advances once more after completion (seed
            # semantics); a member finishing this pass finishes at the
            # post-advance clock, exactly like the standalone loop
            for i in just_done:
                finished_at[i] = clock.now
        for i, rt in enumerate(runtimes):
            if finished_at[i] is not None and i not in just_done:
                continue    # long-finished members stop snapshotting
            if clock.now < rt.start_s:
                continue
            ls = members[i]
            if clock.now / DAY >= ls.next_snap_day:
                ls.timeline.append((clock.now / DAY,
                                    {r: _bytes_at(rt.table, r)
                                     for r in rt.cfg.replicas}))
                ls.next_snap_day = float(int(clock.now / DAY) + 1)
        if done:
            break           # step engine: mirror the seed driver's ordering
    for i in range(n):
        if finished_at[i] is None:
            _finish(i)      # horizon reached with work outstanding
    stats.sim_days = clock.now / DAY
    if not fed:
        rt, ls = runtimes[0], members[0]
        return aggregate_report(rt.cfg, shared.graph, rt.catalog, clock,
                                rt.table, rt.notifier, ls.timeline)
    reports: Dict[str, CampaignReport] = {}
    for i, rt in enumerate(runtimes):
        reports[rt.label] = aggregate_report(
            rt.cfg, shared.graph, rt.catalog, SimClock(finished_at[i]),
            rt.table, rt.notifier, members[i].timeline)
    return FederationReport(
        members=reports,
        started_day={rt.label: rt.start_day for rt in runtimes},
        finished_day={rt.label: finished_at[i] / DAY
                      for i, rt in enumerate(runtimes)},
        span_days=max(finished_at) / DAY)


def run_scenario(scenario, engine: str = "events", scale: float = 1.0,
                 seed: int = 0, n_datasets: Optional[int] = None,
                 stats: Optional[EngineStats] = None):
    """Build and run a scenario (or federation) by name or spec."""
    from repro.scenarios.registry import get_scenario
    spec = get_scenario(scenario) if isinstance(scenario, str) else scenario
    if not hasattr(spec, "build"):
        raise TypeError(
            f"{getattr(spec, 'name', spec)!r} is not a buildable scenario "
            "(crash-resume scenarios run via "
            "repro.scenarios.crash_resume.run_crash_resume)")
    world = spec.build(scale=scale, seed=seed, n_datasets=n_datasets)
    return run_world(world, engine=engine, stats=stats)
