"""Event-driven simulation core: next-event time advance.

The seed campaign driver ticks a fixed 1800-second step for the whole
simulated campaign — thousands of scheduler passes where nothing changes.
This module instead advances the clock straight to the next *event*:

  * the next projected transfer completion / permission halt / scan finish
    (``SimulatedTransport.next_event_hint``, which folds pending fault-stall
    time into each estimate);
  * the next maintenance-window boundary of any site
    (``PauseManager.next_boundary``);
  * the next retry-backoff expiry (``ReplicationScheduler.next_backoff_expiry``);
  * the next scheduled human permission fix and the next incremental
    publication (top-up) check.

Because ``SimulatedTransport._advance_mover`` is segment-exact (the transfer
trajectory is independent of how wall time is sliced into ticks), jumping
between events is behavior-preserving: the paper-2022 scenario reproduces the
step-driven duration and fault statistics within tolerance while replaying a
77-simulated-day campaign in a few hundred iterations instead of thousands.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.campaign import (CampaignReport, _bytes_at, aggregate_report,
                                 apply_human_fixes)
from repro.core.pause import DAY
from repro.core.snapshot import LoopState

# guards: never advance by less than MIN_STEP_S (numerical safety), never by
# more than MAX_STEP_S (bounds drift if a hint source under-estimates)
MIN_STEP_S = 1.0
MAX_STEP_S = 12 * 3600.0


@dataclass
class EngineStats:
    """Driver telemetry: how many scheduler/transport iterations were spent."""
    iterations: int = 0
    sim_days: float = 0.0


def _next_event_dt(world, now: float, fix_at: Dict[str, float]) -> float:
    """Seconds until the next thing that can change scheduler-visible state."""
    cand = [world.transport.next_event_hint()]
    cand.append(world.pause.next_change(now) - now)
    cand.append(world.sched.next_backoff_expiry(now) - now)
    for t in fix_at.values():
        if t > now:
            cand.append(t - now)
    if world.incremental is not None:
        for t in world.top_up_times:
            if t > now:
                cand.append(t - now)
    dt = min((c for c in cand if c > 0), default=MAX_STEP_S)
    return max(MIN_STEP_S, min(dt, MAX_STEP_S))


def _outstanding_top_ups(world) -> set:
    """Published datasets not yet admitted to the catalog (membership, not
    time comparison: the daily incremental check can lag an event that lands
    exactly on a publication timestamp).  Computed once per run; the driver
    shrinks the set as ``maybe_check`` admits paths, instead of rescanning
    the feed every iteration."""
    if world.incremental is None:
        return set()
    return {d.path for _, d in world.incremental.feed.all_events()
            if d.path not in world.catalog}


def run_world(world, engine: str = "events",
              stats: Optional[EngineStats] = None,
              on_iteration=None, checkpointer=None,
              resume: Optional[LoopState] = None) -> CampaignReport:
    """Drive a compiled ``ScenarioWorld`` to completion.

    ``engine="step"`` reproduces the seed driver (fixed ``cfg.step_s``
    cadence); ``engine="events"`` uses next-event time advance.  Both share
    the same transport/scheduler/human-fix code and the same aggregation.
    ``on_iteration(world, now)``, if given, is called once per driver
    iteration (after the scheduler pass, before the clock advances) — the
    observer hook the interactive example uses for progress display.

    ``checkpointer`` (a ``repro.core.snapshot.Checkpointer``) is consulted at
    the top of every iteration — the loop's consistency boundary — and may
    write a durable snapshot and/or raise ``CampaignKilled`` after one.
    ``resume`` is the ``LoopState`` from ``repro.core.snapshot.resume_world``;
    the loop then continues the killed campaign's trajectory bit-for-bit.
    """
    if engine not in ("events", "step"):
        raise ValueError(f"unknown engine {engine!r}")
    cfg = world.cfg
    clock, sched, transport = world.clock, world.sched, world.transport
    stats = stats if stats is not None else EngineStats()
    if resume is not None:
        timeline = resume.timeline
        fix_at = resume.fix_at
        next_snap_day = resume.next_snap_day
        stats.iterations = resume.iterations
        pending_top_ups = set(resume.pending_top_ups)
        feed_cursor = resume.feed_cursor
    else:
        timeline: List[Tuple[float, Dict[str, int]]] = []
        fix_at: Dict[str, float] = {}
        next_snap_day = 1.0
        stats.iterations = 0
        pending_top_ups = _outstanding_top_ups(world)
        feed_cursor = (world.incremental.feed.count()
                       if world.incremental is not None else 0)

    def _loop_state() -> LoopState:
        return LoopState(iterations=stats.iterations, fix_at=fix_at,
                         next_snap_day=next_snap_day, timeline=timeline,
                         pending_top_ups=pending_top_ups,
                         feed_cursor=feed_cursor)

    while clock.now < cfg.max_days * DAY:
        if checkpointer is not None:
            checkpointer.on_boundary(world, _loop_state(), engine)
        stats.iterations += 1
        sched.step(clock.now)
        apply_human_fixes(world.notifier, fix_at, clock.now,
                          cfg.human_fix_days)
        if world.incremental is not None:
            pending_top_ups.difference_update(
                world.incremental.maybe_check(clock.now))
        if on_iteration is not None:
            on_iteration(world, clock.now)
        if world.incremental is not None:
            feed = world.incremental.feed
            if feed.count() > feed_cursor:  # published mid-run (e.g. by the
                pending_top_ups.update(     # observer hook): keep running
                    d.path for _, d in feed.events_since(feed_cursor)
                    if d.path not in world.catalog)
                feed_cursor = feed.count()
        done = sched.done() and not pending_top_ups
        if done and engine == "events":
            break           # stop exactly at the last event's timestamp
        dt = (cfg.step_s if engine == "step"
              else _next_event_dt(world, clock.now, fix_at))
        clock.advance(dt)
        transport.tick()
        if clock.now / DAY >= next_snap_day:
            timeline.append((clock.now / DAY,
                             {r: _bytes_at(world.table, r)
                              for r in cfg.replicas}))
            next_snap_day = float(int(clock.now / DAY) + 1)
        if done:
            break           # step engine: mirror the seed driver's ordering
    stats.sim_days = clock.now / DAY
    return aggregate_report(cfg, world.graph, world.catalog, clock,
                            world.table, world.notifier, timeline)


def run_scenario(scenario, engine: str = "events", scale: float = 1.0,
                 seed: int = 0, n_datasets: Optional[int] = None,
                 stats: Optional[EngineStats] = None) -> CampaignReport:
    """Build and run a scenario by name or ``ScenarioSpec``."""
    from repro.scenarios.registry import get_scenario
    spec = get_scenario(scenario) if isinstance(scenario, str) else scenario
    if not hasattr(spec, "build"):
        raise TypeError(
            f"{getattr(spec, 'name', spec)!r} is not a buildable scenario "
            "(crash-resume scenarios run via "
            "repro.scenarios.crash_resume.run_crash_resume)")
    world = spec.build(scale=scale, seed=seed, n_datasets=n_datasets)
    return run_world(world, engine=engine, stats=stats)
