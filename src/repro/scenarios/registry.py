"""Named campaign scenarios.

Each entry is a complete ``ScenarioSpec``.  ``paper-2022`` reproduces the
campaign wiring of ``repro.core.campaign.build_campaign`` exactly (same
topology, same calendar, same fault profile); the rest are the what-if
studies the paper's capacity-planning discussion calls for — degraded
source, storms of transient faults, flaky networking, a fourth site, a
mid-campaign top-up, and a cold start where relays carry almost everything.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.control.policy import TransferPolicySpec
from repro.core.routes import GB, TB
from repro.core.scrub import ScrubSpec
from repro.ensemble.spec import AxisSpec, EnsembleSpec
from repro.scenarios.crash_resume import (CRASH_RESUME_SCENARIOS,
                                          CrashResumeSpec)
from repro.demand.spec import DemandSpec
from repro.obs.spec import ObsSpec
from repro.scenarios.spec import (CatalogSpec, FaultProfileSpec,
                                  FederationMemberSpec, FederationSpec,
                                  OutageSpec, RouteSpec, ScenarioSpec,
                                  SiteSpec, TopUpSpec)

# --------------------------------------------------------------- paper sites
_LLNL = SiteSpec("LLNL", read_gbps=1.5, write_gbps=1.5,
                 scan_files_per_s=20_000, scan_mem_limit_files=2_000_000)
_ALCF = SiteSpec("ALCF", read_gbps=10.0, write_gbps=10.0)
_OLCF = SiteSpec("OLCF", read_gbps=10.0, write_gbps=10.0)
_NERSC = SiteSpec("NERSC", read_gbps=10.0, write_gbps=10.0)

_PAPER_ROUTES = (
    RouteSpec("LLNL", "ALCF", 2 * 0.648),
    RouteSpec("LLNL", "OLCF", 2 * 0.662),
    RouteSpec("ALCF", "OLCF", 2 * 1.706),
    RouteSpec("OLCF", "ALCF", 2 * 2.352),
)

# paper Fig. 5 calendar: OLCF DTN online day 5; ALCF extended maintenance
# days 5-10 then weekly 12 h from day 17; OLCF weekly 12 h from day 40.
_PAPER_OUTAGES = (
    OutageSpec("OLCF", start_day=0.0, duration_h=5 * 24.0, planned=False),
    OutageSpec("ALCF", start_day=5.0, duration_h=5 * 24.0),
    OutageSpec("ALCF", start_day=17.0, duration_h=12.0, weekly=True),
    OutageSpec("OLCF", start_day=40.0, duration_h=12.0, weekly=True),
)

PAPER_2022 = ScenarioSpec(
    name="paper-2022",
    description="The 2022 campaign as published: LLNL sources 7.3 PB to "
                "ALCF and OLCF over Table-3 routes with the Fig.-5 "
                "maintenance calendar and the CMIP5 permission incident.",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL, _ALCF, _OLCF), routes=_PAPER_ROUTES,
    outages=_PAPER_OUTAGES)

FOUR_SITE_MESH = ScenarioSpec(
    name="four-site-mesh",
    description="A fourth LCF (NERSC) joins: three replicas on a full "
                "inter-LCF relay mesh — does the slow source still bound "
                "the campaign?",
    source="LLNL", replicas=("ALCF", "OLCF", "NERSC"),
    sites=(_LLNL, _ALCF, _OLCF, _NERSC),
    routes=_PAPER_ROUTES + (
        RouteSpec("LLNL", "NERSC", 2 * 0.650),
        RouteSpec("ALCF", "NERSC", 2 * 1.800),
        RouteSpec("NERSC", "ALCF", 2 * 1.800),
        RouteSpec("OLCF", "NERSC", 2 * 2.000),
        RouteSpec("NERSC", "OLCF", 2 * 2.000),
    ),
    outages=_PAPER_OUTAGES)

DEGRADED_SOURCE = ScenarioSpec(
    name="degraded-source",
    description="The source file system at half health: LLNL reads at "
                "0.75 GB/s and scans at half speed — how much does the "
                "58-day floor stretch?",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(SiteSpec("LLNL", read_gbps=0.75, write_gbps=0.75,
                    scan_files_per_s=10_000,
                    scan_mem_limit_files=2_000_000),
           _ALCF, _OLCF),
    routes=_PAPER_ROUTES,
    outages=_PAPER_OUTAGES,
    max_days=400.0)

FAULT_STORM = ScenarioSpec(
    name="fault-storm",
    description="20x the transient-fault intensity with a heavier fragility "
                "tail: does bounded retry + quarantine still converge?",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL, _ALCF, _OLCF), routes=_PAPER_ROUTES,
    outages=_PAPER_OUTAGES,
    faults=FaultProfileSpec(transient_per_tb=3.0, fragility_tail=1.8,
                            max_retries=10, backoff_s=1800.0))

HARSH_FAULTS = ScenarioSpec(
    name="harsh-faults",
    description="The fault-storm profile compounded by unplanned multi-day "
                "DTN outages, with the flight recorder on: the post-mortem "
                "walkthrough scenario (EXPERIMENTS.md) — read the outage "
                "timeline back out of the recorded stream.",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL, _ALCF, _OLCF), routes=_PAPER_ROUTES,
    outages=_PAPER_OUTAGES + (
        # unplanned mid-campaign DTN failures on top of the Fig.-5 calendar
        OutageSpec("ALCF", start_day=9.0, duration_h=36.0, planned=False),
        OutageSpec("OLCF", start_day=21.0, duration_h=60.0, planned=False),
        OutageSpec("ALCF", start_day=33.5, duration_h=6.0, weekly=True),
    ),
    faults=FaultProfileSpec(transient_per_tb=3.0, fragility_tail=1.8,
                            max_retries=10, backoff_s=1800.0),
    obs=ObsSpec(trace=True, metrics=True),
    max_days=400.0)

FLAKY_NETWORK = ScenarioSpec(
    name="flaky-network",
    description="Routes at 60% of Table-3 bandwidth plus short unplanned "
                "outages every few days at both replicas.",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL, _ALCF, _OLCF),
    routes=tuple(RouteSpec(r.source, r.destination, 0.6 * r.gbps)
                 for r in _PAPER_ROUTES),
    outages=_PAPER_OUTAGES + (
        OutageSpec("ALCF", start_day=3.0, duration_h=3.0, weekly=True,
                   planned=False),
        OutageSpec("OLCF", start_day=8.5, duration_h=4.0, weekly=True,
                   planned=False),
        OutageSpec("ALCF", start_day=11.25, duration_h=2.0, weekly=True,
                   planned=False),
    ),
    faults=FaultProfileSpec(transient_per_tb=0.6),
    max_days=400.0)

INCREMENTAL_TOP_UP = ScenarioSpec(
    name="incremental-top-up",
    description="New ESGF publications land mid-campaign (paper C7): the "
                "daily incremental check folds them into the same table "
                "and the campaign absorbs them.",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL, _ALCF, _OLCF), routes=_PAPER_ROUTES,
    outages=_PAPER_OUTAGES,
    top_ups=(TopUpSpec(publish_day=12.0, n_datasets=6),
             TopUpSpec(publish_day=20.0, n_datasets=4)))

COLD_START_RELAY = ScenarioSpec(
    name="cold-start-relay",
    description="Cold start at four sites with thin source egress beyond "
                "the primary: every replica but ALCF is fed almost "
                "entirely by replica-to-replica relays.",
    source="LLNL", replicas=("ALCF", "OLCF", "NERSC"),
    sites=(_LLNL, _ALCF, _OLCF, _NERSC),
    routes=(
        RouteSpec("LLNL", "ALCF", 2 * 0.648),
        # thin direct paths: usable during primary maintenance, otherwise
        # relays dominate
        RouteSpec("LLNL", "OLCF", 0.10),
        RouteSpec("LLNL", "NERSC", 0.10),
        RouteSpec("ALCF", "OLCF", 2 * 1.706),
        RouteSpec("OLCF", "ALCF", 2 * 2.352),
        RouteSpec("ALCF", "NERSC", 2 * 1.800),
        RouteSpec("NERSC", "ALCF", 2 * 1.800),
        RouteSpec("OLCF", "NERSC", 2 * 2.000),
        RouteSpec("NERSC", "OLCF", 2 * 2.000),
    ),
    outages=(OutageSpec("ALCF", start_day=20.0, duration_h=12.0,
                        weekly=True),),
    max_days=400.0)


MEGA_CAMPAIGN = ScenarioSpec(
    name="mega-campaign",
    description="Production-scale stress: the same 7.3 PB sliced into "
                "20,480 datasets replicated to three LCFs over the "
                "four-site mesh — ~61k table rows, the regime where "
                "per-iteration cost must stay O(active), not O(catalog).",
    source="LLNL", replicas=("ALCF", "OLCF", "NERSC"),
    sites=(_LLNL, _ALCF, _OLCF, _NERSC),
    routes=_PAPER_ROUTES + (
        RouteSpec("LLNL", "NERSC", 2 * 0.650),
        RouteSpec("ALCF", "NERSC", 2 * 1.800),
        RouteSpec("NERSC", "ALCF", 2 * 1.800),
        RouteSpec("OLCF", "NERSC", 2 * 2.000),
        RouteSpec("NERSC", "OLCF", 2 * 2.000),
    ),
    outages=_PAPER_OUTAGES,
    catalog=CatalogSpec(n_datasets=20_480),
    max_days=400.0)


# -------------------------------------------------- control-plane scenarios
# The paper's tool moved 28.9 M files by packing them into large Globus
# tasks; Globus itself tuned concurrency under the covers.  These scenarios
# make that control plane load-bearing: each declares a TransferPolicySpec
# and a per-task dispatch cost (``task_setup_s``) that naive one-task-per-
# dataset scheduling cannot amortize.
SMALL_FILE_STORM = ScenarioSpec(
    name="small-file-storm",
    description="500k tiny files across 2,000 small datasets with a 45 s "
                "per-task dispatch cost: one task per dataset drowns in "
                "dispatch overhead; the declared policy bundles the "
                "catalog into large tasks and AIMD-tunes route concurrency "
                "(the regime where Globus bundling beat scripted scp).",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL, _ALCF, _OLCF), routes=_PAPER_ROUTES,
    catalog=CatalogSpec(n_datasets=2000, total_bytes=2 * TB,
                        total_files=500_000, unreadable_fraction=0.0),
    task_setup_s=45.0,
    policy=TransferPolicySpec(
        bundling="greedy", controller="aimd",
        target_files=25_000, target_bytes=200 * GB,
        max_files=100_000, max_bytes=1 * TB,
        control_interval_s=3600.0,
        max_active_per_route=6),
    max_days=50.0)

MIXED_BUNDLE_PAPER = ScenarioSpec(
    name="mixed-bundle-paper",
    description="paper-2022 with per-dataset file manifests: the composer "
                "packs individual files into size-balanced bundles that "
                "may span datasets, and the gradient tuner steers future "
                "bundle sizing from observed throughput.",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL, _ALCF, _OLCF), routes=_PAPER_ROUTES,
    outages=_PAPER_OUTAGES,
    task_setup_s=30.0,
    policy=TransferPolicySpec(
        bundling="balanced", granularity="file", controller="gradient",
        target_files=500_000, target_bytes=100 * TB,
        max_files=1_500_000, max_bytes=400 * TB,
        balance_batch=4,
        control_interval_s=12 * 3600.0),
    max_days=400.0)

# contention-kneed DTNs: aggregate throughput degrades beyond the knee, so
# concurrency has a real optimum for the AIMD tuner to find
_LLNL_KNEE = SiteSpec("LLNL", read_gbps=1.5, write_gbps=1.5,
                      scan_files_per_s=20_000,
                      scan_mem_limit_files=2_000_000, concurrency_knee=4)
_ALCF_KNEE = SiteSpec("ALCF", read_gbps=10.0, write_gbps=10.0,
                      concurrency_knee=6)
_OLCF_KNEE = SiteSpec("OLCF", read_gbps=10.0, write_gbps=10.0,
                      concurrency_knee=6)

LOSSY_ROUTE_TUNING = ScenarioSpec(
    name="lossy-route-tuning",
    description="Elevated NETWORK fault intensity over contention-kneed "
                "DTNs, launched over-parallel (6 transfers/route against a "
                "source knee of 4): the static baseline thrashes the DTNs "
                "for the whole campaign; the AIMD tuner observes the "
                "fault/throughput signal and backs concurrency off toward "
                "the knee.",
    source="LLNL", replicas=("ALCF", "OLCF"),
    sites=(_LLNL_KNEE, _ALCF_KNEE, _OLCF_KNEE), routes=_PAPER_ROUTES,
    outages=_PAPER_OUTAGES,
    faults=FaultProfileSpec(transient_per_tb=2.0, fragility_tail=1.9,
                            max_retries=10, backoff_s=1800.0),
    max_active_per_route=6,
    policy=TransferPolicySpec(
        controller="aimd", control_interval_s=6 * 3600.0,
        max_active_per_route=8),
    max_days=400.0)


# ---------------------------------------------------------- demand scenarios
# The point of the 7.3 PB was never the bytes: it was serving ESGF users
# from replicas near their compute.  These scenarios add a synthetic user
# population reading the catalog WHILE it replicates — requests served from
# whichever replica holds the dataset (else redirected to the slow source),
# user reads contending with movers for the site read caps, and popularity
# feeding back into replication order.
_ESGF_DEMAND = DemandSpec(
    users=2_000_000,                 # ~ESGF registered-user order of magnitude
    requests_per_user_day=0.01,      # ~20k dataset reads/day across the fleet
    zipf_s=1.1,
    wave_interval_s=6 * 3600.0,
    request_bytes=4 * GB,
    cache_bytes=int(1.5 * TB),
    eviction="lru",
    prioritize=True)

ESGF_SERVING = PAPER_2022.vary(
    name="esgf-serving",
    description="paper-2022 while 2M ESGF users read the catalog: requests "
                "land on whichever replica holds a dataset (else redirect "
                "to the slow source), user reads contend with movers for "
                "the site read caps, and popularity re-orders replication "
                "popular-first.",
    demand=_ESGF_DEMAND)

POPULAR_FIRST_VS_CATALOG_ORDER = PAPER_2022.vary(
    name="popular-first-vs-catalog-order",
    description="The esgf-serving ablation: identical traffic but "
                "replication keeps catalog order (no popularity feedback) "
                "— the comparator that shows what popular-first buys in "
                "time-to-90%-hit-rate.",
    demand=dataclasses.replace(_ESGF_DEMAND, prioritize=False))

CACHE_PRESSURE = PAPER_2022.vary(
    name="cache-pressure",
    description="Serving under cache pressure: 6M users, 64 GB replica "
                "caches, popularity-weighted eviction, demand-driven "
                "warm-ups, and popularity drifting every 20 days.",
    demand=DemandSpec(
        users=6_000_000,
        requests_per_user_day=0.01,
        zipf_s=1.1,
        drift_interval_days=20.0,
        drift_fraction=0.25,
        wave_interval_s=6 * 3600.0,
        request_bytes=4 * GB,
        cache_bytes=64 * GB,
        eviction="popularity",
        warm_per_wave=2,
        prioritize=True))


# --------------------------------------------------------- integrity scenarios
# Silent corruption: a small fraction of landed bytes are bad on arrival
# (undetected by the in-flight INTEGRITY faults, which fire and retry during
# the transfer).  The scrub engine periodically re-verifies landed replicas
# in size-bounded passes and routes detected replicas back through the
# ordinary retry/relay machinery as repairs.  The rate is accelerated
# (~25 bad replicas/PB landed, vs real-world fractions of one) so that
# reduced-shape CI replays still draw a handful of corruptions.
_SCRUB = ScrubSpec(latent_per_pb=25.0, interval_days=5.0,
                   scan_tb_per_pass=2000.0)

SCRUB_AND_REPAIR = PAPER_2022.vary(
    name="scrub-and-repair",
    description="paper-2022 with accelerated latent corruption (~25 bad "
                "replicas/PB landed) and a 5-day scrub cadence at 2 PB/pass: "
                "detected replicas are re-transferred through the normal "
                "retry path, contending with live replication, until the "
                "campaign ends corruption-free.",
    scrub=_SCRUB)

BIT_ROT_PAPER = PAPER_2022.vary(
    name="bit-rot-paper",
    description="The no-scrub ablation: identical latent-corruption draws "
                "but no re-verification ever runs — the campaign 'succeeds' "
                "while silently corrupt replicas survive to the end, "
                "measurable in the integrity summary.",
    scrub=dataclasses.replace(_SCRUB, interval_days=0.0))

CORRUPT_UNDER_DEMAND = ESGF_SERVING.vary(
    name="corrupt-under-demand",
    description="esgf-serving with latent corruption and scrubbing: "
                "detected replicas drop out of the serveable set (hit rate "
                "dips), repairs contend with user traffic for the read "
                "caps, and the serveable set recovers as repairs land.",
    scrub=_SCRUB)


# ------------------------------------------------------ federation scenarios
# The paper's actual regime: the 29M-file catalog was moved TWICE — to ANL
# and to ORNL — as two overlapping campaigns contending for the same
# ~1.5 GB/s source file system.  Each half below is a complete
# single-destination campaign; the federation family runs them over one
# shared world (one clock/transport/LLNL read cap).
PAPER_TO_ALCF = ScenarioSpec(
    name="paper-to-alcf",
    description="The ALCF half of the 2022 campaign as its own campaign: "
                "LLNL sources 7.3 PB to ALCF over the direct route only "
                "(no inter-LCF relay), with the ALCF maintenance calendar.",
    source="LLNL", replicas=("ALCF",),
    sites=(_LLNL, _ALCF),
    routes=(RouteSpec("LLNL", "ALCF", 2 * 0.648),),
    outages=(OutageSpec("ALCF", start_day=5.0, duration_h=5 * 24.0),
             OutageSpec("ALCF", start_day=17.0, duration_h=12.0,
                        weekly=True)),
    max_days=400.0)

PAPER_TO_OLCF = ScenarioSpec(
    name="paper-to-olcf",
    description="The OLCF half of the 2022 campaign as its own campaign: "
                "LLNL sources 7.3 PB to OLCF direct, with OLCF's late DTN "
                "start and maintenance calendar.",
    source="LLNL", replicas=("OLCF",),
    sites=(_LLNL, _OLCF),
    routes=(RouteSpec("LLNL", "OLCF", 2 * 0.662),),
    outages=(OutageSpec("OLCF", start_day=0.0, duration_h=5 * 24.0,
                        planned=False),
             OutageSpec("OLCF", start_day=40.0, duration_h=12.0,
                        weekly=True)),
    max_days=400.0)

FEDERATION_PAPER_TWICE = FederationSpec(
    name="federation-paper-twice",
    description="The paper moved the catalog twice: the ALCF and OLCF "
                "pulls as two OVERLAPPED independent campaigns contending "
                "for the shared 1.5 GB/s LLNL source — aggregate LLNL "
                "egress stays capped at read_bw while both make progress.",
    members=(FederationMemberSpec(PAPER_TO_ALCF, start_day=0.0,
                                  label="alcf"),
             FederationMemberSpec(PAPER_TO_OLCF, start_day=0.0,
                                  label="olcf")),
    shared_sites=("LLNL",))

# the paper's headline regime end-to-end: all 28.9 M files moved TWICE, at
# file granularity.  Both members run the mixed-bundle-paper control plane —
# the composer synthesizes each dataset's file manifest and packs file runs
# into size-balanced bundles — so the simulator's unit of work is the same
# as the tool's (Globus tasks over file batches), not a per-dataset proxy.
# This is the scale point the array-native hot path is gated on: the full
# two-destination replay must stay O(active bundles) in memory and complete
# in minutes on one core (see benchmarks/check_regression.py check_scaling).
_PAPER_29M_POLICY = TransferPolicySpec(
    bundling="balanced", granularity="file", controller="gradient",
    target_files=500_000, target_bytes=100 * TB,
    max_files=1_500_000, max_bytes=400 * TB,
    balance_batch=4,
    control_interval_s=12 * 3600.0)

PAPER_29M_TWICE = dataclasses.replace(
    FEDERATION_PAPER_TWICE.with_policy(_PAPER_29M_POLICY),
    name="paper-29m-twice",
    description="The catalog's 28.9 M files moved twice at file "
                "granularity: the ALCF and OLCF pulls as overlapped "
                "campaigns whose control planes pack file runs into "
                "size-balanced bundles — the paper-scale stress point for "
                "the O(active) hot path.")

FEDERATION_PAPER_SERIAL = FederationSpec(
    name="federation-paper-serial",
    description="The serial comparator: the same two pulls back to back "
                "(OLCF starts only after the ALCF campaign's window), so "
                "LLNL egress is never shared — total campaign days must "
                "LOSE to federation-paper-twice.",
    members=(FederationMemberSpec(PAPER_TO_ALCF, start_day=0.0,
                                  label="alcf"),
             FederationMemberSpec(PAPER_TO_OLCF, start_day=100.0,
                                  label="olcf")),
    shared_sites=("LLNL",))

FEDERATION_PAPER_AND_TOPUP = FederationSpec(
    name="federation-paper-and-topup",
    description="Mixed federation: the relay-assisted two-destination "
                "paper campaign and an incremental top-up campaign share "
                "one world — every site and route is contended.",
    members=(FederationMemberSpec(PAPER_2022, start_day=0.0,
                                  label="paper"),
             FederationMemberSpec(INCREMENTAL_TOP_UP, start_day=2.0,
                                  label="topup")),
    shared_sites=("LLNL", "ALCF", "OLCF"))


# ------------------------------------------------------ ensemble scenarios
# Batched what-if studies over the specs above: a base scenario plus
# perturbation axes, run as N lanes in lockstep by repro.ensemble (or as N
# scalar replays when the base needs an event-driven subsystem).

ENSEMBLE_PAPER_BANDS = EnsembleSpec(
    name="ensemble-paper-bands",
    base=PAPER_2022,
    n_lanes=256)                     # pure seed sweep; lane 0 == paper-2022
"""Confidence bands for the headline result: the 2022 campaign replayed
across 256 world seeds (catalog draw + fault stream), reduced to
p5/p50/p95 campaign days.  Lane 0 is the unperturbed paper-2022 world the
bit-identity gate replays against the scalar engine."""

AIMD_SEARCH = EnsembleSpec(
    name="aimd-search",
    base=LOSSY_ROUTE_TUNING,
    axes=(AxisSpec("policy.fault_budget", (4, 8, 16)),
          AxisSpec("policy.drop_fraction", (0.10, 0.15, 0.25)),
          AxisSpec("policy.control_interval_s",
                   (3 * 3600.0, 6 * 3600.0, 12 * 3600.0))),
    n_lanes=27, mode="grid")
"""Grid search over the AIMD tuner's constants on the lossy-route scenario
(3 x 3 x 3 = 27 lanes).  Policy axes compile to a control plane, so this
ensemble runs on the scalar fallback; the search driver checkpoints
progress between chunks."""

SEED_SWEEP_FEDERATION = EnsembleSpec(
    name="seed-sweep-federation",
    base=FEDERATION_PAPER_TWICE,
    n_lanes=8)
"""Seed sweep over the overlapped two-campaign federation — federations
need the shared-transport scalar path, so every lane is an independent
event-engine replay reduced to one row (span days, summed counters)."""

_ENSEMBLE_REGISTRY: Dict[str, EnsembleSpec] = {
    s.name: s for s in (ENSEMBLE_PAPER_BANDS, AIMD_SEARCH,
                        SEED_SWEEP_FEDERATION)
}


_REGISTRY: Dict[str, ScenarioSpec] = {
    s.name: s for s in (
        PAPER_2022, FOUR_SITE_MESH, DEGRADED_SOURCE, FAULT_STORM,
        HARSH_FAULTS,
        FLAKY_NETWORK, INCREMENTAL_TOP_UP, COLD_START_RELAY, MEGA_CAMPAIGN,
        PAPER_TO_ALCF, PAPER_TO_OLCF,
        SMALL_FILE_STORM, MIXED_BUNDLE_PAPER, LOSSY_ROUTE_TUNING,
        ESGF_SERVING, POPULAR_FIRST_VS_CATALOG_ORDER, CACHE_PRESSURE,
        SCRUB_AND_REPAIR, BIT_ROT_PAPER, CORRUPT_UNDER_DEMAND)
}

_FEDERATION_REGISTRY: Dict[str, FederationSpec] = {
    s.name: s for s in (FEDERATION_PAPER_TWICE, FEDERATION_PAPER_SERIAL,
                        FEDERATION_PAPER_AND_TOPUP, PAPER_29M_TWICE)
}

# the crash-injection family: kill/resume meta-scenarios wrapping the specs
# above (run via repro.scenarios.crash_resume.run_crash_resume, not build())
_CRASH_REGISTRY: Dict[str, "CrashResumeSpec"] = dict(CRASH_RESUME_SCENARIOS)


def list_scenarios() -> List[str]:
    """Names of the plain (buildable) ``ScenarioSpec`` scenarios."""
    return sorted(_REGISTRY)


def list_federations() -> List[str]:
    """Names of the federated (N concurrent campaigns) scenario family."""
    return sorted(_FEDERATION_REGISTRY)


def list_crash_scenarios() -> List[str]:
    """Names of the crash-resume (kill/resume) scenario family."""
    return sorted(_CRASH_REGISTRY)


def list_ensembles() -> List[str]:
    """Names of the ensemble (batched what-if) scenario family."""
    return sorted(_ENSEMBLE_REGISTRY)


def scenario_tags(spec) -> List[str]:
    """Feature tags for a registry entry (``--list`` annotations): which
    opt-in subsystems the scenario exercises."""
    tags: List[str] = []
    if isinstance(spec, CrashResumeSpec):
        tags.append("crash-resume")
        spec = get_scenario(spec.base)   # tag by the wrapped base scenario
    if isinstance(spec, EnsembleSpec):
        tags.append("ensemble")
        tags.extend(scenario_tags(spec.base))   # tag by the base scenario
        return tags
    if isinstance(spec, FederationSpec):
        tags.append("federation")
        if any(m.scenario.policy.enabled for m in spec.members) or (
                spec.policy is not None and spec.policy.enabled):
            tags.append("policy")
        if any(m.scenario.demand.enabled for m in spec.members):
            tags.append("demand")
        if any(m.scenario.scrub.enabled for m in spec.members):
            tags.append("scrub")
        if any(m.scenario.obs.enabled for m in spec.members):
            tags.append("obs")
        return tags
    if getattr(spec, "policy", None) is not None and spec.policy.enabled:
        tags.append("policy")
    if getattr(spec, "demand", None) is not None and spec.demand.enabled:
        tags.append("demand")
    if getattr(spec, "scrub", None) is not None and spec.scrub.enabled:
        tags.append("scrub")
    if getattr(spec, "obs", None) is not None and spec.obs.enabled:
        tags.append("obs")
    if getattr(spec, "top_ups", ()):
        tags.append("top-ups")
    return tags


def get_scenario(name: str):
    """Look up a scenario by name: a ``ScenarioSpec``, a ``FederationSpec``
    for the federation family, a ``CrashResumeSpec`` for the crash-resume
    family, or an ``EnsembleSpec`` for the ensemble family."""
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name in _FEDERATION_REGISTRY:
        return _FEDERATION_REGISTRY[name]
    if name in _CRASH_REGISTRY:
        return _CRASH_REGISTRY[name]
    if name in _ENSEMBLE_REGISTRY:
        return _ENSEMBLE_REGISTRY[name]
    known = (sorted(_REGISTRY) + sorted(_FEDERATION_REGISTRY)
             + sorted(_CRASH_REGISTRY) + sorted(_ENSEMBLE_REGISTRY))
    raise KeyError(
        f"unknown scenario {name!r}; available: {', '.join(known)}")


def register(spec):
    """Add a custom scenario (tests and downstream configs); federation and
    crash-resume specs go into their own family registries."""
    if isinstance(spec, CrashResumeSpec):
        _CRASH_REGISTRY[spec.name] = spec
    elif isinstance(spec, FederationSpec):
        _FEDERATION_REGISTRY[spec.name] = spec
    elif isinstance(spec, EnsembleSpec):
        _ENSEMBLE_REGISTRY[spec.name] = spec
    else:
        _REGISTRY[spec.name] = spec
    return spec
