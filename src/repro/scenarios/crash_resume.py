"""Crash-injection campaign scenarios: kill the world N times, resume, and
prove the trajectory is bit-identical to an uninterrupted run.

A ``CrashResumeSpec`` wraps a base registry scenario with a kill schedule
expressed as fractions of the uninterrupted run's iteration count.  Running
one is a three-act experiment:

  1. replay the base scenario uninterrupted and record its trajectory
     summary (iterations, simulated days, fault count, succeeded-set digest);
  2. replay it again, killing the process state at each scheduled iteration
     via ``Checkpointer(kill_after=...)`` — every kill leaves only the
     on-disk snapshot behind; the world object is discarded and rebuilt from
     the checkpoint with ``resume_world``;
  3. diff the resumed run's final trajectory summary against the reference —
     ``match`` must be exact, float equality included.

This is the operational property the paper's tool was built around
(progress in a database, the driver process disposable) turned into a
repeatable scenario family.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.snapshot import (CampaignKilled, Checkpointer,
                                 federation_trajectory_summary, resume_world,
                                 trajectory_summary)
from repro.scenarios.events import EngineStats, run_world


def summarize_trajectory(world, report, stats: EngineStats) -> Dict:
    """The bit-identity tuple for either world kind: per-member summaries
    for a federation, the single-campaign summary otherwise."""
    if hasattr(world, "runtimes"):
        return federation_trajectory_summary(report, stats, world)
    return trajectory_summary(report, stats, world.table)


@dataclass(frozen=True)
class CrashResumeSpec:
    """A named crash-injection scenario: ``base`` is a registry
    ``ScenarioSpec`` name; ``kill_fracs`` are kill points as fractions of the
    uninterrupted run's iteration count."""
    name: str
    description: str
    base: str
    kill_fracs: Tuple[float, ...] = (0.5,)
    engine: str = "events"


def run_crash_resume(spec: CrashResumeSpec, workdir: str,
                     scale: float = 1.0, seed: int = 0,
                     n_datasets: Optional[int] = None,
                     policy_static: bool = False) -> Dict:
    """Run the three-act kill/resume experiment; returns a report dict whose
    ``match`` field is the acceptance verdict.  ``policy_static`` forces the
    base scenario onto the naive static per-dataset policy (CLI ``--policy
    static``)."""
    from repro.scenarios.registry import get_scenario
    base = get_scenario(spec.base)
    if isinstance(base, CrashResumeSpec):
        raise TypeError(f"{spec.name}: base scenario {spec.base!r} is itself "
                        "a crash-resume scenario")
    if policy_static and hasattr(base, "with_policy"):
        from repro.control.policy import STATIC_POLICY
        base = base.with_policy(STATIC_POLICY)

    # act 1: the uninterrupted reference trajectory
    world = base.build(scale=scale, seed=seed, n_datasets=n_datasets)
    ref_stats = EngineStats()
    ref_report = run_world(world, engine=spec.engine, stats=ref_stats)
    reference = summarize_trajectory(world, ref_report, ref_stats)

    # the kill schedule in absolute iterations, strictly inside the run
    total = ref_stats.iterations
    kills = sorted({min(max(1, int(f * total)), total - 1)
                    for f in spec.kill_fracs})

    # act 2: kill at every scheduled point, resuming from disk each time
    world = base.build(scale=scale, seed=seed, n_datasets=n_datasets)
    stats = EngineStats()
    loop = None
    killed_at: List[int] = []
    report = None
    for k in kills:
        ck = Checkpointer(workdir, kill_after=k)
        try:
            report = run_world(world, engine=spec.engine, stats=stats,
                               checkpointer=ck, resume=loop)
            break                       # finished before this kill point
        except CampaignKilled as killed:
            killed_at.append(killed.iterations)
        world, _, loop = resume_world(workdir)
        stats = EngineStats()
    else:
        # act 3: final resume runs to completion
        report = run_world(world, engine=spec.engine, stats=stats, resume=loop)
    resumed = summarize_trajectory(world, report, stats)

    return {
        "scenario": spec.name,
        "base": spec.base,
        "engine": spec.engine,
        "kills": killed_at,
        "reference": reference,
        "resumed": resumed,
        "match": resumed == reference,
    }


# ------------------------------------------------------------ scenario family
CRASH_RESUME_PAPER = CrashResumeSpec(
    name="crash-resume-paper",
    description="Kill the paper-2022 replay at 35% and 70% of its "
                "iterations, resuming from the durable snapshot each time; "
                "the final trajectory must be bit-identical to an "
                "uninterrupted run.",
    base="paper-2022", kill_fracs=(0.35, 0.7))

CRASH_RESUME_STORM = CrashResumeSpec(
    name="crash-resume-storm",
    description="Three kills through the fault-storm scenario: heavy "
                "retry/backoff state and a hot fault-RNG stream must all "
                "survive resume.",
    base="fault-storm", kill_fracs=(0.25, 0.5, 0.75))

CRASH_RESUME_TOPUP = CrashResumeSpec(
    name="crash-resume-topup",
    description="Kill mid-campaign while incremental top-ups are still "
                "being published: the feed cursor, pending-publication set, "
                "and mid-run catalog additions must survive resume.",
    base="incremental-top-up", kill_fracs=(0.5,))

CRASH_RESUME_STEP = CrashResumeSpec(
    name="crash-resume-step",
    description="Kill/resume under the fixed-step driver — resume "
                "determinism must not depend on the event engine.",
    base="paper-2022", kill_fracs=(0.5,), engine="step")

CRASH_RESUME_FEDERATION = CrashResumeSpec(
    name="crash-resume-federation",
    description="Kill the overlapped two-campaign federation at ~50%: the "
                "shared clock/RNG/transport plus every member's scheduler "
                "and table must resume to identical per-member digests.",
    base="federation-paper-twice", kill_fracs=(0.5,))

CRASH_RESUME_POLICY = CrashResumeSpec(
    name="crash-resume-policy",
    description="Kill the adaptive small-file-storm campaign at ~50%: the "
                "bundle-composer cursor, already-cut bundles, controller "
                "internals, live route caps, and the policy ledger must "
                "all resume to a digest-identical trajectory.",
    base="small-file-storm", kill_fracs=(0.5,))

CRASH_RESUME_DEMAND = CrashResumeSpec(
    name="crash-resume-demand",
    description="Kill the esgf-serving campaign at ~50% with user traffic "
                "live: the request-workload RNG, popularity order, read "
                "caches, wave cursors, prioritized scheduler heaps, and the "
                "transport's read load must all resume to a digest-identical "
                "trajectory.",
    base="esgf-serving", kill_fracs=(0.5,))

CRASH_RESUME_SCRUB = CrashResumeSpec(
    name="crash-resume-scrub",
    description="Kill the scrub-and-repair campaign at ~50%, mid-scrub: the "
                "scrub anchor and cursor, at-risk/repairing ledgers, "
                "incarnation counters, and exposure accounting must all "
                "resume to a digest-identical corruption-free end state.",
    base="scrub-and-repair", kill_fracs=(0.5,))

CRASH_RESUME_SCENARIOS: Dict[str, CrashResumeSpec] = {
    s.name: s for s in (CRASH_RESUME_PAPER, CRASH_RESUME_STORM,
                        CRASH_RESUME_TOPUP, CRASH_RESUME_STEP,
                        CRASH_RESUME_FEDERATION, CRASH_RESUME_POLICY,
                        CRASH_RESUME_DEMAND, CRASH_RESUME_SCRUB)
}
