"""Scenario CLI.

    PYTHONPATH=src python -m repro.scenarios.run --scenario paper-2022 \
        [--engine events|step] [--datasets N] [--scale S] [--seed K] \
        [--checkpoint-dir DIR] [--checkpoint-every K] [--kill-after N] \
        [--json out.json] [--verbose]
    PYTHONPATH=src python -m repro.scenarios.run --resume DIR [...]
    PYTHONPATH=src python -m repro.scenarios.run --list

Operating a campaign: pass ``--checkpoint-dir`` to write durable snapshots
every ``--checkpoint-every`` iterations and on SIGTERM/SIGINT.  A killed run
exits with code 3; ``--resume DIR`` continues it from the latest snapshot
with a bit-identical trajectory (the report's ``trajectory`` block — digest
included — matches the uninterrupted run's).  ``--kill-after N`` kills the
run deterministically at iteration N (CI's crash-resume equivalence check).

Crash-resume family scenarios (``--scenario crash-resume-*``) run the whole
kill/resume experiment against an uninterrupted reference and exit non-zero
unless the trajectories match.

Federation scenarios (``--scenario federation-*``) run N campaigns over one
shared simulated world; every flag above — ``--engine``, ``--datasets``,
``--scale``, ``--checkpoint-dir``, ``--kill-after``, ``--resume`` — works
unchanged (checkpoints then carry one table copy per member campaign).

Observability: ``--obs RUN.ndjson`` streams the flight recorder (lifecycle
trace + metrics samples) to a file, force-enabling trace+metrics when the
scenario does not declare its own ``ObsSpec``; ``--obs-cadence DAYS``
overrides the metrics sample interval; ``python -m repro.obs.report
RUN.ndjson`` renders the post-mortem.  ``--profile`` adds per-phase
wall-time buckets to the report.  Observation never changes the
trajectory — the report's ``trajectory`` block (digest included) is
bit-identical with or without these flags.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import tempfile
import time
from typing import Optional, Sequence

from repro.core.campaign import FederationReport
from repro.core.snapshot import (CampaignKilled, Checkpointer, SnapshotError,
                                 federation_trajectory_summary, resume_world,
                                 trajectory_summary)
from repro.scenarios.crash_resume import CrashResumeSpec, run_crash_resume
from repro.scenarios.events import EngineStats, run_world
from repro.scenarios.registry import (get_scenario, list_crash_scenarios,
                                      list_federations, list_scenarios,
                                      scenario_tags)

EXIT_KILLED = 3


def report_to_dict(rep, stats: EngineStats, wall_s: float) -> dict:
    return {
        "wall_s": round(wall_s, 3),
        "engine_iterations": stats.iterations,
        "duration_days": round(rep.duration_days, 3),
        "floor_days": round(rep.floor_days, 3),
        "total_tb": round(rep.total_bytes / 1024 ** 4, 3),
        "bytes_at": {k: int(v) for k, v in rep.bytes_at.items()},
        "complete_at_all": all(v >= rep.total_bytes * 0.999
                               for v in rep.bytes_at.values()),
        "per_route_gbps": {f"{a}->{b}": round(v, 3)
                           for (a, b), v in rep.per_route_gbps.items()},
        "per_route_transfers": {f"{a}->{b}": v
                                for (a, b), v in rep.per_route_transfers.items()},
        "faults_total": rep.faults_total,
        "faults_mean": round(rep.faults_per_transfer_mean, 3),
        "faults_max": rep.faults_per_transfer_max,
        "fault_histogram": {str(k): v
                            for k, v in sorted(rep.fault_histogram.items())},
        "quarantined": rep.quarantined,
        "notifications": len(rep.notifications),
    }


def _member_report_to_dict(rep) -> dict:
    """A member campaign's slice of the federation report (no wall clock or
    iteration counts — those are shared across the federation)."""
    return {
        "duration_days": round(rep.duration_days, 3),
        "floor_days": round(rep.floor_days, 3),
        "total_tb": round(rep.total_bytes / 1024 ** 4, 3),
        "bytes_at": {k: int(v) for k, v in rep.bytes_at.items()},
        "complete_at_all": all(v >= rep.total_bytes * 0.999
                               for v in rep.bytes_at.values()),
        "per_route_gbps": {f"{a}->{b}": round(v, 3)
                           for (a, b), v in rep.per_route_gbps.items()},
        "per_route_transfers": {f"{a}->{b}": v
                                for (a, b), v in rep.per_route_transfers.items()},
        "faults_total": rep.faults_total,
        "quarantined": rep.quarantined,
        "notifications": len(rep.notifications),
    }


def federation_report_to_dict(rep: FederationReport, stats: EngineStats,
                              wall_s: float) -> dict:
    return {
        "wall_s": round(wall_s, 3),
        "engine_iterations": stats.iterations,
        "span_days": round(rep.span_days, 3),
        "started_day": {k: round(v, 3) for k, v in rep.started_day.items()},
        "finished_day": {k: round(v, 3) for k, v in rep.finished_day.items()},
        "members": {label: _member_report_to_dict(m)
                    for label, m in rep.members.items()},
    }


def _emit(doc: dict, json_path: Optional[str]) -> None:
    print(json.dumps(doc, indent=2))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)


def _apply_obs(spec, args):
    """The spec the obs flags ask for: force ``FULL_OBS`` onto a scenario
    (or every federation member) that declared none, and apply a cadence
    override onto whatever is enabled."""
    import dataclasses as _dc

    from repro.obs.spec import FULL_OBS
    if hasattr(spec, "members"):                # FederationSpec
        base = spec.members[0].scenario.obs
        declared = any(m.scenario.obs.enabled for m in spec.members)
    else:
        base = spec.obs
        declared = spec.obs.enabled
    if args.obs and not declared:
        base = FULL_OBS
    if args.obs_cadence is not None:
        base = _dc.replace(base, sample_interval_days=args.obs_cadence)
    return spec.with_obs(base)


def _obs_runtimes(world):
    """Every observed campaign runtime of a (possibly federation) world."""
    runtimes = (world.runtimes if hasattr(world, "runtimes")
                else [world.runtime])
    return [rt for rt in runtimes if rt is not None and rt.obs is not None]


def _run_crash_family(spec: CrashResumeSpec, args) -> int:
    if args.engine and args.engine != spec.engine:
        spec = dataclasses.replace(spec, engine=args.engine)
    workdir = args.checkpoint_dir or tempfile.mkdtemp(prefix="crash-resume-")
    t0 = time.time()
    res = run_crash_resume(spec, workdir, scale=args.scale, seed=args.seed,
                           n_datasets=args.datasets,
                           policy_static=args.policy == "static")
    res["wall_s"] = round(time.time() - t0, 3)
    res["checkpoint_dir"] = workdir
    _emit(res, args.json)
    return 0 if res["match"] else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios.run",
        description="Run a named replication-campaign scenario.")
    ap.add_argument("--scenario", default=None,
                    help="scenario name (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--engine", choices=("events", "step"), default=None,
                    help="driver engine (default: events, or the snapshot's "
                         "engine when resuming)")
    ap.add_argument("--datasets", type=int, default=None,
                    help="override the catalog's dataset count")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="byte/file-count scale factor (1.0 = full 7.3 PB)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", choices=("declared", "static"),
                    default="declared",
                    help="transfer policy: the scenario's declared control "
                         "plane, or 'static' to force the naive per-dataset "
                         "fixed-concurrency baseline")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume from the latest snapshot in DIR (scenario, "
                         "seed, scale, and engine come from the snapshot)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="write durable snapshots into DIR (created on "
                         "demand); also where SIGTERM/SIGINT checkpoints land")
    ap.add_argument("--checkpoint-every", type=int, default=200,
                    metavar="K", help="snapshot cadence in driver iterations "
                                      "(default 200; 0 = only on kill/signal)")
    ap.add_argument("--kill-after", type=int, default=None, metavar="N",
                    help="checkpoint and exit (code 3) once N iterations have "
                         "run — deterministic crash injection")
    ap.add_argument("--json", default=None, help="also write the report here")
    ap.add_argument("--obs", default=None, metavar="RUN.ndjson",
                    help="stream the flight recorder (trace + metrics) to "
                         "this NDJSON file; enables trace+metrics when the "
                         "scenario does not declare observability")
    ap.add_argument("--obs-cadence", type=float, default=None, metavar="DAYS",
                    help="metrics sample interval in sim days (with --obs, "
                         "or overriding a declared ObsSpec)")
    ap.add_argument("--profile", action="store_true",
                    help="instrument the hot-path seams and report per-phase "
                         "wall-time buckets")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for name in (list_scenarios() + list_federations()
                     + list_crash_scenarios()):
            spec = get_scenario(name)
            tags = scenario_tags(spec)
            annot = f" [{','.join(tags)}]" if tags else ""
            print(f"{name:32}{annot:28} {spec.description}")
        return 0
    if not args.scenario and not args.resume:
        ap.error("--scenario or --resume is required (or use --list)")
    if args.scenario and args.resume:
        ap.error("--scenario and --resume are mutually exclusive")
    if args.resume and (args.obs or args.obs_cadence is not None):
        ap.error("--obs/--obs-cadence cannot be combined with --resume "
                 "(a resumed world is rebuilt from the scenario "
                 "declaration; declare an ObsSpec in the registry spec "
                 "instead)")

    if not args.resume:
        try:
            spec = get_scenario(args.scenario)
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2
        if isinstance(spec, CrashResumeSpec):
            return _run_crash_family(spec, args)
        if args.policy == "static" and hasattr(spec, "with_policy"):
            from repro.control.policy import STATIC_POLICY
            spec = spec.with_policy(STATIC_POLICY)
        if (args.obs or args.obs_cadence is not None) \
                and hasattr(spec, "with_obs"):
            spec = _apply_obs(spec, args)

    # install signal routing BEFORE the (potentially slow) world build, so a
    # SIGTERM at any point after startup exits through the checkpoint path
    ckpt_dir = args.checkpoint_dir or args.resume
    checkpointer = None
    if ckpt_dir or args.kill_after is not None:
        checkpointer = Checkpointer(
            ckpt_dir or tempfile.mkdtemp(prefix="campaign-ckpt-"),
            every=args.checkpoint_every, kill_after=args.kill_after)
        checkpointer.install_signal_handlers()

    resumed_from = None
    if args.resume:
        try:
            world, snap, loop = resume_world(args.resume)
        except (SnapshotError, FileNotFoundError, KeyError) as e:
            print(f"error: cannot resume from {args.resume!r}: {e}",
                  file=sys.stderr)
            return 2
        engine = args.engine or snap.engine
        spec = world.spec
        resumed_from = {"dir": args.resume, "iterations": snap.iterations}
    else:
        world = spec.build(scale=args.scale, seed=args.seed,
                           n_datasets=args.datasets)
        loop = None
        engine = args.engine or "events"
    if args.verbose:
        print(f"# {spec.name}: {spec.description}", file=sys.stderr)

    sink = None
    if args.obs:
        from repro.obs.sink import ObsSink
        sink = ObsSink(args.obs)
        for rt in _obs_runtimes(world):
            rt.obs.attach_sink(sink)
    prof = None
    if args.profile:
        from repro.obs.profile import PhaseProfiler
        prof = PhaseProfiler().instrument_standard()

    stats = EngineStats()
    t0 = time.time()
    try:
        rep = run_world(world, engine=engine, stats=stats,
                        checkpointer=checkpointer, resume=loop)
    except CampaignKilled as killed:
        _emit({"scenario": spec.name, "engine": engine, "killed": True,
               "iterations": killed.iterations,
               "checkpoint_dir": killed.checkpoint_dir,
               "resume_with": f"python -m repro.scenarios.run "
                              f"--resume {killed.checkpoint_dir}"},
              args.json)
        return EXIT_KILLED
    finally:
        if prof is not None:
            prof.restore()
        if sink is not None:
            sink.close()
    if isinstance(rep, FederationReport):
        out = federation_report_to_dict(rep, stats, time.time() - t0)
        out["trajectory"] = federation_trajectory_summary(rep, stats, world)
        demand = {rt.label: rt.demand.summary() for rt in world.runtimes
                  if rt.demand is not None}
        if demand:
            out["demand"] = demand
        scrub = {rt.label: rt.scrub.summary() for rt in world.runtimes
                 if rt.scrub is not None}
        if scrub:
            out["scrub"] = scrub
        obs = {rt.label: rt.obs.summary() for rt in world.runtimes
               if rt.obs is not None}
        if obs:
            out["obs"] = obs
    else:
        out = report_to_dict(rep, stats, time.time() - t0)
        out["trajectory"] = trajectory_summary(rep, stats, world.table)
        if world.demand is not None:
            out["demand"] = world.demand.summary()
        if world.scrub is not None:
            out["scrub"] = world.scrub.summary()
        if world.obs is not None:
            out["obs"] = world.obs.summary()
    out["scenario"] = spec.name
    out["engine"] = engine
    if prof is not None:
        out["profile"] = prof.report(time.time() - t0)
    if resumed_from is not None:
        out["resumed_from"] = resumed_from
    if checkpointer is not None:
        out["checkpoints_written"] = checkpointer.writes
    _emit(out, args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
