"""Scenario CLI.

    PYTHONPATH=src python -m repro.scenarios.run --scenario paper-2022 \
        [--engine events|step] [--datasets N] [--scale S] [--seed K] \
        [--json out.json] [--verbose]
    PYTHONPATH=src python -m repro.scenarios.run --list
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional, Sequence

from repro.scenarios.events import EngineStats, run_scenario
from repro.scenarios.registry import get_scenario, list_scenarios


def report_to_dict(rep, stats: EngineStats, wall_s: float) -> dict:
    return {
        "wall_s": round(wall_s, 3),
        "engine_iterations": stats.iterations,
        "duration_days": round(rep.duration_days, 3),
        "floor_days": round(rep.floor_days, 3),
        "total_tb": round(rep.total_bytes / 1024 ** 4, 3),
        "bytes_at": {k: int(v) for k, v in rep.bytes_at.items()},
        "complete_at_all": all(v >= rep.total_bytes * 0.999
                               for v in rep.bytes_at.values()),
        "per_route_gbps": {f"{a}->{b}": round(v, 3)
                           for (a, b), v in rep.per_route_gbps.items()},
        "per_route_transfers": {f"{a}->{b}": v
                                for (a, b), v in rep.per_route_transfers.items()},
        "faults_total": rep.faults_total,
        "faults_mean": round(rep.faults_per_transfer_mean, 3),
        "faults_max": rep.faults_per_transfer_max,
        "fault_histogram": {str(k): v
                            for k, v in sorted(rep.fault_histogram.items())},
        "quarantined": rep.quarantined,
        "notifications": len(rep.notifications),
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios.run",
        description="Run a named replication-campaign scenario.")
    ap.add_argument("--scenario", default=None,
                    help="scenario name (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--engine", choices=("events", "step"), default="events")
    ap.add_argument("--datasets", type=int, default=None,
                    help="override the catalog's dataset count")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="byte/file-count scale factor (1.0 = full 7.3 PB)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="also write the report here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for name in list_scenarios():
            spec = get_scenario(name)
            print(f"{name:20} {spec.description}")
        return 0
    if not args.scenario:
        ap.error("--scenario is required (or use --list)")

    try:
        spec = get_scenario(args.scenario)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    if args.verbose:
        print(f"# {spec.name}: {spec.description}", file=sys.stderr)
    stats = EngineStats()
    t0 = time.time()
    rep = run_scenario(spec, engine=args.engine, scale=args.scale,
                       seed=args.seed, n_datasets=args.datasets, stats=stats)
    out = report_to_dict(rep, stats, time.time() - t0)
    out["scenario"] = spec.name
    out["engine"] = args.engine
    print(json.dumps(out, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
