"""Declarative scenario specifications.

A ``ScenarioSpec`` is a complete, human-readable description of a replication
campaign — site capabilities, route bandwidths, maintenance calendars, fault
profiles, catalog shape, and incidents — in natural units (GB/s, days,
hours).  ``build()`` compiles it onto the existing campaign wiring
(``CampaignConfig`` + ``RouteGraph`` + ``PauseManager`` + scheduler/transport
construction in ``repro.core.campaign.build_campaign``), so every scenario
runs through exactly the code path the paper-2022 reproduction uses.

Capacity-planning questions ("what if the source were slower?  what if
maintenance doubled?  what if a fourth site joined?") become one-line edits
to a spec or entries in ``repro.scenarios.registry``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.campaign import CampaignConfig, build_campaign
from repro.core.faults import FaultInjector, RetryPolicy
from repro.core.incremental import IncrementalReplicator, PublishFeed
from repro.core.pause import DAY, PauseManager
from repro.core.routes import GB, PB, Dataset, Route, RouteGraph, Site

HOUR = 3600.0


@dataclass(frozen=True)
class SiteSpec:
    """One storage site: aggregate read/write caps and scan behavior."""
    name: str
    read_gbps: float                       # GB/s (binary GB, as paper Table 3)
    write_gbps: float
    scan_files_per_s: float = 50_000.0
    scan_mem_limit_files: int = 5_000_000


@dataclass(frozen=True)
class RouteSpec:
    """One directed WAN route with its per-route bandwidth cap (GB/s)."""
    source: str
    destination: str
    gbps: float


@dataclass(frozen=True)
class OutageSpec:
    """A maintenance-calendar entry: one-off or weekly recurring."""
    site: str
    start_day: float
    duration_h: float
    weekly: bool = False
    until_day: Optional[float] = None      # default: campaign max_days
    planned: bool = True


@dataclass(frozen=True)
class FaultProfileSpec:
    """Transient-fault intensity and the retry policy responding to it."""
    transient_per_tb: float = 0.15
    fragility_tail: float = 2.5
    max_retries: int = 8
    backoff_s: float = 3600.0
    fault_retry_cost_s: float = 30.0


@dataclass(frozen=True)
class CatalogSpec:
    """Shape of the dataset catalog (paper: 2291 paths / 7.3 PB / 29 M files)."""
    n_datasets: int = 2291
    total_bytes: int = int(7.3 * PB)
    total_files: int = 28_907_532
    unreadable_fraction: float = 0.01      # CMIP5 permission incident


@dataclass(frozen=True)
class TopUpSpec:
    """Datasets published mid-campaign (paper C7, incremental replication)."""
    publish_day: float
    n_datasets: int
    bytes_each: int = int(2 * GB)
    files_each: int = 200


@dataclass
class ScenarioWorld:
    """A compiled, runnable scenario: the campaign wiring plus (optionally)
    an incremental-replication feed for mid-campaign top-ups."""
    spec: "ScenarioSpec"
    cfg: CampaignConfig
    graph: RouteGraph
    catalog: Dict[str, Dataset]
    clock: object
    pause: PauseManager
    transport: object
    table: object
    sched: object
    notifier: object
    incremental: Optional[IncrementalReplicator] = None
    top_up_times: Tuple[float, ...] = ()
    # build provenance, recorded so a campaign checkpoint can rebuild an
    # identical world (repro.core.snapshot)
    scale: float = 1.0
    seed: int = 0
    n_datasets: Optional[int] = None


@dataclass(frozen=True)
class ScenarioSpec:
    """A full declarative campaign scenario."""
    name: str
    description: str
    source: str
    replicas: Tuple[str, ...]
    sites: Tuple[SiteSpec, ...]
    routes: Tuple[RouteSpec, ...]
    outages: Tuple[OutageSpec, ...] = ()
    faults: FaultProfileSpec = FaultProfileSpec()
    catalog: CatalogSpec = CatalogSpec()
    top_ups: Tuple[TopUpSpec, ...] = ()
    human_fix_days: float = 3.0
    max_days: float = 200.0
    step_s: float = 1800.0                 # fixed-step engine cadence
    max_active_per_route: int = 2

    # ------------------------------------------------------------- compilers
    def to_campaign_config(self, scale: float = 1.0, seed: int = 0,
                           n_datasets: Optional[int] = None) -> CampaignConfig:
        return CampaignConfig(
            n_datasets=n_datasets if n_datasets is not None
            else self.catalog.n_datasets,
            total_bytes=self.catalog.total_bytes,
            total_files=self.catalog.total_files,
            source=self.source,
            replicas=tuple(self.replicas),
            step_s=self.step_s,
            max_days=self.max_days,
            seed=seed,
            unreadable_fraction=self.catalog.unreadable_fraction,
            human_fix_days=self.human_fix_days,
            scale=scale)

    def build_graph(self) -> RouteGraph:
        sites = [Site(s.name, read_bw=s.read_gbps * GB,
                      write_bw=s.write_gbps * GB,
                      scan_files_per_s=s.scan_files_per_s,
                      scan_mem_limit_files=s.scan_mem_limit_files)
                 for s in self.sites]
        routes = [Route(r.source, r.destination, r.gbps * GB)
                  for r in self.routes]
        return RouteGraph(sites, routes)

    def build_pause(self) -> PauseManager:
        pause = PauseManager()
        for o in self.outages:
            start = o.start_day * DAY
            if o.weekly:
                until = (o.until_day if o.until_day is not None
                         else self.max_days) * DAY
                pause.add_weekly(o.site, start, o.duration_h * HOUR, until,
                                 planned=o.planned)
            else:
                pause.add_window(o.site, start, start + o.duration_h * HOUR,
                                 planned=o.planned)
        return pause

    def build_retry(self) -> RetryPolicy:
        return RetryPolicy(max_retries=self.faults.max_retries,
                           backoff_s=self.faults.backoff_s,
                           fault_retry_cost_s=self.faults.fault_retry_cost_s)

    def build(self, scale: float = 1.0, seed: int = 0,
              n_datasets: Optional[int] = None, table=None) -> ScenarioWorld:
        """Compile the spec onto the campaign wiring, ready to run under
        either the fixed-step or the event-driven engine.  ``table`` accepts
        a restored ``TransferTable`` when resuming from a checkpoint."""
        cfg = self.to_campaign_config(scale=scale, seed=seed,
                                      n_datasets=n_datasets)
        injector = FaultInjector(seed=seed,
                                 transient_per_tb=self.faults.transient_per_tb,
                                 fragility_tail=self.faults.fragility_tail)
        (graph, catalog, clock, pause, transport, table, sched,
         notifier) = build_campaign(
            cfg, graph=self.build_graph(), pause=self.build_pause(),
            injector=injector, retry=self.build_retry(),
            max_active_per_route=self.max_active_per_route, table=table)
        world = ScenarioWorld(self, cfg, graph, catalog, clock, pause,
                              transport, table, sched, notifier,
                              scale=scale, seed=seed, n_datasets=n_datasets)
        if self.top_ups:
            feed = PublishFeed()
            times: List[float] = []
            for i, tu in enumerate(self.top_ups):
                t = tu.publish_day * DAY
                times.append(t)
                for j in range(tu.n_datasets):
                    feed.publish(t, Dataset(
                        path=f"/css03_data/CMIP6/TOPUP/batch-{i}/ds-{j:04d}",
                        bytes=int(tu.bytes_each * scale) or tu.bytes_each,
                        files=tu.files_each,
                        directories=max(1, tu.files_each // 10)))
            world.incremental = IncrementalReplicator(feed, sched,
                                                      check_interval=DAY)
            world.top_up_times = tuple(times)
        return world

    # --------------------------------------------------------------- helpers
    def vary(self, **changes) -> "ScenarioSpec":
        """A copy with top-level fields replaced (sweep convenience)."""
        return dataclasses.replace(self, **changes)

    def with_catalog(self, **changes) -> "ScenarioSpec":
        return dataclasses.replace(
            self, catalog=dataclasses.replace(self.catalog, **changes))

    def with_faults(self, **changes) -> "ScenarioSpec":
        return dataclasses.replace(
            self, faults=dataclasses.replace(self.faults, **changes))
