"""Declarative scenario specifications.

A ``ScenarioSpec`` is a complete, human-readable description of a replication
campaign — site capabilities, route bandwidths, maintenance calendars, fault
profiles, catalog shape, and incidents — in natural units (GB/s, days,
hours).  ``build()`` compiles it onto the existing campaign wiring
(``CampaignConfig`` + ``RouteGraph`` + ``PauseManager`` + scheduler/transport
construction in ``repro.core.campaign.build_campaign``), so every scenario
runs through exactly the code path the paper-2022 reproduction uses.

Capacity-planning questions ("what if the source were slower?  what if
maintenance doubled?  what if a fourth site joined?") become one-line edits
to a spec or entries in ``repro.scenarios.registry``.

Determinism invariants (what makes ``(spec, scale, seed, n_datasets)`` a
complete trajectory key, relied on by snapshots, the engine-equivalence
tests, and the ensemble lanes engine):

* ``build()`` is a pure function of its arguments: same spec + same
  ``(scale, seed, n_datasets)`` always wires the same world.  Specs are
  frozen dataclasses; ``vary()`` copies, never mutates.
* Exactly three RNG streams exist, all derived from ``seed``:
  the **catalog** stream (``make_catalog(seed)`` sizes + the
  ``default_rng(seed + 1)`` unreadable-marking draw in ``build_catalog``),
  the **fault** stream (``FaultInjector(seed)`` — consumed only at transfer
  submission, in submission order, via ``transient_marks``; plus the
  per-replica pure ``latent_corrupt_offsets`` draws which consume nothing),
  and the **demand** stream (``DemandEngine``'s arrival process, seeded
  ``default_rng([seed, 0x44454D44])`` so it can never interleave with the
  fault stream — absent under ``NO_DEMAND``).
* Everything else is derived: pause calendars come from the spec's outage
  list, control-plane decisions from observed state, scrub schedules from
  the spec.  No component reads the wall clock or an unseeded RNG.
"""
from __future__ import annotations

import dataclasses
from collections import ChainMap
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.control.bundles import BundleComposer
from repro.control.plane import ControlPlane
from repro.control.policy import STATIC_POLICY, TransferPolicySpec
from repro.core.campaign import (CampaignConfig, build_campaign,
                                 build_catalog)
from repro.core.faults import (FaultInjector, FederationNotifier, Notifier,
                               RetryPolicy)
from repro.core.incremental import IncrementalReplicator, PublishFeed
from repro.core.pause import DAY, PauseManager
from repro.core.routes import GB, PB, Dataset, Route, RouteGraph, Site
from repro.core.scrub import NO_SCRUB, ScrubEngine, ScrubSpec
from repro.core.transport import SimClock, SimulatedTransport
from repro.demand.engine import DemandEngine
from repro.demand.spec import NO_DEMAND, DemandSpec
from repro.obs.spec import NO_OBS, ObsSpec

HOUR = 3600.0


@dataclass(frozen=True)
class SiteSpec:
    """One storage site: aggregate read/write caps and scan behavior."""
    name: str
    read_gbps: float                       # GB/s (binary GB, as paper Table 3)
    write_gbps: float
    scan_files_per_s: float = 50_000.0
    scan_mem_limit_files: int = 5_000_000
    # DTN contention knee: concurrent transfers beyond this degrade the
    # site's aggregate throughput (None = ideal fair share)
    concurrency_knee: Optional[int] = None


@dataclass(frozen=True)
class RouteSpec:
    """One directed WAN route with its per-route bandwidth cap (GB/s)."""
    source: str
    destination: str
    gbps: float


@dataclass(frozen=True)
class OutageSpec:
    """A maintenance-calendar entry: one-off or weekly recurring."""
    site: str
    start_day: float
    duration_h: float
    weekly: bool = False
    until_day: Optional[float] = None      # default: campaign max_days
    planned: bool = True


@dataclass(frozen=True)
class FaultProfileSpec:
    """Transient-fault intensity and the retry policy responding to it."""
    transient_per_tb: float = 0.15
    fragility_tail: float = 2.5
    max_retries: int = 8
    backoff_s: float = 3600.0
    fault_retry_cost_s: float = 30.0


@dataclass(frozen=True)
class CatalogSpec:
    """Shape of the dataset catalog (paper: 2291 paths / 7.3 PB / 29 M files)."""
    n_datasets: int = 2291
    total_bytes: int = int(7.3 * PB)
    total_files: int = 28_907_532
    unreadable_fraction: float = 0.01      # CMIP5 permission incident


@dataclass(frozen=True)
class TopUpSpec:
    """Datasets published mid-campaign (paper C7, incremental replication)."""
    publish_day: float
    n_datasets: int
    bytes_each: int = int(2 * GB)
    files_each: int = 200


@dataclass
class SharedWorld:
    """The substrate N campaign runtimes attach to: one simulation clock, one
    route graph, one transport (whose fair-share ``_route_rates`` is where
    concurrent campaigns contend for route and site caps), one maintenance
    calendar, and — through the transport — one fault-RNG stream."""
    graph: RouteGraph
    clock: SimClock
    pause: PauseManager
    transport: SimulatedTransport


@dataclass
class CampaignRuntime:
    """One campaign's private runtime: its transfer table, Figure-4
    scheduler, notifier, optional incremental feed, and report identity —
    everything the driver steps per campaign, extracted from the old
    single-campaign ``ScenarioWorld``/``run_world`` so a federation can hold
    N of them over one ``SharedWorld``."""
    spec: "ScenarioSpec"
    cfg: CampaignConfig
    catalog: Dict[str, Dataset]
    table: object
    sched: object
    notifier: Notifier
    label: str = ""
    start_day: float = 0.0
    incremental: Optional[IncrementalReplicator] = None
    top_up_times: Tuple[float, ...] = ()
    # the campaign's control plane (bundling + online tuning); None for the
    # default static per-dataset policy
    control: Optional[ControlPlane] = None
    # the campaign's demand engine (user traffic + replica serving); None
    # for the default replication-only campaign
    demand: Optional[DemandEngine] = None
    # the campaign's scrub engine (silent corruption + re-verification +
    # repair); None for the default corruption-free campaign
    scrub: Optional[ScrubEngine] = None
    # the campaign's flight recorder (trace + metrics); None for the default
    # unobserved campaign.  Never snapshotted: a resumed campaign rebuilds
    # observability fresh, and the trajectory is identical either way.
    obs: Optional[object] = None

    @property
    def start_s(self) -> float:
        return self.start_day * DAY

    @property
    def deadline_s(self) -> float:
        """Absolute sim time at which this campaign times out."""
        return self.start_day * DAY + self.cfg.max_days * DAY

    def binding_catalog(self) -> Dict[str, Dataset]:
        """Every dataset a live transfer of this campaign may reference:
        the raw catalog plus any composed bundles — what the transport
        re-binds mover rows against on resume."""
        merged = dict(self.catalog)
        if self.control is not None and self.control.composer is not None:
            merged.update(self.control.composer.bundle_catalog)
        return merged


@dataclass
class ScenarioWorld:
    """A compiled, runnable scenario: the campaign wiring plus (optionally)
    an incremental-replication feed for mid-campaign top-ups.

    Structurally this is now a 1-element federation — ``shared`` +
    ``runtime`` are the primary objects and the flat fields alias into them —
    but the flat layout is kept as the single-campaign API."""
    spec: "ScenarioSpec"
    cfg: CampaignConfig
    graph: RouteGraph
    catalog: Dict[str, Dataset]
    clock: object
    pause: PauseManager
    transport: object
    table: object
    sched: object
    notifier: object
    incremental: Optional[IncrementalReplicator] = None
    top_up_times: Tuple[float, ...] = ()
    # build provenance, recorded so a campaign checkpoint can rebuild an
    # identical world (repro.core.snapshot)
    scale: float = 1.0
    seed: int = 0
    n_datasets: Optional[int] = None
    shared: Optional[SharedWorld] = None
    runtime: Optional[CampaignRuntime] = None

    @property
    def control(self) -> Optional[ControlPlane]:
        return self.runtime.control if self.runtime is not None else None

    @property
    def demand(self) -> Optional[DemandEngine]:
        return self.runtime.demand if self.runtime is not None else None

    @property
    def scrub(self) -> Optional[ScrubEngine]:
        return self.runtime.scrub if self.runtime is not None else None

    @property
    def obs(self):
        return self.runtime.obs if self.runtime is not None else None


@dataclass(frozen=True)
class ScenarioSpec:
    """A full declarative campaign scenario."""
    name: str
    description: str
    source: str
    replicas: Tuple[str, ...]
    sites: Tuple[SiteSpec, ...]
    routes: Tuple[RouteSpec, ...]
    outages: Tuple[OutageSpec, ...] = ()
    faults: FaultProfileSpec = FaultProfileSpec()
    catalog: CatalogSpec = CatalogSpec()
    top_ups: Tuple[TopUpSpec, ...] = ()
    human_fix_days: float = 3.0
    max_days: float = 200.0
    step_s: float = 1800.0                 # fixed-step engine cadence
    max_active_per_route: int = 2
    # control plane: bundling + online tuning.  The default (per-dataset
    # tasks, static caps) compiles to NO control plane and replays the
    # pre-control-plane trajectory bit-identically.
    policy: TransferPolicySpec = STATIC_POLICY
    # fixed dispatch cost per transfer task (Globus task setup/queueing);
    # the term bundling amortizes.  0.0 = the seed model.
    task_setup_s: float = 0.0
    # user-traffic demand over the replicated catalog ("ESGF-as-a-service").
    # The default (zero users) compiles to NO demand engine and replays the
    # replication-only trajectory bit-identically.
    demand: DemandSpec = NO_DEMAND
    # silent corruption + scrub/repair campaigns.  The default (zero latent
    # corruption) compiles to NO scrub engine and replays the corruption-free
    # trajectory bit-identically.
    scrub: ScrubSpec = NO_SCRUB
    # flight recorder (lifecycle trace + metrics time-series).  The default
    # (``NO_OBS``) compiles to NO engine and zero hooks; an enabled spec
    # observes without perturbing — trajectories and snapshots stay
    # bit-identical with obs on or off (CI-gated).
    obs: ObsSpec = NO_OBS
    # retention horizon (days) for the transport's per-(day, route) flow
    # telemetry; None keeps every bucket for the whole campaign
    flow_horizon_days: Optional[float] = None

    # ------------------------------------------------------------- compilers
    def to_campaign_config(self, scale: float = 1.0, seed: int = 0,
                           n_datasets: Optional[int] = None) -> CampaignConfig:
        return CampaignConfig(
            n_datasets=n_datasets if n_datasets is not None
            else self.catalog.n_datasets,
            total_bytes=self.catalog.total_bytes,
            total_files=self.catalog.total_files,
            source=self.source,
            replicas=tuple(self.replicas),
            step_s=self.step_s,
            max_days=self.max_days,
            seed=seed,
            unreadable_fraction=self.catalog.unreadable_fraction,
            human_fix_days=self.human_fix_days,
            scale=scale,
            task_setup_s=self.task_setup_s,
            flow_horizon_days=self.flow_horizon_days)

    def build_graph(self) -> RouteGraph:
        sites = [Site(s.name, read_bw=s.read_gbps * GB,
                      write_bw=s.write_gbps * GB,
                      scan_files_per_s=s.scan_files_per_s,
                      scan_mem_limit_files=s.scan_mem_limit_files,
                      concurrency_knee=s.concurrency_knee)
                 for s in self.sites]
        routes = [Route(r.source, r.destination, r.gbps * GB)
                  for r in self.routes]
        return RouteGraph(sites, routes)

    def build_pause(self) -> PauseManager:
        pause = PauseManager()
        for o in self.outages:
            start = o.start_day * DAY
            if o.weekly:
                until = (o.until_day if o.until_day is not None
                         else self.max_days) * DAY
                pause.add_weekly(o.site, start, o.duration_h * HOUR, until,
                                 planned=o.planned)
            else:
                pause.add_window(o.site, start, start + o.duration_h * HOUR,
                                 planned=o.planned)
        return pause

    def build_retry(self) -> RetryPolicy:
        return RetryPolicy(max_retries=self.faults.max_retries,
                           backoff_s=self.faults.backoff_s,
                           fault_retry_cost_s=self.faults.fault_retry_cost_s)

    def _attach_top_ups(self, runtime: CampaignRuntime, scale: float) -> None:
        """Compile the spec's top-up schedule into a publish feed wired to
        the runtime's scheduler."""
        if not self.top_ups:
            return
        feed = PublishFeed()
        times: List[float] = []
        for i, tu in enumerate(self.top_ups):
            t = tu.publish_day * DAY
            times.append(t)
            for j in range(tu.n_datasets):
                feed.publish(t, Dataset(
                    path=f"/css03_data/CMIP6/TOPUP/batch-{i}/ds-{j:04d}",
                    bytes=int(tu.bytes_each * scale) or tu.bytes_each,
                    files=tu.files_each,
                    directories=max(1, tu.files_each // 10)))
        runtime.incremental = IncrementalReplicator(feed, runtime.sched,
                                                    check_interval=DAY)
        runtime.top_up_times = tuple(times)

    def _compose_bundles(self, catalog: Dict[str, Dataset], seed: int,
                         fresh: bool,
                         namespace: Optional[str] = None
                         ) -> Optional[BundleComposer]:
        """The policy's bundle composer over ``catalog`` (None when the
        policy keeps per-dataset tasks).  ``fresh`` cuts the initial
        lookahead; a resume skips it — the restored cursor and already-cut
        bundles come from the snapshot instead.  ``namespace`` disambiguates
        bundle paths (federation members pass their unique label)."""
        pol = self.policy
        if not pol.enabled or pol.bundling == "dataset":
            return None
        if self.top_ups:
            raise ValueError(
                f"scenario {self.name!r}: bundling policies and incremental "
                "top-ups cannot be combined (the composer's item stream is "
                "fixed at build time)")
        composer = BundleComposer(catalog, pol, seed=seed,
                                  namespace=namespace or self.name)
        if fresh:
            while (not composer.done
                   and len(composer.bundle_catalog) < max(1, pol.lookahead)):
                composer.cut_next()
        return composer

    def _build_demand(self, catalog: Dict[str, Dataset], table, sched,
                      transport, seed: int, label: str
                      ) -> Optional[DemandEngine]:
        """The spec's demand engine over the built campaign (None when no
        users are declared).  Users request the *raw* catalog, so demand
        cannot be combined with bundling policies (bundle rows would
        materialize paths no user ever asks for)."""
        if not self.demand.enabled:
            return None
        if self.policy.enabled and self.policy.bundling != "dataset":
            raise ValueError(
                f"scenario {self.name!r}: demand traffic and bundling "
                "policies cannot be combined (the replica catalog tracks "
                "per-dataset rows, bundles materialize composite paths)")
        return DemandEngine(self.demand, catalog, table, sched, transport,
                            self.source, self.replicas, seed=seed,
                            label=label)

    def _build_scrub(self, catalog: Dict[str, Dataset], table, injector,
                     label: str) -> Optional[ScrubEngine]:
        """The spec's scrub engine over the built campaign (None when latent
        corruption is off).  Corruption draws key off raw dataset paths, so
        scrub cannot be combined with bundling policies (bundle rows would
        never map back to the per-dataset integrity ledger)."""
        if not self.scrub.enabled:
            return None
        if self.policy.enabled and self.policy.bundling != "dataset":
            raise ValueError(
                f"scenario {self.name!r}: scrub campaigns and bundling "
                "policies cannot be combined (the integrity ledger tracks "
                "per-dataset replicas, bundles materialize composite paths)")
        return ScrubEngine(self.scrub, catalog, table, injector,
                           self.source, self.replicas, label=label)

    def _build_obs(self, label: str):
        """The flight recorder, or None when the spec does not opt in —
        ``NO_OBS`` must compile to zero hooks (engine imported lazily so an
        unobserved build never touches the obs package)."""
        if not self.obs.enabled:
            return None
        from repro.obs.engine import Observability
        return Observability(self.obs, label=label)

    def build(self, scale: float = 1.0, seed: int = 0,
              n_datasets: Optional[int] = None, table=None) -> ScenarioWorld:
        """Compile the spec onto the campaign wiring, ready to run under
        either the fixed-step or the event-driven engine.  ``table`` accepts
        a restored ``TransferTable`` when resuming from a checkpoint."""
        self.policy.validate()
        self.demand.validate()
        self.scrub.validate()
        self.obs.validate()
        cfg = self.to_campaign_config(scale=scale, seed=seed,
                                      n_datasets=n_datasets)
        injector = FaultInjector(seed=seed,
                                 transient_per_tb=self.faults.transient_per_tb,
                                 fragility_tail=self.faults.fragility_tail)
        graph = self.build_graph()
        catalog = build_catalog(cfg, graph)
        composer = self._compose_bundles(catalog, seed, fresh=table is None)
        (graph, sched_catalog, clock, pause, transport, table, sched,
         notifier) = build_campaign(
            cfg, graph=graph, pause=self.build_pause(),
            injector=injector, retry=self.build_retry(),
            max_active_per_route=self.max_active_per_route, table=table,
            catalog=(composer.bundle_catalog if composer is not None
                     else catalog))
        control = None
        if self.policy.enabled:
            control = ControlPlane(self.policy, sched, transport,
                                   self.source, self.replicas,
                                   composer=composer, label=self.name)
        demand = self._build_demand(catalog, table, sched, transport,
                                    seed, label=self.name)
        scrub = self._build_scrub(catalog, table, injector, label=self.name)
        runtime = CampaignRuntime(self, cfg, catalog, table, sched, notifier,
                                  label=self.name, control=control,
                                  demand=demand, scrub=scrub)
        self._attach_top_ups(runtime, scale)
        shared = SharedWorld(graph, clock, pause, transport)
        obs = self._build_obs(label=self.name)
        if obs is not None:
            runtime.obs = obs
            obs.attach(runtime, shared)
        return ScenarioWorld(self, cfg, graph, catalog, clock, pause,
                             transport, table, sched, notifier,
                             incremental=runtime.incremental,
                             top_up_times=runtime.top_up_times,
                             scale=scale, seed=seed, n_datasets=n_datasets,
                             shared=shared, runtime=runtime)

    # --------------------------------------------------------------- helpers
    def vary(self, **changes) -> "ScenarioSpec":
        """A copy with top-level fields replaced (sweep convenience)."""
        return dataclasses.replace(self, **changes)

    def with_catalog(self, **changes) -> "ScenarioSpec":
        return dataclasses.replace(
            self, catalog=dataclasses.replace(self.catalog, **changes))

    def with_faults(self, **changes) -> "ScenarioSpec":
        return dataclasses.replace(
            self, faults=dataclasses.replace(self.faults, **changes))

    def with_policy(self, policy: Optional[TransferPolicySpec] = None,
                    **changes) -> "ScenarioSpec":
        """A copy with a different transfer policy: pass a whole
        ``TransferPolicySpec`` or field overrides on the current one.
        ``with_policy(STATIC_POLICY)`` is the naive per-dataset baseline."""
        base = policy if policy is not None else self.policy
        if changes:
            base = dataclasses.replace(base, **changes)
        return dataclasses.replace(self, policy=base)

    def with_demand(self, demand: Optional[DemandSpec] = None,
                    **changes) -> "ScenarioSpec":
        """A copy with a different demand (user-traffic) spec: pass a whole
        ``DemandSpec`` or field overrides on the current one.
        ``with_demand(NO_DEMAND)`` is the replication-only baseline."""
        base = demand if demand is not None else self.demand
        if changes:
            base = dataclasses.replace(base, **changes)
        return dataclasses.replace(self, demand=base)

    def with_scrub(self, scrub: Optional[ScrubSpec] = None,
                   **changes) -> "ScenarioSpec":
        """A copy with a different scrub (silent-corruption) spec: pass a
        whole ``ScrubSpec`` or field overrides on the current one.
        ``with_scrub(NO_SCRUB)`` is the corruption-free baseline."""
        base = scrub if scrub is not None else self.scrub
        if changes:
            base = dataclasses.replace(base, **changes)
        return dataclasses.replace(self, scrub=base)

    def with_obs(self, obs: Optional[ObsSpec] = None,
                 **changes) -> "ScenarioSpec":
        """A copy with a different observability spec: pass a whole
        ``ObsSpec`` or field overrides on the current one.
        ``with_obs(NO_OBS)`` is the unobserved baseline."""
        base = obs if obs is not None else self.obs
        if changes:
            base = dataclasses.replace(base, **changes)
        return dataclasses.replace(self, obs=base)


# ================================================================ federation
@dataclass(frozen=True)
class FederationMemberSpec:
    """One campaign of a federation: a full ``ScenarioSpec`` plus the day it
    starts (staggered starts model overlapping real-world campaigns)."""
    scenario: ScenarioSpec
    start_day: float = 0.0
    label: Optional[str] = None


@dataclass
class FederationWorld:
    """N compiled campaign runtimes attached to one shared substrate.  Built
    by ``FederationSpec.build``; driven by ``repro.scenarios.events.run_world``
    (which folds every runtime's next-event candidates into one clock
    advance); checkpointed as a ``repro.core.snapshot.FederationSnapshot``."""
    spec: "FederationSpec"
    shared: SharedWorld
    runtimes: List[CampaignRuntime]
    scale: float = 1.0
    seed: int = 0
    n_datasets: Optional[int] = None

    # convenience passthroughs (CLI / dashboard / tests)
    @property
    def clock(self):
        return self.shared.clock

    @property
    def transport(self):
        return self.shared.transport

    @property
    def graph(self):
        return self.shared.graph

    @property
    def pause(self):
        return self.shared.pause

    def runtime_by_label(self, label: str) -> CampaignRuntime:
        for rt in self.runtimes:
            if rt.label == label:
                return rt
        raise KeyError(label)

    def merged_catalog(self) -> Dict[str, Dataset]:
        """Union of member catalogs plus every member's composed bundles
        (bundle paths are namespaced per member, so they never collide;
        shared raw-path collisions were validated identical at build time)
        — the transport's dataset re-binding map on resume."""
        merged: Dict[str, Dataset] = {}
        for rt in self.runtimes:
            merged.update(rt.binding_catalog())
        return merged


@dataclass(frozen=True)
class FederationSpec:
    """N declarative campaigns sharing one simulated world.

    Compiles to a ``FederationWorld``: one clock / route graph / maintenance
    calendar / ``SimulatedTransport`` (one fault-RNG stream), with a private
    ``CampaignRuntime`` (table + scheduler + notifier + feed) per member.
    Concurrent members contend naturally through the transport's fair-share
    allocator — a member route's achievable rate shrinks whenever another
    member's movers touch the same site, which is exactly the paper's regime
    of two overlapping campaigns reading one ~1.5 GB/s source file system.

    ``shared_sites`` declares which sites are intentionally shared: every
    site named by more than one member must be listed here, and all members
    must describe it (and any shared route) with identical capabilities.
    A 1-element federation is the degenerate case and runs bit-identically
    to the member scenario built standalone.
    """
    name: str
    description: str
    members: Tuple[FederationMemberSpec, ...]
    shared_sites: Tuple[str, ...] = ()
    # when set, every member campaign runs under THIS transfer policy
    # (each member still gets its own control plane, tuning its own
    # scheduler's caps against the shared transport's telemetry)
    policy: Optional[TransferPolicySpec] = None

    # --------------------------------------------------------------- helpers
    def with_policy(self, policy: TransferPolicySpec) -> "FederationSpec":
        """A copy running every member under ``policy``."""
        return dataclasses.replace(self, policy=policy)

    def with_obs(self, obs: ObsSpec) -> "FederationSpec":
        """A copy with every member campaign observed under ``obs`` (each
        member gets its own flight recorder; one shared sink tells their
        streams apart by the per-record ``campaign`` label)."""
        members = tuple(
            dataclasses.replace(m, scenario=m.scenario.with_obs(obs))
            for m in self.members)
        return dataclasses.replace(self, members=members)

    def member_labels(self) -> List[str]:
        labels = []
        for i, m in enumerate(self.members):
            label = m.label or m.scenario.name
            if label in labels:
                label = f"{label}#{i}"
            labels.append(label)
        return labels

    def _validate(self) -> None:
        if not self.members:
            raise ValueError(f"federation {self.name!r} has no members")
        site_owner: Dict[str, Tuple[SiteSpec, str]] = {}
        route_owner: Dict[Tuple[str, str], Tuple[RouteSpec, str]] = {}
        faults = self.members[0].scenario.faults
        setup = self.members[0].scenario.task_setup_s
        horizon = self.members[0].scenario.flow_horizon_days
        for m in self.members:
            spec = m.scenario
            if spec.faults != faults:
                raise ValueError(
                    f"federation {self.name!r}: member {spec.name!r} declares "
                    "a different fault/retry profile; the shared transport "
                    "has one fault injector and one in-transfer retry cost")
            if spec.task_setup_s != setup:
                raise ValueError(
                    f"federation {self.name!r}: member {spec.name!r} declares "
                    f"task_setup_s={spec.task_setup_s}, the shared transport "
                    f"has one task dispatch cost ({setup})")
            if spec.flow_horizon_days != horizon:
                raise ValueError(
                    f"federation {self.name!r}: member {spec.name!r} declares "
                    f"flow_horizon_days={spec.flow_horizon_days}, the shared "
                    f"transport has one telemetry horizon ({horizon})")
            for s in spec.sites:
                seen = site_owner.get(s.name)
                if seen is None:
                    site_owner[s.name] = (s, spec.name)
                    continue
                if seen[0] != s:
                    raise ValueError(
                        f"federation {self.name!r}: site {s.name!r} declared "
                        f"with different capabilities by {seen[1]!r} and "
                        f"{spec.name!r}")
                if s.name not in self.shared_sites:
                    raise ValueError(
                        f"federation {self.name!r}: site {s.name!r} is used "
                        f"by {seen[1]!r} and {spec.name!r} but not declared "
                        "in shared_sites")
            for r in spec.routes:
                key = (r.source, r.destination)
                seen = route_owner.get(key)
                if seen is None:
                    route_owner[key] = (r, spec.name)
                elif seen[0] != r:
                    raise ValueError(
                        f"federation {self.name!r}: route {key} declared "
                        f"with different bandwidth by {seen[1]!r} and "
                        f"{spec.name!r}")

    def build_graph(self) -> RouteGraph:
        """Union of the member topologies (validated consistent)."""
        sites: Dict[str, Site] = {}
        routes: Dict[Tuple[str, str], Route] = {}
        for m in self.members:
            g = m.scenario.build_graph()
            sites.update(g.sites)
            routes.update(g.routes)
        return RouteGraph(list(sites.values()), list(routes.values()))

    def build_pause(self) -> PauseManager:
        """Union maintenance calendar: identical outage declarations from
        several members collapse to one window (site maintenance is a fact
        about the site, not about who is transferring)."""
        pause = PauseManager()
        seen = set()
        for m in self.members:
            for o in m.scenario.outages:
                key = (o.site, o.start_day, o.duration_h, o.weekly,
                       o.until_day, o.planned, m.scenario.max_days)
                if key in seen:
                    continue
                seen.add(key)
                start = o.start_day * DAY
                if o.weekly:
                    until = (o.until_day if o.until_day is not None
                             else m.scenario.max_days) * DAY
                    pause.add_weekly(o.site, start, o.duration_h * HOUR,
                                     until, planned=o.planned)
                else:
                    pause.add_window(o.site, start,
                                     start + o.duration_h * HOUR,
                                     planned=o.planned)
        return pause

    # ----------------------------------------------------------------- build
    def build(self, scale: float = 1.0, seed: int = 0,
              n_datasets: Optional[int] = None,
              tables: Optional[List] = None) -> FederationWorld:
        """Compile every member onto one shared substrate.  ``tables``
        accepts restored per-member ``TransferTable``s (checkpoint resume),
        in member order."""
        self._validate()
        if tables is not None and len(tables) != len(self.members):
            raise ValueError(
                f"federation {self.name!r}: {len(tables)} restored tables "
                f"for {len(self.members)} members")
        graph = self.build_graph()
        pause = self.build_pause()
        base = self.members[0].scenario
        injector = FaultInjector(
            seed=seed,
            transient_per_tb=base.faults.transient_per_tb,
            fragility_tail=base.faults.fragility_tail)
        fed_notifier = FederationNotifier()
        transport = SimulatedTransport(graph, SimClock(0.0), pause, injector,
                                       fed_notifier, base.build_retry(),
                                       task_setup_s=base.task_setup_s,
                                       flow_horizon_days=base.flow_horizon_days)
        shared = SharedWorld(graph, transport.clock, pause, transport)
        runtimes: List[CampaignRuntime] = []
        merged: Dict[str, Dataset] = {}
        labels = self.member_labels()
        for i, m in enumerate(self.members):
            spec = m.scenario
            if self.policy is not None:
                spec = spec.with_policy(self.policy)
            spec.policy.validate()
            spec.demand.validate()
            spec.scrub.validate()
            spec.obs.validate()
            cfg = spec.to_campaign_config(scale=scale, seed=seed,
                                          n_datasets=n_datasets)
            notifier = Notifier()
            member_table = tables[i] if tables is not None else None
            catalog = build_catalog(cfg, graph)
            composer = spec._compose_bundles(catalog, seed,
                                             fresh=member_table is None,
                                             namespace=labels[i])
            (_, _, _, _, _, table, sched, _) = build_campaign(
                cfg, graph=graph, retry=spec.build_retry(),
                max_active_per_route=spec.max_active_per_route,
                table=member_table,
                transport=transport, notifier=notifier,
                catalog=(composer.bundle_catalog if composer is not None
                         else catalog))
            control = None
            if spec.policy.enabled:
                control = ControlPlane(spec.policy, sched, transport,
                                       spec.source, spec.replicas,
                                       composer=composer, label=labels[i])
            for path, ds in catalog.items():
                other = merged.get(path)
                if other is None:
                    merged[path] = ds
                elif (other.bytes, other.files, other.directories,
                      other.unreadable) != (ds.bytes, ds.files,
                                            ds.directories, ds.unreadable):
                    raise ValueError(
                        f"federation {self.name!r}: dataset {path!r} differs "
                        "between members — shared paths must describe the "
                        "same data")
            demand = spec._build_demand(catalog, table, sched, transport,
                                        seed, label=labels[i])
            scrub = spec._build_scrub(catalog, table, injector,
                                      label=labels[i])
            rt = CampaignRuntime(spec, cfg, catalog, table, sched, notifier,
                                 label=labels[i], start_day=m.start_day,
                                 control=control, demand=demand, scrub=scrub)
            # route transport notifications (scan OOM, permission halts) by
            # everything this member may have in flight — bundles included.
            # ChainMap is a LIVE view: bundles cut mid-campaign route too.
            route_map = (ChainMap(catalog, composer.bundle_catalog)
                         if composer is not None else catalog)
            fed_notifier.attach(route_map, notifier)
            spec._attach_top_ups(rt, scale)
            obs = spec._build_obs(label=labels[i])
            if obs is not None:
                rt.obs = obs
                obs.attach(rt, shared)
            runtimes.append(rt)
        return FederationWorld(self, shared, runtimes, scale=scale,
                               seed=seed, n_datasets=n_datasets)
