"""Serving launcher: load (or init) a model and serve a batch of requests.

    python -m repro.launch.serve --arch falcon-mamba-7b --requests 8
        [--ckpt-dir DIR] [--max-new 16] [--max-batch 4] [--max-seq 256]

Loads the latest verified checkpoint when ``--ckpt-dir`` is given (falling
back to random init), then drives the wave-batched engine.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.model import LM
from repro.serve.engine import Engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = LM(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        from repro.checkpoint.ckpt import restore_checkpoint
        got = restore_checkpoint(args.ckpt_dir, {"params": params})
        if got is not None:
            step, tree, d = got
            params = tree["params"]
            print(f"loaded checkpoint step {step} from {d}")

    eng = Engine(cfg, params, max_batch=args.max_batch, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        if cfg.n_codebooks > 1:
            prompt = rng.integers(0, cfg.vocab_size, (plen, cfg.n_codebooks))
        else:
            prompt = rng.integers(0, cfg.vocab_size, plen)
        eng.submit(prompt, max_new_tokens=args.max_new)
    done = eng.run_to_completion()
    wall = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {wall:.1f}s "
          f"({eng.waves} waves, {toks/max(wall,1e-9):.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
