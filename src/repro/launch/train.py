"""Training launcher.

    python -m repro.launch.train --arch smollm-135m --steps 200
        [--smoke/--full] [--batch 8] [--seq 128] [--ckpt-dir DIR]
        [--replicate-to POD1 STORE] [--resume] [--microbatches N]

On a real cluster this process runs per host under the usual multi-controller
launch (jax.distributed.initialize); here it drives the same fault-tolerant
loop on local devices.  ``--replicate-to`` turns on cross-site checkpoint
replication via the paper's scheduler (sites are sibling directories of the
checkpoint root).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.checkpoint.replicate import CheckpointReplicator
from repro.configs import ARCH_IDS, get_config
from repro.train.loop import TrainConfig, train


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced same-family config (default on CPU)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="the real architecture config (accelerators)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--replicate-to", nargs="*", default=None,
                    help="site names to replicate checkpoints to")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    replicator = None
    ckpt_dir = args.ckpt_dir
    if args.replicate_to and ckpt_dir:
        root = os.path.dirname(os.path.abspath(ckpt_dir))
        primary = os.path.basename(os.path.abspath(ckpt_dir))
        replicator = CheckpointReplicator(
            root, primary=primary, replicas=tuple(args.replicate_to))
        ckpt_dir = os.path.join(replicator.site_dir(primary), "ckpts")

    tc = TrainConfig(steps=args.steps, batch_size=args.batch,
                     seq_len=args.seq, microbatches=args.microbatches,
                     peak_lr=args.lr, ckpt_every=args.ckpt_every,
                     ckpt_dir=ckpt_dir, replicator=replicator,
                     seed=args.seed, remat=args.remat)
    res = train(cfg, tc)
    print(f"done: arch={cfg.name} steps={res.final_step} "
          f"restarts={res.restarts} "
          f"loss {res.losses[0]:.4f}->{res.losses[-1]:.4f} "
          f"wall={res.wall_s:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
