"""Sharding rules: parameter specs, optimizer-state specs, cache specs, and
input specs for every (arch × shape × mesh) combination.

Strategy (baseline — EXPERIMENTS.md §Perf iterates from here):
  * TP on "model": attention projections, FFN hidden, experts (EP), vocab.
  * DP on ("pod","data"): batch.  Cross-pod is pure DP (grad all-reduce over
    the slow axis — where grad compression applies).
  * FSDP/ZeRO on "data": parameters of ≥3B models are sharded over "data" on
    their non-TP dimension; optimizer moments always are (ZeRO-1).
  * KV caches: batch over ("pod","data"); kv-head dim over "model" when
    divisible, else the sequence dim over "model" (sequence-parallel cache).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, param_count

PyTree = Any

FSDP_THRESHOLD = 3e9


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


# -------------------------------------------------------------- param specs
# (regex on the path suffix, spec builder taking (ndim, fsdp_axis))
def _mat(in_ax, out_ax):
    """Spec for a (..., in, out) matrix; leading dims are stacked layers."""
    def build(ndim, fsdp):
        lead = (None,) * (ndim - 2)
        ia = fsdp if in_ax == "fsdp" else in_ax
        oa = fsdp if out_ax == "fsdp" else out_ax
        return P(*lead, ia, oa)
    return build


def _vec(ax):
    def build(ndim, fsdp):
        lead = (None,) * (ndim - 1)
        return P(*lead, ax)
    return build


def _moe_expert(in_ax, out_ax):
    """(..., E, in, out): experts over 'model' (EP)."""
    def build(ndim, fsdp):
        lead = (None,) * (ndim - 3)
        ia = fsdp if in_ax == "fsdp" else in_ax
        oa = fsdp if out_ax == "fsdp" else out_ax
        return P(*lead, "model", ia, oa)
    return build


_PARAM_RULES = [
    (r"embed$", lambda nd, f: P(*((None,) * (nd - 2)), "model", None)),
    (r"lm_head$", lambda nd, f: P(*((None,) * (nd - 2)), None, "model")),
    (r"attn/wq$", _mat("fsdp", "model")),
    (r"attn/wk$", _mat("fsdp", "model")),
    (r"attn/wv$", _mat("fsdp", "model")),
    (r"attn/wo$", _mat("model", "fsdp")),
    (r"attn/w_dkv$", _mat("fsdp", None)),
    (r"attn/w_krope$", _mat("fsdp", None)),
    (r"attn/w_uk$", _mat(None, "model")),
    (r"attn/w_uv$", _mat(None, "model")),
    (r"(mlp|shared)/w_gate$", _mat("fsdp", "model")),
    (r"(mlp|shared)/w_up$", _mat("fsdp", "model")),
    (r"(mlp|shared)/w_down$", _mat("model", "fsdp")),
    (r"moe/router$", _mat(None, None)),
    (r"moe/w_gate$", _moe_expert("fsdp", None)),
    (r"moe/w_up$", _moe_expert("fsdp", None)),
    (r"moe/w_down$", _moe_expert(None, "fsdp")),
    (r"ssm/in_[xz]$", _mat("fsdp", "model")),
    (r"ssm/in_[BC]$", _mat("fsdp", None)),
    (r"ssm/in_dt$", _mat("fsdp", None)),
    (r"ssm/x_proj$", _mat("model", None)),
    (r"ssm/dt_proj$", _mat(None, "model")),
    (r"ssm/out_proj$", _mat("model", "fsdp")),
    (r"ssm/A_log$", lambda nd, f: P(*((None,) * (nd - 2)), "model", None)
        if nd >= 2 else P(*((None,) * (nd - 1)), None)),
    (r"ssm/conv_x_w$", lambda nd, f: P(*((None,) * (nd - 1)), "model")),
    (r"ssm/conv_x_b$", _vec("model")),
    (r"ssm/(conv_[BC]_[wb]|conv_w|conv_b|dt_bias|D)$",
     lambda nd, f: P(*((None,) * nd))),
    (r"(scale|norm/scale|ln\d?/scale|.*norm.*)$", lambda nd, f: P(*((None,) * nd))),
]


def param_specs(shapes: PyTree, cfg: ModelConfig, mesh: Mesh) -> PyTree:
    """PartitionSpec tree matching the param tree (shapes = eval_shape out)."""
    total, _ = param_count(cfg)
    fsdp = "data" if total >= FSDP_THRESHOLD else None
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1)

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, builder in _PARAM_RULES:
            if re.search(pat, ps):
                spec = builder(leaf.ndim, fsdp)
                return _fix_divisibility(spec, leaf.shape, mesh)
        return P(*((None,) * leaf.ndim))   # default: replicate

    return jax.tree_util.tree_map_with_path(assign, shapes)


def _fix_divisibility(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis assignments whose mesh size does not divide the dim."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def opt_state_specs(param_spec_tree: PyTree, shapes: PyTree, mesh: Mesh,
                    params_shapes: PyTree) -> Dict[str, PyTree]:
    """ZeRO-1: master/m/v follow the param spec, with 'data' added on the
    first unsharded divisible dim when the param itself is not data-sharded."""
    dp = mesh.shape.get("data", 1)

    def zero1(spec, shape_leaf):
        spec_t = tuple(spec) + (None,) * (shape_leaf.ndim - len(tuple(spec)))
        used = set()
        for ax in spec_t:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                used.add(a)
        if "data" in used:
            return P(*spec_t)
        out = list(spec_t)
        for i, (dim, ax) in enumerate(zip(shape_leaf.shape, spec_t)):
            if ax is None and dim % dp == 0 and dim >= dp:
                out[i] = "data"
                break
        return P(*out)

    moment_spec = jax.tree_util.tree_map(
        zero1, param_spec_tree, params_shapes,
        is_leaf=lambda x: isinstance(x, P))
    return moment_spec


# --------------------------------------------------------------- cache specs
def cache_specs(cache_shapes: PyTree, batch: int, seq: int, mesh: Mesh,
                batch_ax) -> PyTree:
    """Shape-driven assignment: batch dim -> batch_ax; then shard heads over
    'model' if divisible, else the sequence dim over 'model'."""
    tp = mesh.shape.get("model", 1)

    def bsz(ax):
        if ax is None:
            return 1
        axes = ax if isinstance(ax, tuple) else (ax,)
        return int(np.prod([mesh.shape[a] for a in axes]))

    def assign(path, leaf):
        dims = list(leaf.shape)
        spec = [None] * leaf.ndim
        # batch: first dim equal to `batch` after the leading stack dims
        b_idx = None
        for i, d in enumerate(dims):
            if d == batch and i <= 2:
                b_idx = i
                break
        if b_idx is not None and batch_ax is not None \
                and batch % bsz(batch_ax) == 0:
            spec[b_idx] = batch_ax
        # model axis: prefer a head-like dim (divisible, not batch/seq),
        # searching from the last dim backwards; else the seq dim
        s_idx = None
        for i, d in enumerate(dims):
            if d == seq and i != b_idx:
                s_idx = i
                break
        for i in range(leaf.ndim - 1, -1, -1):
            if i in (b_idx, s_idx):
                continue
            if dims[i] % tp == 0 and dims[i] >= tp:
                spec[i] = "model"
                break
        else:
            if s_idx is not None and dims[s_idx] % tp == 0:
                spec[s_idx] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)


# --------------------------------------------------------------- input specs
def batch_axis(mesh: Mesh, global_batch: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not axes:
        return None
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if global_batch % size == 0:
        return axes if len(axes) > 1 else axes[0]
    # try data only
    if "data" in mesh.shape and global_batch % mesh.shape["data"] == 0:
        return "data"
    return None


def logical_rules(mesh: Mesh, global_batch: int,
                  cfg: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """Logical-axis rules.  Head sharding is enabled only when the KV-head
    count divides the TP axis (otherwise the (Hkv, g) reshape would misalign
    shard boundaries and GSPMD would gather); the ff / ssm-channel / expert
    constraints are divisibility-guarded per-tensor in axes.constrain."""
    tp = mesh.shape.get("model", 1)
    heads_ok = cfg is not None and (
        (cfg.mla is not None and cfg.n_heads % tp == 0)
        or (cfg.mla is None and cfg.n_kv_heads > 0
            and cfg.n_kv_heads % tp == 0))
    rules = {
        "batch": batch_axis(mesh, global_batch),
        "seq": None,
        "vocab": "model",
        "expert": "model",
        "ff": "model",
        "heads": "model" if heads_ok else None,
        "kv": "model" if heads_ok else None,
        "ssm_ch": "model",
        "ssm_heads": "model",
    }
    import os
    if os.environ.get("REPRO_NO_CONSTRAIN") == "1":   # §Perf baseline replay
        for k in ("ff", "heads", "kv", "ssm_ch", "ssm_heads"):
            rules[k] = None
    return rules
