"""Analytic FLOP/byte model of the *lowered* program.

Why this exists: XLA-CPU ``compiled.cost_analysis()`` counts each ``while``
body ONCE, so any scan-over-layers program is undercounted by ~L×.  (Verified
empirically; see EXPERIMENTS.md §Roofline "accounting".)  This module mirrors
the exact computation our model code lowers — including deliberate baseline
inefficiencies that the perf loop then attacks:

  * chunked attention computes full-S scores per query chunk (no causal block
    skipping) -> attention MACs = T×S, not T×S/2;
  * score tensors round-trip HBM (logits + softmax weights materialize, 2×
    f32 passes) — the Pallas flash kernel keeps them in VMEM on real TPU;
  * full per-layer remat in training recomputes the forward during backward;
  * attention chunks are additionally rematted (one extra attention forward).

All numbers are GLOBAL (whole step, all chips); the roofline divides by chip
count and peak rates.  MACs are converted to FLOPs with the ×2 convention
(matches XLA's dot accounting, verified).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.models.config import ModelConfig
from repro.models.moe import moe_capacity


@dataclass
class Cost:
    flops: float = 0.0          # total FLOPs
    hbm_bytes: float = 0.0      # total HBM bytes moved

    def __iadd__(self, other):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        return self


def _attn_flops_per_layer(cfg: ModelConfig, tokens: int, S: int,
                          decode: bool) -> float:
    """QKVO projections + scores/PV for one attention layer (fwd, FLOPs)."""
    d, hd = cfg.d_model, cfg.head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    if cfg.mla is not None:
        m = cfg.mla
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = (d * H * qk_hd            # W_q
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)   # W_dkv + rope k
                + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                + H * m.v_head_dim * d)  # W_o
        score = S * H * (qk_hd + m.v_head_dim)       # per query token
        return 2.0 * tokens * (proj + score)
    proj = d * hd * (2 * H + 2 * K)
    score = S * H * hd * 2                            # QK^T + PV per query
    return 2.0 * tokens * (proj + score)


def _mlp_flops_per_layer(d: int, ff: int, tokens: int) -> float:
    return 2.0 * tokens * 3 * d * ff


def _moe_flops_per_layer(cfg: ModelConfig, tokens: int) -> float:
    m = cfg.moe
    d = cfg.d_model
    C = moe_capacity(m, tokens)
    routed = 2.0 * m.n_routed * C * 3 * d * m.d_ff_expert
    shared = 2.0 * tokens * 3 * d * (m.n_shared * m.d_ff_expert)
    router = 2.0 * tokens * d * m.n_routed
    return routed + shared + router


def _ssm_flops_per_layer(cfg: ModelConfig, tokens: int, decode: bool) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    if s.version == 1:
        dtr = max(1, d // 16)
        proj = 2 * d * 2 * d_in + 2 * d_in * (dtr + 2 * s.d_state) \
            + 2 * dtr * d_in + 2 * d_in * d
        scan = 8.0 * d_in * s.d_state        # exp + 2 mul + add per (ch, state)
        conv = 2.0 * s.d_conv * d_in
        return tokens * (proj + scan + conv)
    H = d_in // s.headdim
    P, G, N = s.headdim, s.n_groups, s.d_state
    proj = 2 * d * (2 * d_in + 2 * G * N + H) + 2 * d_in * d
    conv = 2.0 * s.d_conv * (d_in + 2 * G * N)
    if decode:
        ssd = 6.0 * H * P * N                 # single-step state update
    else:
        Lc = s.chunk
        # per token: CB^T row (Lc*N per head) + M·x (Lc*P) + state in/out (2NP)
        ssd = 2.0 * H * (Lc * N + Lc * P + 2 * N * P)
    return tokens * (proj + conv + ssd)


def _head_flops(cfg: ModelConfig, tokens: int) -> float:
    return 2.0 * tokens * cfg.d_model * cfg.vocab_size * cfg.n_codebooks


def analytic_cost(cfg: ModelConfig, global_batch: int, seq_len: int,
                  mode: str) -> Dict[str, float]:
    """Returns global flops/bytes for one step of the given mode."""
    from repro.models.config import param_count
    total_p, active_p = param_count(cfg)
    decode = mode == "decode"
    tokens = global_batch * (1 if decode else seq_len)
    S = seq_len                       # context length (cache len for decode)

    kinds = cfg.layer_kinds()
    fwd = Cost()
    attn_fwd = 0.0
    for i, k in enumerate(kinds):
        if k in ("attn", "local"):
            eff_S = min(cfg.sliding_window, S) if (
                k == "local" and cfg.sliding_window) else S
            f = _attn_flops_per_layer(cfg, tokens, eff_S, decode)
            attn_fwd += f
            fwd.flops += f
            if cfg.moe is not None and i >= cfg.moe.first_dense_layers:
                fwd.flops += _moe_flops_per_layer(cfg, tokens)
            elif cfg.moe is not None:
                fwd.flops += _mlp_flops_per_layer(cfg.d_model,
                                                  cfg.moe.d_ff_dense, tokens)
            else:
                fwd.flops += _mlp_flops_per_layer(cfg.d_model, cfg.d_ff, tokens)
        elif k == "ssm":
            fwd.flops += _ssm_flops_per_layer(cfg, tokens, decode)
    if cfg.hybrid is not None:
        n_sites = cfg.n_layers // cfg.hybrid.shared_attn_every
        f = _attn_flops_per_layer(cfg, tokens, S, decode) * n_sites
        attn_fwd += f
        fwd.flops += f
        fwd.flops += _mlp_flops_per_layer(cfg.d_model, cfg.d_ff, tokens) * n_sites
    fwd.flops += _head_flops(cfg, tokens)

    # ----- bytes, forward ----------------------------------------------------
    dtype_b = 2                      # bf16 params/activations
    n_layer_passes = len(kinds) + (0 if cfg.hybrid is None else
                                   cfg.n_layers // cfg.hybrid.shared_attn_every)
    act_pass = 12.0 * tokens * cfg.d_model * dtype_b      # r/w per layer
    fwd.hbm_bytes += total_p * dtype_b                     # weights read once
    fwd.hbm_bytes += n_layer_passes * act_pass
    # baseline score materialization (logits + weights, f32, r+w each)
    if not decode:
        score_elems = 0.0
        for k in kinds:
            if k in ("attn", "local") and cfg.n_heads:
                eff_S = min(cfg.sliding_window, S) if (
                    k == "local" and cfg.sliding_window) else S
                score_elems += float(tokens) * eff_S * cfg.n_heads
        if cfg.hybrid is not None:
            score_elems += (float(tokens) * S * cfg.n_heads
                            * (cfg.n_layers // cfg.hybrid.shared_attn_every))
        fwd.hbm_bytes += score_elems * 4.0 * 4.0   # logits w + r, weights w + r
    if decode:
        fwd.hbm_bytes += _cache_bytes(cfg, global_batch, S)  # read full cache
    fwd.hbm_bytes += tokens * cfg.vocab_size * cfg.n_codebooks * dtype_b  # logits

    out = {"fwd_flops": fwd.flops, "attn_fwd_flops": attn_fwd,
           "fwd_bytes": fwd.hbm_bytes}
    if mode == "train":
        # bwd = 2×fwd; full per-layer remat = +1×fwd; chunked-attention extra
        # remat = +1×attention-fwd; optimizer ~10 flops/param
        flops = 4.0 * fwd.flops + attn_fwd + 10.0 * total_p
        bytes_ = 3.0 * fwd.hbm_bytes            # fwd + remat-fwd + bwd traffic
        bytes_ += total_p * (4 + 4 + 4) * 2     # master/m/v f32 read+write
        bytes_ += total_p * dtype_b * 2         # grads + new bf16 params
        out.update({"flops": flops, "bytes": bytes_})
    else:
        out.update({"flops": fwd.flops, "bytes": fwd.hbm_bytes})
    out["model_flops"] = 6.0 * active_p * tokens if mode == "train" \
        else 2.0 * active_p * tokens
    return out


def _cache_bytes(cfg: ModelConfig, batch: int, S: int) -> float:
    """Bytes of KV/SSM state read per decode step (global)."""
    kinds = cfg.layer_kinds()
    total = 0.0
    for k in kinds:
        if k in ("attn", "local"):
            eff_S = min(cfg.sliding_window, S) if (
                k == "local" and cfg.sliding_window) else S
            if cfg.mla is not None:
                m = cfg.mla
                total += batch * eff_S * (m.kv_lora_rank
                                          + m.qk_rope_head_dim) * 2
            else:
                total += batch * eff_S * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        elif k == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            total += batch * d_in * s.d_state * 4
    if cfg.hybrid is not None:
        n_sites = cfg.n_layers // cfg.hybrid.shared_attn_every
        total += n_sites * batch * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    return total
