import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production meshes and extract roofline inputs from the compiled artifact.

For each cell this produces a JSON record with:
  * ``memory``      — per-device argument/output/temp bytes (fits-on-chip proof)
  * ``cost``        — per-device HLO FLOPs and bytes accessed
  * ``collectives`` — per-type op counts and per-device wire bytes parsed from
                      the post-SPMD optimized HLO
  * timings for lower/compile.

Run one cell:   python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
Run everything: python -m repro.launch.dryrun --all   (subprocess per cell)
Results land in experiments/dryrun/*.json (read by benchmarks/roofline.py).
"""
import argparse
import json
import re
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch import shardings as SH
from repro.models.axes import logical_axis_rules
from repro.models.config import ModelConfig, param_count
from repro.models.model import LM
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# --------------------------------------------------------------- input specs
def sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def train_inputs(cfg: ModelConfig, B: int, T: int, mesh: Mesh, bax):
    batch: Dict[str, Any] = {}
    if cfg.n_codebooks > 1:
        batch["tokens"] = sds((B, T, cfg.n_codebooks), jnp.int32, mesh, P(bax))
        batch["labels"] = sds((B, T, cfg.n_codebooks), jnp.int32, mesh, P(bax))
    elif not cfg.embed_inputs:
        batch["embeds"] = sds((B, T, cfg.d_model), jnp.bfloat16, mesh, P(bax))
        batch["labels"] = sds((B, T), jnp.int32, mesh, P(bax))
    else:
        batch["tokens"] = sds((B, T), jnp.int32, mesh, P(bax))
        batch["labels"] = sds((B, T), jnp.int32, mesh, P(bax))
    if cfg.mrope:
        batch["positions3"] = sds((3, B, T), jnp.int32, mesh, P(None, bax))
    return batch


def abstract_params(model: LM, mesh: Mesh):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = SH.param_specs(shapes, model.cfg, mesh)
    tree = jax.tree_util.tree_map(
        lambda s, sp: sds(s.shape, s.dtype, mesh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return tree, specs, shapes


def abstract_opt_state(params_shapes, param_specs, mesh: Mesh):
    opt_shapes = jax.eval_shape(adamw.init, params_shapes)
    mom_specs = SH.opt_state_specs(param_specs, None, mesh, params_shapes)
    def mk(tree):
        return jax.tree_util.tree_map(
            lambda s, sp: sds(s.shape, s.dtype, mesh, sp), tree, mom_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return adamw.AdamWState(
        step=sds((), jnp.int32, mesh, P()),
        master=mk(opt_shapes.master), m=mk(opt_shapes.m), v=mk(opt_shapes.v))


def abstract_cache(model: LM, B: int, S: int, mesh: Mesh, bax):
    shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    specs = SH.cache_specs(shapes, B, S, mesh, bax)
    tree = jax.tree_util.tree_map(
        lambda s, sp: sds(s.shape, s.dtype, mesh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return tree


# ------------------------------------------------------------- step builders
def build_train_step(model: LM, microbatches: int = 1, mesh: Optional[Mesh] = None,
                     pspecs=None, hoist_fsdp: bool = False):
    """Gradient-accumulation train step: fwd+bwd per microbatch inside a scan
    (bounds live activations), one optimizer update per step.

    hoist_fsdp: gather FSDP-sharded weights ONCE per step (outside the
    microbatch loop) and reduce-scatter gradients back to the sharded layout
    per microbatch.  Without this, XLA re-gathers every weight in every
    microbatch's forward, remat-forward, and backward — measured 13.2 TB/chip
    of all-gather for gemma3-27b train_4k (§Perf cell A iteration 2)."""
    grad_fn = jax.value_and_grad(
        lambda p, b: model.loss_fn(p, b)[0])

    def _drop_data(spec: P) -> P:
        out = []
        for ax in tuple(spec):
            if ax == "data":
                out.append(None)
            elif isinstance(ax, tuple):
                kept = tuple(a for a in ax if a != "data")
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                out.append(ax)
        return P(*out)

    def train_step(params, opt_state, batch):
        use_params = params
        if hoist_fsdp and mesh is not None and pspecs is not None:
            use_params = jax.tree_util.tree_map(
                lambda x, sp: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, _drop_data(sp))),
                params, pspecs, is_leaf=lambda s: isinstance(s, P))

        def reshard_grads(g):
            if not (hoist_fsdp and mesh is not None and pspecs is not None):
                return g
            return jax.tree_util.tree_map(
                lambda x, sp: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, sp)),
                g, pspecs, is_leaf=lambda s: isinstance(s, P))

        if microbatches == 1:
            loss, grads = grad_fn(use_params, batch)
            grads = reshard_grads(grads)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            def split3(x):   # positions3: (3, B, T)
                return x.reshape((x.shape[0], microbatches,
                                  x.shape[1] // microbatches)
                                 + x.shape[2:]).swapaxes(0, 1)
            parts = {k: (split3(v) if k == "positions3" else split(v))
                     for k, v in batch.items()}

            def body(carry, mb):
                g_acc, l_acc = carry
                l, g = grad_fn(use_params, mb)
                # bf16 gradient reduction (wire halves vs f32; accumulator
                # stays f32 and sharded, so no precision loss across
                # microbatches beyond the per-microbatch bf16 round)
                g = jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.bfloat16), g)
                g = reshard_grads(g)     # reduce-scatter over 'data' (ZeRO)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros = reshard_grads(zeros)
            (grads, loss), _ = jax.lax.scan(body, (zeros, 0.0), parts)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        lr = warmup_cosine(opt_state.step, 3e-4, 2000, 100_000)
        params, opt_state, _ = adamw.update(grads, opt_state, lr)
        return params, opt_state, loss
    return train_step


def build_prefill_step(model: LM):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def build_decode_step(model: LM):
    def decode_step(params, cache, token, t):
        return model.decode_step(params, cache, token, t)
    return decode_step


# ----------------------------------------------------------- HLO collectives
_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?[^=]*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WHILE_RE = re.compile(r"while\(.*?\), condition=(%[\w\.\-]+), body=(%[\w\.\-]+)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->")
_LEAD_DIM_RE = re.compile(r"[a-z0-9]+\[(\d+)[,\]]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _wire_bytes(op: str, size: int, g: int) -> float:
    """Per-device wire-byte model:
    all-reduce: 2·S·(g-1)/g (ring RS+AG); all-gather/reduce-scatter:
    S·(g-1)/g; all-to-all / collective-permute: S."""
    if op == "all-reduce":
        return 2 * size * (g - 1) / g
    if op in ("all-gather", "reduce-scatter"):
        return size * (g - 1) / g
    return float(size)


def parse_collectives(hlo: str, scan_lengths=()) -> Dict[str, Any]:
    """Trip-count-aware collective accounting over the post-SPMD HLO.

    XLA text lists each ``while`` body once; collectives inside execute
    trip-count times.  Trip counts are inferred by matching leading dims of
    the while carry tensors against the known scan lengths of the lowered
    program (layer count, group count, query-chunk count, ...) — the same
    undercount that makes cost_analysis unusable for scanned programs (see
    EXPERIMENTS.md §Roofline accounting).
    """
    # ---- split into computation blocks -------------------------------------
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    comps["__entry__"] = comps[cur]
                continue
        if cur is not None and line.strip().startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line.strip())

    cand = sorted(set(int(c) for c in scan_lengths if c and c > 1))

    # Trip count of a while body: scans dynamic-slice the stacked xs along
    # dim 0 by the induction variable — the operand's leading dim IS the trip
    # count.  (Leading-dim pattern matching against known scan lengths is the
    # fallback; it can collide — e.g. an SSD chunk tensor inside a 6-layer
    # group scan carry — so the dynamic-slice evidence wins.)
    _DEF_RE = re.compile(r"^(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
    _DS_RE = re.compile(r"dynamic-slice\((%[\w\.\-]+)")

    def body_trip(body_name: str) -> Optional[int]:
        lines = comps.get(body_name, ())
        shapes = {}
        for ln in lines:
            d = _DEF_RE.match(ln)
            if d:
                dims = [int(x) for x in d.group(3).split(",") if x.strip()]
                shapes[d.group(1)] = dims
        votes: Dict[int, int] = {}
        for ln in lines:
            d = _DEF_RE.match(ln)
            if not d:
                continue
            res = [int(x) for x in d.group(3).split(",") if x.strip()]
            if " dynamic-slice(" in ln:
                m = _DS_RE.search(ln)
                op_shape = shapes.get(m.group(1)) if m else None
                if (op_shape and res and len(op_shape) == len(res)
                        and res[0] == 1 and op_shape[0] > 1):
                    votes[op_shape[0]] = votes.get(op_shape[0], 0) + 1
            elif "dynamic-slice" in d.group(1) and "fusion(" in ln:
                # dynamic-slice+bitcast fusion: (N, ...) -> (...) lead dropped
                fm = re.search(r"fusion\((%[\w\.\-]+)", ln)
                op_shape = shapes.get(fm.group(1)) if fm else None
                if (op_shape and len(op_shape) == len(res) + 1
                        and op_shape[1:] == res and op_shape[0] > 1):
                    votes[op_shape[0]] = votes.get(op_shape[0], 0) + 1
                elif (op_shape and res and len(op_shape) == len(res)
                        and res[0] == 1 and op_shape[0] > 1):
                    votes[op_shape[0]] = votes.get(op_shape[0], 0) + 1
        if votes:
            return max(votes, key=lambda k: (votes[k], -k))
        return None

    def trip_of(line: str, body_name: str) -> int:
        t = body_trip(body_name)
        if t is not None:
            return t
        lead = [int(x) for x in _LEAD_DIM_RE.findall(line.split(" while(")[0])]
        matches = [c for c in cand if c in lead]
        return max(matches) if matches else 1

    # ---- per-computation direct cost + child whiles -------------------------
    direct: Dict[str, Dict] = {}
    children: Dict[str, list] = {}
    for name, lines in comps.items():
        d = {"counts": {}, "by_type_bytes": {}, "wire_bytes": 0.0}
        ch = []
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                ch.append((wm.group(2), trip_of(line, wm.group(2))))
                continue
            m = _COLL_RE.search(line)
            if not m:
                continue
            lhs = line.split("=")[0]
            if "-done" in lhs:
                continue
            op, dtype, dims = m.group(1), m.group(2), m.group(3)
            size = _shape_bytes(dtype, dims)
            g = None
            gm = _GROUP_RE.search(line)
            if gm:
                g = len([x for x in gm.group(1).split(",") if x.strip()])
            else:
                gi = _GROUP_IOTA_RE.search(line)
                if gi:
                    g = int(gi.group(2))
            g = g or 2
            wire = _wire_bytes(op, size, g)
            d["counts"][op] = d["counts"].get(op, 0) + 1
            d["by_type_bytes"][op] = d["by_type_bytes"].get(op, 0.0) + wire
            d["wire_bytes"] += wire
        direct[name] = d
        children[name] = ch

    # ---- roll up from the entry with multiplicities -------------------------
    import functools

    @functools.lru_cache(maxsize=None)
    def rollup(name: str):
        d = direct.get(name, {"counts": {}, "by_type_bytes": {},
                              "wire_bytes": 0.0})
        total = dict(wire_bytes=d["wire_bytes"],
                     counts=dict(d["counts"]),
                     by_type_bytes=dict(d["by_type_bytes"]))
        for child, trip in children.get(name, ()):
            sub = rollup(child)
            total["wire_bytes"] += trip * sub["wire_bytes"]
            for k, v in sub["counts"].items():
                total["counts"][k] = total["counts"].get(k, 0) + trip * v
            for k, v in sub["by_type_bytes"].items():
                total["by_type_bytes"][k] = (total["by_type_bytes"].get(k, 0.0)
                                             + trip * v)
        return total

    out = rollup("__entry__")
    out["static_op_lines"] = sum(d["counts"].get(k, 0) for d in direct.values()
                                 for k in d["counts"])
    return out


# -------------------------------------------------------------------- runner
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    B, T, mode = shp["global_batch"], shp["seq_len"], shp["mode"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    bax = SH.batch_axis(mesh, B)
    rules = SH.logical_rules(mesh, B, cfg)
    # §Perf: with head-sharded attention the per-chunk score block is 16×
    # smaller, so larger query chunks are free — and collectives sunk into
    # chunk loops drop proportionally (cell-A iterations 4-7)
    from repro.models import layers as LY
    if os.environ.get("REPRO_CHUNK_Q") is None:
        LY.CHUNK_Q = 512 if rules.get("heads") else 128
    model = LM(cfg, remat=(mode == "train"))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mode": mode,
        "mesh": dict(mesh.shape), "chips": chips,
        "global_batch": B, "seq_len": T, "ok": False,
    }
    total_p, active_p = param_count(cfg)
    rec["params_total"] = total_p
    rec["params_active"] = active_p

    # microbatches: keep ~4 sequences per device per microbatch (production
    # grad-accumulation; bounds live activations under full remat)
    dp = 1
    if bax is not None:
        axes = bax if isinstance(bax, tuple) else (bax,)
        dp = int(np.prod([mesh.shape[a] for a in axes]))
    per_dev_batch = max(1, B // dp)
    microbatches = max(1, per_dev_batch // 2) if mode == "train" else 1
    rec["microbatches"] = microbatches

    t0 = time.time()
    params, pspecs, pshapes = abstract_params(model, mesh)
    with mesh, logical_axis_rules(mesh, rules):
        if mode == "train":
            opt = abstract_opt_state(pshapes, pspecs, mesh)
            batch = train_inputs(cfg, B, T, mesh, bax)
            total_p, _ = param_count(cfg)
            hoist = (total_p >= SH.FSDP_THRESHOLD
                     and os.environ.get("REPRO_HOIST_FSDP", "0") == "1")
            rec["hoist_fsdp"] = hoist
            fn = jax.jit(build_train_step(model, microbatches, mesh, pspecs,
                                          hoist_fsdp=hoist),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params, opt, batch)
            tokens = B * T
        elif mode == "prefill":
            cache = abstract_cache(model, B, T, mesh, bax)
            batch = train_inputs(cfg, B, T, mesh, bax)
            batch.pop("labels", None)
            fn = jax.jit(build_prefill_step(model), donate_argnums=(2,))
            lowered = fn.lower(params, batch, cache)
            tokens = B * T
        else:  # decode
            cache = abstract_cache(model, B, T, mesh, bax)
            if cfg.n_codebooks > 1:
                tok = sds((B, 1, cfg.n_codebooks), jnp.int32, mesh, P(bax))
            else:
                tok = sds((B, 1), jnp.int32, mesh, P(bax))
            t_in = sds((), jnp.int32, mesh, P())
            fn = jax.jit(build_decode_step(model), donate_argnums=(1,))
            lowered = fn.lower(params, cache, tok, t_in)
            tokens = B
    rec["lower_s"] = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t0

    # ---- memory ------------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")
            if hasattr(ma, k)}
        if verbose:
            print("memory_analysis:", rec["memory"])
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    # ---- cost --------------------------------------------------------------
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals",
                             "optimal_seconds")}
        if verbose:
            print("cost_analysis flops:", rec["cost"].get("flops"))
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}

    # ---- collectives (trip-count-aware) -------------------------------------
    from repro.models.layers import CHUNK_Q
    from repro.models.model import derive_pattern
    pat = derive_pattern(cfg)
    scan_lengths = [pat.n_scan, pat.n_groups, pat.group_local, pat.n_tail,
                    microbatches]
    if mode != "decode":
        scan_lengths.append(T // CHUNK_Q)
        if cfg.ssm is not None:
            scan_lengths.append(T // cfg.ssm.chunk)
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo, tuple(scan_lengths))
    rec["hlo_bytes"] = len(hlo)
    rec["tokens_per_step"] = tokens

    # ---- analytic cost model (see launch/analytic.py for why) ---------------
    from repro.launch.analytic import analytic_cost
    rec["analytic"] = analytic_cost(cfg, B, T, mode)
    rec["model_flops"] = rec["analytic"]["model_flops"]
    rec["ok"] = True
    return rec


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    pods = "pod2" if multi_pod else "pod1"
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{pods}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    if args.all:
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                if not shape_applicable(arch, shape):
                    _write_skip(arch, shape)
                    continue
                for mp in (False, True):
                    p = cell_path(arch, shape, mp)
                    if os.path.exists(p) and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape]
                    if mp:
                        cmd.append("--multi-pod")
                    print(">>", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, mp))
        print("FAILURES:", failures)
        return 1 if failures else 0

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        rec = run_cell(args.arch, args.shape, mp)
        with open(cell_path(args.arch, args.shape, mp), "w") as f:
            json.dump(rec, f, indent=2)
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "chips", "ok", "lower_s",
                           "compile_s")}))
    return 0


def _write_skip(arch: str, shape: str) -> None:
    for mp in (False, True):
        with open(cell_path(arch, shape, mp), "w") as f:
            json.dump({"arch": arch, "shape": shape, "ok": True,
                       "skipped": "full-attention arch at 500k (DESIGN.md §5)",
                       "chips": 512 if mp else 256}, f, indent=2)


if __name__ == "__main__":
    sys.exit(main())
