"""Production meshes.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips ("data", "model").
Multi-pod: 2×16×16 = 512 chips ("pod", "data", "model") — the "pod" axis is
the slow (DCN) dimension; DP and the paper-derived relay/compressed
collectives run across it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run via launch/dryrun.py "
            "(it sets --xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(n_devices: int = 1):
    """Tiny mesh over available devices (CPU tests)."""
    n = min(n_devices, len(jax.devices()))
    return jax.make_mesh((1, n), ("data", "model"))
