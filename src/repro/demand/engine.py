"""The demand engine: ESGF-as-a-service over a running campaign.

Per admission wave (``DemandSpec.wave_interval_s`` of sim time, anchored on
the first ``step`` exactly like ``ControlPlane``'s control interval):

  1. optionally drift the popularity permutation (then re-key the
     scheduler's priority heaps);
  2. sample the wave's request counts (Poisson total, multinomial Zipf
     split — O(catalog), not O(requests));
  3. serve each requested dataset: a cache hit at its serving replica costs
     only the hit overhead; a cached-out replica read streams the request
     bytes at the reader's fair-share rate and admits the dataset to the
     cache; an unmaterialized dataset is redirected to the source (a *miss*
     for the hit-rate SLO) and pays the redirect penalty on top of the
     source-side stream rate;
  4. optionally warm the caches with the hottest materialized-but-uncached
     datasets (demand-driven top-ups; evictions fall out of cache pressure);
  5. register the wave's aggregate read traffic as concurrent reader
     streams on the transport (``set_read_load``), where it contends with
     replication movers for the site read caps until the next wave.

Latency percentiles come from a fixed log-scale histogram (quarter-decade
buckets), so p50/p99 are deterministic and resume bit-identically; hit-rate
is accumulated per sim day, giving the time-to-90%-hit-rate headline metric
(``day90``).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.pause import DAY
from repro.core.routes import Dataset, TB
from repro.demand.cache import ReadCache
from repro.demand.catalog import ReplicaCatalog
from repro.demand.spec import DemandSpec
from repro.demand.workload import RequestWorkload

# quarter-decade latency buckets from 1 ms: deterministic percentile math
_LAT_BASE_S = 1e-3
_LAT_BUCKETS = 64


def _lat_bucket(latency_s: float) -> int:
    if latency_s <= _LAT_BASE_S:
        return 0
    return min(_LAT_BUCKETS - 1,
               int(4.0 * math.log10(latency_s / _LAT_BASE_S)))


def _bucket_latency(idx: int) -> float:
    return _LAT_BASE_S * 10.0 ** ((idx + 0.5) / 4.0)


class DemandEngine:
    def __init__(self, spec: DemandSpec, catalog: Dict[str, Dataset],
                 table, sched, transport, source: str,
                 replicas: Sequence[str], seed: int = 0,
                 label: str = "campaign"):
        spec.validate()
        self.spec = spec
        self.sched = sched
        self.transport = transport
        self.source = source
        self.replicas = tuple(replicas)
        self.label = label
        self.replica_catalog = ReplicaCatalog(table, source, replicas)
        paths = sorted(catalog)
        self.workload = RequestWorkload(spec, paths, seed=seed)
        # a read serves the requested slice, never more than the dataset
        self._req_bytes = {p: max(1, min(int(spec.request_bytes),
                                         int(catalog[p].bytes)))
                           for p in paths}
        self.caches = {r: ReadCache(r, spec.cache_bytes, spec.eviction)
                       for r in self.replicas}
        self._next_wave: Optional[float] = None
        self._last_wave: Optional[float] = None
        self.waves = 0
        self.requests_total = 0
        self.hits_total = 0
        self.cache_hits_total = 0
        self.source_reads_total = 0
        self.bytes_served = 0
        self.warmups = 0
        self._daily: Dict[int, List[int]] = {}        # day -> [requests, hits]
        self._latency_hist: Dict[int, int] = {}
        # flight-recorder seam: called after each admission wave with (t1,
        # wave stats); plain attribute, None compiles to no observation
        self.obs_hook = None
        if spec.prioritize:
            sched.set_priority(self.workload.rank_of)

    # ----------------------------------------------------------------- step
    def step(self, now: float) -> None:
        """Driver hook, called once per active iteration.  The first call
        anchors the wave boundary (ControlPlane's interval anchoring); each
        later call at or past the boundary processes one admission wave."""
        if self._next_wave is None:
            self._last_wave = now
            self._next_wave = now + self.spec.wave_interval_s
            return
        if now + 1e-9 < self._next_wave:
            return
        self._process_wave(self._last_wave, now)
        self._last_wave = now
        self._next_wave = now + self.spec.wave_interval_s

    def next_wave(self, now: float) -> float:
        """Absolute sim time of the next admission wave (event-engine
        hint); ``now`` before the first step has anchored the cadence."""
        return now if self._next_wave is None else self._next_wave

    def teardown(self) -> None:
        """The campaign is over: user traffic stops consuming the site read
        caps (federation members keep running on the shared transport)."""
        self.transport.set_read_load(self.label, {})

    # ----------------------------------------------------------------- wave
    def _process_wave(self, t0: float, t1: float) -> None:
        if self.workload.maybe_drift(t1) and self.spec.prioritize:
            self.sched.reprioritize()
        counts = self.workload.sample_wave(t0, t1)
        self.waves += 1
        day = self._daily.setdefault(int(t1 // DAY), [0, 0])
        read_bytes: Dict[str, int] = {}
        rate_memo: Dict[str, float] = {}

        def stream_rate(site: str) -> float:
            r = rate_memo.get(site)
            if r is None:
                r = rate_memo[site] = max(
                    1.0, self.transport.user_read_rate(site))
            return r

        for r in np.flatnonzero(counts):
            rank = int(r)
            c = int(counts[rank])
            path = self.workload.path_at_rank(rank)
            nbytes = self._req_bytes[path]
            site = self.replica_catalog.serving_site(path)
            if site is None:
                # not materialized anywhere: redirected to the slow source
                latency = (self.spec.miss_penalty_s
                           + nbytes / stream_rate(self.source))
                self.source_reads_total += c
                read_bytes[self.source] = (read_bytes.get(self.source, 0)
                                           + c * nbytes)
                hit = False
            else:
                cache = self.caches[site]
                if cache.touch(path, now=t1, count=c):
                    latency = self.spec.hit_overhead_s
                    self.cache_hits_total += c
                else:
                    latency = (self.spec.hit_overhead_s
                               + nbytes / stream_rate(site))
                    read_bytes[site] = read_bytes.get(site, 0) + c * nbytes
                    cache.admit(path, nbytes, rank=rank, now=t1)
                hit = True
            self.requests_total += c
            self.bytes_served += c * nbytes
            day[0] += c
            if hit:
                self.hits_total += c
                day[1] += c
            b = _lat_bucket(latency)
            self._latency_hist[b] = self._latency_hist.get(b, 0) + c

        # demand-driven cache top-ups: pre-stage the hottest materialized
        # datasets that are not cached at their serving replica yet
        warmed = 0
        if self.spec.warm_per_wave > 0:
            for rank in range(self.workload.n):
                if warmed >= self.spec.warm_per_wave:
                    break
                path = self.workload.path_at_rank(rank)
                site = self.replica_catalog.serving_site(path)
                if site is None or self.caches[site].contains(path):
                    continue
                nbytes = self._req_bytes[path]
                if self.caches[site].admit(path, nbytes, rank=rank, now=t1):
                    read_bytes[site] = read_bytes.get(site, 0) + nbytes
                    warmed += 1
            self.warmups += warmed

        # the wave's aggregate read traffic becomes concurrent reader
        # streams on each serving site until the next wave
        dt = max(1.0, t1 - t0)
        load = {}
        for site, nb in sorted(read_bytes.items()):
            streams = int(math.ceil(nb / (dt * self.spec.stream_bps)))
            if streams > 0:
                load[site] = streams
        self.transport.set_read_load(self.label, load)
        if self.obs_hook is not None:
            self.obs_hook(t1, {"wave": self.waves,
                               "requests": self.requests_total,
                               "hits": self.hits_total,
                               "cache_hits": self.cache_hits_total,
                               "source_reads": self.source_reads_total,
                               "warmed": warmed})

    # -------------------------------------------------------------- metrics
    def latency_quantile(self, q: float) -> float:
        total = sum(self._latency_hist.values())
        if total == 0:
            return 0.0
        target = q * total
        acc = 0
        for idx in sorted(self._latency_hist):
            acc += self._latency_hist[idx]
            if acc >= target:
                return round(_bucket_latency(idx), 4)
        return round(_bucket_latency(_LAT_BUCKETS - 1), 4)

    def day90(self, threshold: float = 0.9) -> Optional[int]:
        """First sim day whose daily hit-rate reaches ``threshold`` — the
        time-to-90%-hit-rate headline metric; None if never reached."""
        for d in sorted(self._daily):
            req, hits = self._daily[d]
            if req > 0 and hits / req >= threshold:
                return d
        return None

    def final_day_hit_rate(self) -> float:
        if not self._daily:
            return 0.0
        req, hits = self._daily[max(self._daily)]
        return hits / req if req else 0.0

    def summary(self) -> dict:
        req = self.requests_total
        return {
            "users": self.spec.users,
            "waves": self.waves,
            "requests": req,
            "hits": self.hits_total,
            "hit_rate": round(self.hits_total / req, 4) if req else 0.0,
            "cache_hits": self.cache_hits_total,
            "cache_hit_rate": (round(self.cache_hits_total / req, 4)
                               if req else 0.0),
            "source_reads": self.source_reads_total,
            "bytes_served_tb": round(self.bytes_served / TB, 3),
            "p50_s": self.latency_quantile(0.5),
            "p99_s": self.latency_quantile(0.99),
            "day90": self.day90(),
            "final_day_hit_rate": round(self.final_day_hit_rate(), 4),
            "drifts": self.workload.drifts,
            "warmups": self.warmups,
            "caches": {s: c.summary()
                       for s, c in sorted(self.caches.items())},
        }

    # ------------------------------------------------------------ checkpoints
    def state_dict(self) -> dict:
        return {
            "workload": self.workload.state_dict(),
            "caches": {s: c.state_dict()
                       for s, c in sorted(self.caches.items())},
            "next_wave": self._next_wave,
            "last_wave": self._last_wave,
            "waves": self.waves,
            "requests_total": self.requests_total,
            "hits_total": self.hits_total,
            "cache_hits_total": self.cache_hits_total,
            "source_reads_total": self.source_reads_total,
            "bytes_served": self.bytes_served,
            "warmups": self.warmups,
            "daily": [[d, req, hits]
                      for d, (req, hits) in sorted(self._daily.items())],
            "latency_hist": [[i, c]
                             for i, c in sorted(self._latency_hist.items())],
        }

    def load_state_dict(self, d: dict) -> None:
        if set(d["caches"]) != set(self.caches):
            raise ValueError(
                f"demand snapshot caches {sorted(d['caches'])} do not match "
                f"the scenario's replicas {sorted(self.caches)}")
        self.workload.load_state_dict(d["workload"])
        for s, st in d["caches"].items():
            self.caches[s].load_state_dict(st)
        self._next_wave = d["next_wave"]
        self._last_wave = d["last_wave"]
        self.waves = int(d["waves"])
        self.requests_total = int(d["requests_total"])
        self.hits_total = int(d["hits_total"])
        self.cache_hits_total = int(d["cache_hits_total"])
        self.source_reads_total = int(d["source_reads_total"])
        self.bytes_served = int(d["bytes_served"])
        self.warmups = int(d["warmups"])
        self._daily = {int(day): [int(req), int(hits)]
                       for day, req, hits in d["daily"]}
        self._latency_hist = {int(i): int(c) for i, c in d["latency_hist"]}
