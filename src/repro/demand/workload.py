"""Deterministic synthetic request workload.

Millions of users are modeled at O(catalog) cost per admission wave, not
O(requests): the wave's total request count is one Poisson draw around the
diurnally-modulated population rate, and its split across datasets is one
multinomial draw over a Zipf probability vector.  Popularity is a seeded
permutation of the catalog — rank 0 is the hottest dataset — and optional
drift reshuffles a fraction of the permutation on a fixed sim-time cadence.

The RNG is a dedicated ``np.random.default_rng`` stream, seeded from the
scenario seed plus a demand-stream discriminator so it can never interleave
with the fault injector's stream; its bit-generator state serializes in
snapshots exactly like ``FaultInjector``'s.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

import numpy as np

from repro.core.pause import DAY
from repro.demand.spec import DemandSpec

# demand RNG stream discriminator ("DEMD"): keeps the demand stream disjoint
# from the fault injector's default_rng(seed) for every scenario seed
_DEMAND_STREAM = 0x44454D44


class RequestWorkload:
    def __init__(self, spec: DemandSpec, paths: Sequence[str], seed: int = 0):
        if not paths:
            raise ValueError("request workload needs a non-empty catalog")
        self.spec = spec
        self.paths: List[str] = list(paths)
        n = len(self.paths)
        self.rng = np.random.default_rng([seed, _DEMAND_STREAM])
        # _order[r] = catalog index of the dataset with popularity rank r
        self._order: List[int] = [int(i) for i in self.rng.permutation(n)]
        w = np.arange(1, n + 1, dtype=float) ** (-spec.zipf_s)
        self._p = w / w.sum()
        self._next_drift = (spec.drift_interval_days * DAY
                            if spec.drift_interval_days > 0 else None)
        self.drifts = 0
        self._rebuild_ranks()

    def _rebuild_ranks(self) -> None:
        self._rank: Dict[str, int] = {
            self.paths[j]: r for r, j in enumerate(self._order)}

    # -------------------------------------------------------------- queries
    @property
    def n(self) -> int:
        return len(self.paths)

    def path_at_rank(self, rank: int) -> str:
        return self.paths[self._order[rank]]

    def rank_of(self, path: str) -> int:
        """Popularity rank (0 = hottest); unknown paths (mid-run top-ups)
        rank below the whole catalog."""
        return self._rank.get(path, len(self.paths))

    def probabilities(self) -> np.ndarray:
        """Per-rank request probability (rank-monotone by construction)."""
        return self._p.copy()

    def diurnal(self, t: float) -> float:
        """Load factor at sim time ``t``: 1 +/- amplitude over a 24 h cycle,
        peaking mid-day."""
        a = self.spec.diurnal_amplitude
        if a <= 0:
            return 1.0
        return 1.0 + a * math.sin(2 * math.pi * (t / DAY - 0.25))

    # ------------------------------------------------------------- sampling
    def sample_wave(self, t0: float, t1: float) -> np.ndarray:
        """Request counts by popularity rank for the interval [t0, t1):
        one Poisson draw for the wave total (rate = population rate at the
        interval midpoint), one multinomial split over the Zipf vector."""
        dt = max(0.0, t1 - t0)
        lam = (self.spec.users * self.spec.requests_per_user_day
               * (dt / DAY) * self.diurnal(0.5 * (t0 + t1)))
        total = int(self.rng.poisson(lam)) if lam > 0 else 0
        if total == 0:
            return np.zeros(len(self.paths), dtype=np.int64)
        return self.rng.multinomial(total, self._p)

    def maybe_drift(self, now: float) -> bool:
        """Reshuffle ``drift_fraction`` of the popularity ranks once per
        drift interval; returns True when the permutation changed (the
        engine then re-keys the scheduler's priority heaps)."""
        if self._next_drift is None:
            return False
        drifted = False
        n = len(self.paths)
        while now + 1e-9 >= self._next_drift:
            k = min(n, max(2, int(round(self.spec.drift_fraction * n))))
            idx = np.sort(self.rng.choice(n, size=k, replace=False))
            vals = [self._order[int(i)] for i in idx]
            shuffled = [vals[int(j)] for j in self.rng.permutation(k)]
            for i, v in zip(idx, shuffled):
                self._order[int(i)] = v
            self._next_drift += self.spec.drift_interval_days * DAY
            self.drifts += 1
            drifted = True
        if drifted:
            self._rebuild_ranks()
        return drifted

    # ---------------------------------------------------------- checkpoints
    def state_dict(self) -> dict:
        return {"rng": self.rng.bit_generator.state,
                "order": list(self._order),
                "next_drift": self._next_drift,
                "drifts": self.drifts}

    def load_state_dict(self, d: dict) -> None:
        self.rng.bit_generator.state = d["rng"]
        self._order = [int(i) for i in d["order"]]
        self._next_drift = d["next_drift"]
        self.drifts = int(d["drifts"])
        self._rebuild_ranks()
