"""Demand engine: the consumer half of the replication story.

The paper moved 7.3 PB so that ESGF nodes could *serve* the climate
community; ``repro.demand`` models that community.  A ``RequestWorkload``
generates deterministic, Zipf-skewed, diurnally-modulated user read traffic
against the campaign catalog; a ``ReplicaCatalog`` tracks which datasets are
materialized where (fed by transfer-table row transitions, O(active)); a
per-replica ``ReadCache`` serves hot datasets; and the ``DemandEngine`` ties
them together — user reads contend with replication movers for the same
fair-share site read caps, and the demand policy re-prioritizes the
scheduler's direct-start heaps popular-first so that replication chases the
request distribution instead of catalog order.
"""
from repro.demand.cache import ReadCache
from repro.demand.catalog import ReplicaCatalog
from repro.demand.engine import DemandEngine
from repro.demand.spec import NO_DEMAND, DemandSpec
from repro.demand.workload import RequestWorkload

__all__ = ["DemandEngine", "DemandSpec", "NO_DEMAND", "ReadCache",
           "ReplicaCatalog", "RequestWorkload"]
