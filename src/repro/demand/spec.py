"""Declarative demand (user-traffic) specification.

Mirrors ``repro.control.policy.TransferPolicySpec``: a frozen dataclass a
``ScenarioSpec`` carries, whose default (``NO_DEMAND``, zero users) compiles
to **no demand engine at all** — a scenario that does not opt in runs exactly
the code path (and trajectory) it ran before this subsystem existed.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.routes import GB

KNOWN_EVICTION = ("lru", "popularity", "pin")


@dataclass(frozen=True)
class DemandSpec:
    """A synthetic user population reading the campaign's catalog.

    Request volume is ``users * requests_per_user_day`` reads/day, Zipf-skewed
    over a seeded popularity permutation of the catalog and modulated by a
    diurnal curve.  Requests are admitted in waves every ``wave_interval_s``
    of sim time (the ``repro.serve`` wave-admission shape); each wave's
    non-cached reads register as concurrent reader streams on the serving
    site's read cap, where they contend with replication movers.
    """
    # ---- population and skew
    users: int = 0                       # 0 = no demand engine (NO_DEMAND)
    requests_per_user_day: float = 0.01  # mean dataset reads per user per day
    zipf_s: float = 1.1                  # popularity exponent (rank^-s)
    drift_interval_days: float = 0.0     # 0 = popularity never drifts
    drift_fraction: float = 0.2          # fraction of ranks reshuffled per drift
    diurnal_amplitude: float = 0.5       # load swing around the mean, [0, 1)
    # ---- admission and service model
    wave_interval_s: float = 6 * 3600.0  # request-admission cadence
    request_bytes: int = 4 * GB          # bytes served per read (capped at ds size)
    stream_bps: float = 0.25 * GB        # nominal per-reader-stream rate
    miss_penalty_s: float = 30.0         # redirect-to-source overhead on a miss
    hit_overhead_s: float = 0.05         # cache-hit service overhead
    # ---- per-replica read cache
    cache_bytes: int = 0                 # capacity per replica site; 0 = unbounded
    eviction: str = "lru"                # lru | popularity | pin
    warm_per_wave: int = 0               # proactive cache warm-ups per wave
    # ---- replication policy coupling
    prioritize: bool = True              # popular-first direct-heap priorities

    @property
    def enabled(self) -> bool:
        """True when this spec needs a live demand engine."""
        return self.users > 0

    def validate(self) -> None:
        if self.users < 0:
            raise ValueError(f"users must be >= 0, got {self.users}")
        if not self.enabled:
            return
        if self.requests_per_user_day < 0:
            raise ValueError("requests_per_user_day must be >= 0, got "
                             f"{self.requests_per_user_day}")
        if self.zipf_s <= 0:
            raise ValueError(f"zipf_s must be > 0, got {self.zipf_s}")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1), got "
                             f"{self.diurnal_amplitude}")
        if not 0.0 <= self.drift_fraction <= 1.0:
            raise ValueError("drift_fraction must be in [0, 1], got "
                             f"{self.drift_fraction}")
        if self.drift_interval_days < 0:
            raise ValueError("drift_interval_days must be >= 0, got "
                             f"{self.drift_interval_days}")
        if self.wave_interval_s <= 0:
            raise ValueError("wave_interval_s must be > 0, got "
                             f"{self.wave_interval_s}")
        if self.request_bytes <= 0:
            raise ValueError("request_bytes must be > 0, got "
                             f"{self.request_bytes}")
        if self.stream_bps <= 0:
            raise ValueError(f"stream_bps must be > 0, got {self.stream_bps}")
        if self.cache_bytes < 0:
            raise ValueError(f"cache_bytes must be >= 0, got "
                             f"{self.cache_bytes}")
        if self.eviction not in KNOWN_EVICTION:
            raise ValueError(f"unknown eviction {self.eviction!r} "
                             f"(known: {', '.join(KNOWN_EVICTION)})")
        if self.warm_per_wave < 0:
            raise ValueError(f"warm_per_wave must be >= 0, got "
                             f"{self.warm_per_wave}")


NO_DEMAND = DemandSpec()
