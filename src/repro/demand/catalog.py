"""Replica catalog: which datasets are materialized at which sites.

In the spirit of Allcock et al.'s replica management layer: the serving tier
asks "where can this dataset be read from?" and the answer must stay current
as replication lands copies.  Rather than re-scanning the transfer table per
request, the catalog subscribes to row transitions — a SUCCEEDED row at a
destination materializes the dataset there — so updates cost O(1) per
transition and lookups are a dict probe.  The source site implicitly holds
everything; replica holdings are a pure function of the table, which is why
this object is never serialized: on resume it is rebuilt by adopting the
restored table's rows (the same pattern ``ReplicationScheduler.__init__``
uses for its queues).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Set, Tuple

from repro.core.transfer_table import (Status, TransferRecord, TransferTable)


class ReplicaCatalog:
    def __init__(self, table: TransferTable, source: str,
                 replicas: Sequence[str]):
        self.source = source
        self.replicas: Tuple[str, ...] = tuple(replicas)
        self._holders: Dict[str, Set[str]] = {}
        table.add_listener(self._on_row)
        # adopt rows that predate this catalog (checkpoint resume: the
        # restored table already carries the campaign's history)
        for rec in table.all():
            self._on_row(rec, None, None)

    # ------------------------------------------------------------- listener
    def _on_row(self, rec: TransferRecord, old_status: Optional[Status],
                old_source: Optional[str]) -> None:
        if rec.status == Status.SUCCEEDED:
            self._holders.setdefault(rec.dataset, set()).add(rec.destination)
        elif old_status == Status.SUCCEEDED:
            # a replica leaving SUCCEEDED (scrub found it corrupt and flipped
            # it back into the repair path) is unserveable until re-landed:
            # reads fall back to other holders or the source, so the hit rate
            # dips during repair and recovers when the re-transfer lands
            held = self._holders.get(rec.dataset)
            if held is not None:
                held.discard(rec.destination)
                if not held:
                    del self._holders[rec.dataset]

    # -------------------------------------------------------------- queries
    def materialized(self, dataset: str) -> bool:
        """True once at least one replica holds the dataset."""
        return dataset in self._holders

    def holders(self, dataset: str) -> Set[str]:
        return self._holders.get(dataset, set())

    def serving_site(self, dataset: str) -> Optional[str]:
        """The replica a user read is directed to: the first replica in
        priority order that holds the dataset, or None (source read)."""
        held = self._holders.get(dataset)
        if not held:
            return None
        for r in self.replicas:
            if r in held:
                return r
        return None

    def materialized_count(self) -> int:
        return len(self._holders)
