"""Per-replica read cache with pluggable eviction.

Serving tiers in front of archive storage keep hot datasets on fast media;
this models that layer per replica site.  Three eviction disciplines:

  * ``"lru"``        — classic least-recently-used;
  * ``"popularity"`` — evict the least popular entry first (highest
    popularity rank), breaking ties toward the least recently used;
  * ``"pin"``        — pin-all: admitted entries are never evicted, and new
    admissions are refused once the capacity is full.

All state lives in one insertion-ordered dict, so iteration (and therefore
eviction tie-breaking and serialization) is deterministic and survives a
checkpoint/resume byte-for-byte.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List


class ReadCache:
    def __init__(self, site: str, capacity_bytes: int = 0,
                 eviction: str = "lru"):
        self.site = site
        self.capacity = int(capacity_bytes)      # 0 = unbounded
        self.eviction = eviction
        # path -> [nbytes, popularity rank at admission, last-used sim time]
        self._entries: "OrderedDict[str, List]" = OrderedDict()
        self.used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, path: str) -> bool:
        return path in self._entries

    # -------------------------------------------------------------- serving
    def touch(self, path: str, now: float, count: int = 1) -> bool:
        """Serve ``count`` requests for ``path``; True on a cache hit."""
        e = self._entries.get(path)
        if e is None:
            self.misses += count
            return False
        e[2] = now
        self._entries.move_to_end(path)
        self.hits += count
        return True

    def admit(self, path: str, nbytes: int, rank: int, now: float) -> bool:
        """Admit ``path`` after a miss, evicting per policy to make room;
        False when the entry cannot fit (over-capacity, or pin-all full)."""
        if path in self._entries:
            return True
        nbytes = int(nbytes)
        if self.capacity and nbytes > self.capacity:
            return False
        while self.capacity and self.used + nbytes > self.capacity:
            if not self._evict_one():
                return False
        self._entries[path] = [nbytes, int(rank), float(now)]
        self.used += nbytes
        return True

    def _evict_one(self) -> bool:
        if not self._entries or self.eviction == "pin":
            return False
        if self.eviction == "lru":
            victim = next(iter(self._entries))
        else:  # popularity-weighted: least popular first, then oldest use
            victim = max(self._entries,
                         key=lambda p: (self._entries[p][1],
                                        -self._entries[p][2]))
        e = self._entries.pop(victim)
        self.used -= e[0]
        self.evictions += 1
        return True

    # -------------------------------------------------------------- summary
    def summary(self) -> dict:
        return {"entries": len(self._entries), "used_bytes": self.used,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}

    # ---------------------------------------------------------- checkpoints
    def state_dict(self) -> dict:
        return {"entries": [[p, e[0], e[1], e[2]]
                            for p, e in self._entries.items()],
                "used": self.used, "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}

    def load_state_dict(self, d: dict) -> None:
        self._entries = OrderedDict(
            (p, [int(nb), int(rank), float(last)])
            for p, nb, rank, last in d["entries"])
        self.used = int(d["used"])
        self.hits = int(d["hits"])
        self.misses = int(d["misses"])
        self.evictions = int(d["evictions"])
