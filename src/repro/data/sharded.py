"""File-backed sharded dataset with per-host assignment, prefetch/straggler
handling, and exact resumable iterator state — the at-scale data pipeline.

Layout: a dataset directory holds ``shard-%05d.npy`` token files plus an
``index.json``.  Hosts take shards round-robin by ``host_id`` (on a real
cluster, ``jax.process_index()``).  Iterator state is the *complete* delivery
state — remaining shard order, epoch, and the leftover token buffer — so
restart resumes with no token skipped or repeated, even if straggler
requeuing reordered shards.  Shard reads run under a deadline: a read that
exceeds it is requeued to the back of the order and logged (host-level
straggler mitigation; the training loop never stalls on one slow disk).
"""
from __future__ import annotations

import io
import json
import os
import queue
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


def write_shards(root: str, tokens: np.ndarray, shard_len: int) -> int:
    os.makedirs(root, exist_ok=True)
    n = len(tokens) // shard_len
    names = []
    for i in range(n):
        name = f"shard-{i:05d}.npy"
        np.save(os.path.join(root, name),
                tokens[i * shard_len:(i + 1) * shard_len])
        names.append(name)
    with open(os.path.join(root, "index.json"), "w") as f:
        json.dump({"shards": names, "shard_len": shard_len}, f)
    return n


@dataclass
class IterState:
    """Exact delivery state (serializes into the training checkpoint)."""
    pending: List[str] = field(default_factory=list)  # shards left this epoch
    epoch: int = 0
    leftover: np.ndarray = field(default_factory=lambda: np.empty(0, np.int32))

    def save(self, path: str) -> None:
        np.savez(path, pending=np.array(self.pending), epoch=self.epoch,
                 leftover=self.leftover)

    @classmethod
    def load(cls, path: str) -> "IterState":
        z = np.load(path, allow_pickle=False)
        return cls(pending=[str(s) for s in z["pending"]],
                   epoch=int(z["epoch"]),
                   leftover=z["leftover"].astype(np.int32))


class ShardedDataset:
    def __init__(self, root: str, host_id: int = 0, n_hosts: int = 1,
                 straggler_deadline_s: float = 30.0):
        with open(os.path.join(root, "index.json")) as f:
            idx = json.load(f)
        self.root = root
        self.all_shards: List[str] = idx["shards"]
        self.shard_len: int = idx["shard_len"]
        self.my_shards = self.all_shards[host_id::n_hosts]
        if not self.my_shards:
            raise ValueError(f"host {host_id}/{n_hosts}: no shards")
        self.deadline = straggler_deadline_s
        self.slow_shards: List[str] = []   # straggler log
        self.load_hook = None              # tests inject delays/failures here

    # ------------------------------------------------------------------ load
    def _load(self, name: str) -> np.ndarray:
        if self.load_hook is not None:
            self.load_hook(name)
        return np.load(os.path.join(self.root, name))

    def _load_with_deadline(self, name: str) -> Optional[np.ndarray]:
        result: queue.Queue = queue.Queue()

        def work():
            try:
                result.put(("ok", self._load(name)))
            except Exception as e:  # noqa: BLE001
                result.put(("err", e))

        th = threading.Thread(target=work, daemon=True)
        th.start()
        try:
            kind, val = result.get(timeout=self.deadline)
        except queue.Empty:
            self.slow_shards.append(name)
            return None
        if kind == "err":
            self.slow_shards.append(name)
            return None
        return val

    # -------------------------------------------------------------- iterate
    def batches(self, batch: int, seq: int, state: Optional[IterState] = None
                ) -> Iterator[Tuple[Dict[str, np.ndarray], IterState]]:
        """Yields (batch_dict, state_after_batch).  Feeding the yielded state
        back into ``batches`` resumes exactly after that batch."""
        st = state if state is not None else IterState(
            pending=list(self.my_shards))
        pending = list(st.pending) or list(self.my_shards)
        epoch = st.epoch
        buf = st.leftover.copy()
        need = batch * (seq + 1)
        while True:
            while len(buf) < need:
                if not pending:
                    pending = list(self.my_shards)
                    epoch += 1
                name = pending.pop(0)
                data = self._load_with_deadline(name)
                if data is None:
                    pending.append(name)   # straggler: requeue at the back
                    continue
                buf = np.concatenate([buf, data.astype(np.int32)])
            used = buf[:need].reshape(batch, seq + 1)
            buf = buf[need:]
            out_state = IterState(pending=list(pending), epoch=epoch,
                                  leftover=buf.copy())
            yield ({"tokens": used[:, :-1].copy(),
                    "labels": used[:, 1:].copy()}, out_state)
