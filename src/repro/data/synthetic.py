"""Deterministic synthetic token stream.

Stateless: batch ``i`` is a pure function of (seed, i), so resuming after a
failure needs only the step counter — the data-pipeline half of
checkpoint/restart is exact by construction.  Tokens follow a Zipf-ish
distribution with a next-token structure (affine hash chain) so small models
actually learn and loss decreases.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

from repro.models.config import ModelConfig
from repro.models.frontends import mrope_position_ids


@dataclass
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    n_codebooks: int = 1
    embeds_dim: int = 0            # >0 -> emit embeddings instead of tokens
    mrope: bool = False


class SyntheticTokens:
    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed << 20) ^ step)
        shape = (c.batch_size, c.seq_len + 1)
        if c.n_codebooks > 1:
            shape = shape + (c.n_codebooks,)
        # structured stream: x_{t+1} = (a * x_t + b) % V with noise
        a = 31337 % c.vocab_size or 7
        x0 = rng.integers(0, c.vocab_size, (c.batch_size,) + shape[2:])
        toks = np.empty(shape, np.int64)
        toks[:, 0] = x0
        for t in range(1, shape[1]):
            nxt = (toks[:, t - 1] * a + 13) % c.vocab_size
            noise = rng.random(nxt.shape) < 0.1
            rand = rng.integers(0, c.vocab_size, nxt.shape)
            toks[:, t] = np.where(noise, rand, nxt)
        out: Dict[str, np.ndarray] = {}
        if c.embeds_dim:
            emb_rng = np.random.default_rng(c.seed ^ 0xE)
            table = emb_rng.normal(0, 0.02, (c.vocab_size, c.embeds_dim)
                                   ).astype(np.float32)
            out["embeds"] = table[toks[:, :-1]]
            out["labels"] = toks[:, 1:].astype(np.int32)
        else:
            out["tokens"] = toks[:, :-1].astype(np.int32)
            out["labels"] = toks[:, 1:].astype(np.int32)
        if c.mrope:
            out["positions3"] = mrope_position_ids(c.batch_size, c.seq_len)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def for_model(cfg: ModelConfig, batch_size: int, seq_len: int,
              seed: int = 0) -> SyntheticTokens:
    return SyntheticTokens(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, batch_size=batch_size,
        seed=seed, n_codebooks=cfg.n_codebooks,
        embeds_dim=0 if cfg.embed_inputs else cfg.d_model,
        mrope=cfg.mrope))
