"""Dataset staging: the paper's replication machinery as the training-data
path.

A 1000-node job stages dataset shards from the persistent store (= LLNL, the
slow source) to pod-local staging areas (= ALCF/OLCF).  The Figure-4 scheduler
moves them: the store is read once, pods relay among themselves, transfers
overlap training, and pod maintenance re-routes instead of stalling the job.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.faults import Notifier, RetryPolicy
from repro.core.routes import Dataset
from repro.core.scheduler import ReplicationPolicy, ReplicationScheduler
from repro.core.transfer_table import Status, TransferTable
from repro.core.transport import LocalFSTransport


@dataclass
class StagingArea:
    """Replicates dataset directories from ``store`` to each pod's area."""
    root: str                       # parent of site dirs
    store: str = "STORE"
    pods: tuple = ("POD0", "POD1")

    def __post_init__(self):
        self.transport = LocalFSTransport(self.root)
        self.table = TransferTable()
        self.notifier = Notifier()
        self.catalog: Dict[str, Dataset] = {}
        self.scheduler = ReplicationScheduler(
            self.table, self.transport, self.catalog,
            ReplicationPolicy(self.store, self.pods),
            RetryPolicy(max_retries=3, backoff_s=0.0), self.notifier)
        for site in (self.store, *self.pods):
            os.makedirs(os.path.join(self.root, site), exist_ok=True)

    # ------------------------------------------------------------------ api
    def register(self, rel_path: str) -> None:
        """Register a dataset directory (already present under the store)."""
        base = os.path.join(self.root, self.store, rel_path.lstrip("/"))
        nbytes = nfiles = ndirs = 0
        for dirpath, _, files in os.walk(base):
            ndirs += 1
            for fn in files:
                nfiles += 1
                nbytes += os.path.getsize(os.path.join(dirpath, fn))
        ds = Dataset(rel_path, nbytes, nfiles, ndirs)
        self.catalog[rel_path] = ds
        self.table.populate([rel_path], self.store, list(self.pods))

    def run_until_staged(self, max_steps: int = 10_000) -> int:
        """Drive the scheduler to completion (LocalFSTransport is immediate,
        so each step completes submissions).  Returns steps used."""
        now = 0.0
        for i in range(max_steps):
            self.scheduler.step(now)
            now += 1.0
            if self.scheduler.done():
                return i + 1
        raise RuntimeError("staging did not converge")

    def pod_path(self, pod: str, rel_path: str) -> str:
        return os.path.join(self.root, pod, rel_path.lstrip("/"))

    def staged_ok(self, rel_path: str) -> bool:
        return all(
            (self.table.get(rel_path, pod) or None) is not None
            and self.table.get(rel_path, pod).status == Status.SUCCEEDED
            for pod in self.pods)
