"""Ensemble declarations: a base scenario plus perturbation axes.

An ``EnsembleSpec`` names a base ``ScenarioSpec`` and a tuple of
``AxisSpec`` perturbations; compiling it yields one ``(ScenarioSpec, seed,
label)`` triple per lane.  Axes perturb *numbers*, never topology — site
names, route pairs, source, and replica order are invariant across lanes,
which is what lets the lanes engine hold every world in one dense array.

Axis paths (the ``name`` of an ``AxisSpec``):

* ``seed`` — the world seed (catalog + fault + demand streams).
* ``faults.<field>`` — any ``FaultProfileSpec`` field
  (``transient_per_tb``, ``fragility_tail``, ``max_retries``,
  ``backoff_s``, ``fault_retry_cost_s``).
* ``catalog.<field>`` — any ``CatalogSpec`` field.
* ``route.<SRC>-><DST>.gbps`` — one route's bandwidth.
* ``site.<NAME>.<field>`` — one ``SiteSpec`` field (``read_gbps``,
  ``write_gbps``, ``scan_files_per_s``, ``scan_mem_limit_files``,
  ``concurrency_knee``).
* ``policy.<field>`` — any ``TransferPolicySpec`` field (AIMD constants,
  bundle caps).  Non-static policies compile to a control plane, so these
  ensembles run on the scalar fallback, not the array engine.
* top-level scalars: ``human_fix_days``, ``task_setup_s``, ``max_days``,
  ``max_active_per_route``.

Grid mode takes the full cross product of all axis values; random mode
draws ``n_lanes`` independent combinations (one value per axis, uniform)
from a dedicated sample stream — deterministic in ``sample_seed`` and
independent of every in-world RNG stream.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.scenarios.spec import ScenarioSpec


@dataclass(frozen=True)
class AxisSpec:
    """One perturbation axis: a dotted path and the values it sweeps."""
    name: str
    values: Tuple

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"axis {self.name!r} has no values")
        object.__setattr__(self, "values", tuple(self.values))


def apply_axis(spec: ScenarioSpec, name: str, value):
    """Return ``(spec, seed_override)`` with one axis applied.  ``seed``
    is special-cased: it does not change the spec, it changes which world
    the lane builds."""
    if name == "seed":
        return spec, int(value)
    if name in ("human_fix_days", "task_setup_s", "max_days",
                "max_active_per_route", "step_s"):
        return spec.vary(**{name: value}), None
    head, _, rest = name.partition(".")
    if head == "faults":
        return spec.with_faults(**{rest: value}), None
    if head == "catalog":
        return spec.with_catalog(**{rest: value}), None
    if head == "policy":
        return spec.vary(
            policy=dataclasses.replace(spec.policy, **{rest: value})), None
    if head == "route":
        pair, _, fld = rest.partition(".")
        src, _, dst = pair.partition("->")
        routes, hits = [], 0
        for r in spec.routes:
            if r.source == src and r.destination == dst:
                r = dataclasses.replace(r, **{fld or "gbps": value})
                hits += 1
            routes.append(r)
        if not hits:
            raise KeyError(f"axis {name!r}: no route {src}->{dst}")
        return spec.vary(routes=tuple(routes)), None
    if head == "site":
        sname, _, fld = rest.partition(".")
        sites, hits = [], 0
        for s in spec.sites:
            if s.name == sname:
                s = dataclasses.replace(s, **{fld: value})
                hits += 1
            sites.append(s)
        if not hits:
            raise KeyError(f"axis {name!r}: no site {sname}")
        return spec.vary(sites=tuple(sites)), None
    raise KeyError(f"unknown ensemble axis {name!r}")


@dataclass(frozen=True)
class EnsembleSpec:
    """A batch of perturbed worlds around ``base``.

    ``axes`` empty → a pure seed sweep: ``n_lanes`` lanes with seeds
    ``base_seed .. base_seed + n_lanes - 1``.  With axes, ``mode="grid"``
    enumerates the cross product (``n_lanes`` then only caps it) and
    ``mode="random"`` draws ``n_lanes`` combinations."""
    name: str
    base: ScenarioSpec
    axes: Tuple[AxisSpec, ...] = ()
    n_lanes: int = 16
    base_seed: int = 0
    mode: str = "grid"              # "grid" | "random"
    sample_seed: int = 0

    def __post_init__(self):
        if self.mode not in ("grid", "random"):
            raise ValueError(f"unknown ensemble mode {self.mode!r}")
        if self.n_lanes < 1:
            raise ValueError("n_lanes must be >= 1")
        object.__setattr__(self, "axes", tuple(self.axes))

    # ------------------------------------------------------------ compilation
    def combos(self) -> List[Dict[str, object]]:
        """The per-lane axis assignments, lane order fixed by construction.
        Lane 0 of a seed sweep is always the unperturbed (base_seed) world —
        the lane the bit-identity gate replays against the scalar engine."""
        if not self.axes:
            return [{"seed": self.base_seed + i} for i in range(self.n_lanes)]
        if self.mode == "grid":
            prod = itertools.product(*(a.values for a in self.axes))
            out = [dict(zip((a.name for a in self.axes), vals))
                   for vals in itertools.islice(prod, self.n_lanes)]
            return out
        rng = np.random.default_rng([self.sample_seed, 0x454E53])  # "ENS"
        out = []
        for _ in range(self.n_lanes):
            out.append({a.name: a.values[int(rng.integers(len(a.values)))]
                        for a in self.axes})
        return out

    def lane_specs(self) -> List[Tuple[ScenarioSpec, int, Dict[str, object]]]:
        """One ``(spec, seed, label)`` per lane."""
        lanes = []
        for combo in self.combos():
            spec, seed = self.base, self.base_seed
            for axis, value in combo.items():
                spec, s = apply_axis(spec, axis, value)
                if s is not None:
                    seed = s
            lanes.append((spec, seed, combo))
        return lanes
