"""Ensemble CLI.

    PYTHONPATH=src python -m repro.ensemble.run --ensemble ensemble-paper-bands \
        [--lanes N] [--scale S] [--datasets N] [--backend numpy|jax|pallas] \
        [--search [--objective sim_days] [--checkpoint FILE] [--chunk K]] \
        [--json out.json] [--verbose]
    PYTHONPATH=src python -m repro.ensemble.run --ensemble <name> --check-lane0
    PYTHONPATH=src python -m repro.ensemble.run --list

``--check-lane0`` is the bit-identity gate CI runs: lane 0 of the ensemble
replays through the array lanes engine AND through the scalar event engine,
and the two trajectories — iteration count, float-exact sim days, fault and
quarantine counters, per-replica bytes, succeeded-set digest — must match
exactly (the numpy backend is the reference; jax/Pallas backends are
allowed float64 round-off drift and are gated elementwise in tests, not
here).  Exit code 4 on any mismatch.

``--search`` runs the checkpointed search driver instead of a plain band
reduction: lanes evaluate in ``--chunk``-sized pieces, progress persists to
``--checkpoint`` after every chunk, and the report names the winning lane
by ``--objective``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional, Sequence

from repro.ensemble.engine import run_ensemble, scalar_lane
from repro.ensemble.search import SearchDriver
from repro.ensemble.spec import EnsembleSpec
from repro.scenarios.registry import get_scenario, list_ensembles

EXIT_MISMATCH = 4

#: the trajectory fields the lane-0 gate compares (LaneResult attributes)
GATE_FIELDS = ("iterations", "sim_days", "faults_total", "quarantined",
               "bytes_at", "succeeded_digest", "timed_out")


def _get_ensemble(name: str, lanes: Optional[int]) -> EnsembleSpec:
    spec = get_scenario(name)
    if not isinstance(spec, EnsembleSpec):
        raise SystemExit(f"{name!r} is not an ensemble scenario; "
                         f"available: {', '.join(list_ensembles())}")
    if lanes is not None:
        spec = dataclasses.replace(spec, n_lanes=lanes)
    return spec


def check_lane0(espec: EnsembleSpec, scale: float,
                n_datasets: Optional[int], backend: str) -> dict:
    """Replay lane 0 through both engines and diff the trajectories.
    Returns ``{"match": bool, "mismatches": {...}, ...}``."""
    lane0 = dataclasses.replace(espec, n_lanes=1)
    ens = run_ensemble(lane0, scale=scale, n_datasets=n_datasets,
                       backend=backend)
    spec, seed, label = espec.lane_specs()[0]
    ref = scalar_lane(spec, seed, label, scale, n_datasets)
    got = ens.lane(0)
    mism = {}
    for f in GATE_FIELDS:
        a, b = getattr(ref, f), getattr(got, f)
        if a != b:
            mism[f] = {"scalar": a, "ensemble": b}
    return {"ensemble": espec.name, "engine": ens.engine,
            "backend": ens.backend, "seed": seed,
            "match": not mism, "mismatches": mism}


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(prog="repro.ensemble.run")
    p.add_argument("--ensemble", help="registered ensemble name")
    p.add_argument("--list", action="store_true",
                   help="list registered ensembles and exit")
    p.add_argument("--lanes", type=int, default=None,
                   help="override the ensemble's lane count")
    p.add_argument("--scale", type=float, default=0.01)
    p.add_argument("--datasets", type=int, default=None)
    p.add_argument("--backend", default="numpy",
                   choices=("numpy", "jax", "pallas"))
    p.add_argument("--check-lane0", action="store_true",
                   help="bit-identity gate: diff lane 0 vs the scalar engine")
    p.add_argument("--search", action="store_true",
                   help="run the checkpointed search driver")
    p.add_argument("--objective", default="sim_days")
    p.add_argument("--maximize", action="store_true")
    p.add_argument("--checkpoint", default=None,
                   help="search progress file (resume by re-running)")
    p.add_argument("--chunk", type=int, default=16)
    p.add_argument("--json", dest="json_out", default=None)
    p.add_argument("--verbose", action="store_true")
    args = p.parse_args(argv)

    if args.list:
        for name in list_ensembles():
            spec = get_scenario(name)
            axes = ", ".join(a.name for a in spec.axes) or "seed sweep"
            print(f"{name:28s} lanes={spec.n_lanes:<4d} [{axes}]")
        return 0
    if not args.ensemble:
        p.error("--ensemble NAME required (or --list)")

    espec = _get_ensemble(args.ensemble, args.lanes)
    t0 = time.perf_counter()

    if args.check_lane0:
        out = check_lane0(espec, args.scale, args.datasets, args.backend)
        out["wall_s"] = round(time.perf_counter() - t0, 3)
        print(json.dumps(out, indent=2))
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(out, f, indent=2)
        if not out["match"]:
            print("lane-0 trajectory MISMATCH vs scalar engine",
                  file=sys.stderr)
            return EXIT_MISMATCH
        return 0

    if args.search:
        def progress(k, n):
            if args.verbose:
                print(f"  {k}/{n} lanes", file=sys.stderr)
        driver = SearchDriver(espec, scale=args.scale,
                              n_datasets=args.datasets, backend=args.backend,
                              objective=args.objective,
                              minimize=not args.maximize,
                              checkpoint=args.checkpoint, chunk=args.chunk)
        outcome = driver.run(progress=progress)
        out = outcome.to_json()
        out["wall_s"] = round(time.perf_counter() - t0, 3)
    else:
        res = run_ensemble(espec, scale=args.scale, n_datasets=args.datasets,
                           backend=args.backend)
        out = res.to_json()
        out["wall_s"] = round(time.perf_counter() - t0, 3)
        if not args.verbose:
            out.pop("lanes")

    print(json.dumps(out, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
