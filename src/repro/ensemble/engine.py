"""Ensemble driver: compile an ``EnsembleSpec``, run every lane, reduce.

Dispatch: if the base spec (and therefore every lane — axes never add
subsystems the base lacks, except ``policy.*`` axes, which are checked per
lane) is lane-capable, all lanes run in one ``LanesEngine`` lockstep pass;
otherwise each lane is an independent scalar replay through the event
engine — same trajectories, no array speedup.  ``force_scalar=True``
requests the fallback explicitly (the bit-identity gate uses it to produce
the reference side)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.pause import DAY
from repro.core.snapshot import succeeded_digest
from repro.ensemble.lanes import (LaneResult, LanesEngine, lane_capable,
                                  numpy_segment)
from repro.ensemble.reduce import quantile_bands
from repro.ensemble.spec import EnsembleSpec


@dataclass
class EnsembleResult:
    name: str
    n_lanes: int
    engine: str                    # "lanes" | "scalar"
    backend: str                   # "numpy" | "jax" | "pallas" (lanes only)
    lanes: List[LaneResult]
    bands: Dict[str, Dict[str, float]]

    def lane(self, i: int) -> LaneResult:
        return self.lanes[i]

    def to_json(self) -> dict:
        return {
            "name": self.name, "n_lanes": self.n_lanes,
            "engine": self.engine, "backend": self.backend,
            "bands": self.bands,
            "lanes": [{"seed": r.seed, "label": r.label,
                       "iterations": r.iterations, "sim_days": r.sim_days,
                       "faults_total": r.faults_total,
                       "quarantined": r.quarantined,
                       "timed_out": r.timed_out,
                       "succeeded_digest": r.succeeded_digest}
                      for r in self.lanes],
        }


def _segment_fn(backend: str):
    if backend == "numpy":
        return numpy_segment
    if backend in ("jax", "pallas"):
        from repro.ensemble.batch import make_segment_fn
        return make_segment_fn(backend)
    raise ValueError(f"unknown ensemble backend {backend!r}")


def scalar_lane(spec, seed: int, label: dict, scale: float,
                n_datasets: Optional[int]) -> LaneResult:
    """One lane as a plain scalar replay (the fallback and reference path).

    Accepts any spec with a ``build`` method the event engine can drive —
    single-campaign ``ScenarioSpec``s and ``FederationSpec``s (whose lanes
    reduce the per-member reports into one row: ``sim_days`` is the
    federation span, counters sum over members, and the digest chains the
    member digests in member order)."""
    import hashlib

    from repro.scenarios.events import EngineStats, run_world
    stats = EngineStats()
    world = spec.build(scale=scale, seed=seed, n_datasets=n_datasets)
    report = run_world(world, engine="events", stats=stats)
    if hasattr(report, "members"):                       # FederationReport
        members = list(report.members.values())
        bytes_at: Dict[str, int] = {}
        for m in members:
            for k, v in m.bytes_at.items():
                bytes_at[k] = bytes_at.get(k, 0) + int(v)
        h = hashlib.sha256()
        for rt in world.runtimes:
            h.update(f"{rt.label}|{succeeded_digest(rt.table)}\n".encode())
        timed_out = any(
            report.finished_day[lbl] >= mem.start_day + mem.scenario.max_days
            for lbl, mem in zip(report.members, spec.members))
        return LaneResult(
            seed=seed, label=dict(label), iterations=stats.iterations,
            sim_days=report.span_days,
            faults_total=sum(m.faults_total for m in members),
            quarantined=sum(m.quarantined for m in members),
            bytes_at=bytes_at, succeeded_digest=h.hexdigest(),
            timed_out=timed_out)
    return LaneResult(
        seed=seed, label=dict(label), iterations=stats.iterations,
        sim_days=report.duration_days, faults_total=report.faults_total,
        quarantined=report.quarantined,
        bytes_at={k: int(v) for k, v in report.bytes_at.items()},
        succeeded_digest=succeeded_digest(world.table),
        timed_out=report.duration_days >= spec.max_days)


def run_ensemble(espec: EnsembleSpec, scale: float = 1.0,
                 n_datasets: Optional[int] = None, backend: str = "numpy",
                 force_scalar: bool = False,
                 metrics: Sequence[str] = ("sim_days", "faults_total",
                                           "quarantined")) -> EnsembleResult:
    lanes = espec.lane_specs()
    capable = (not force_scalar
               and all(lane_capable(spec)[0] for spec, _, _ in lanes))
    if capable:
        eng = LanesEngine(lanes, scale=scale, n_datasets=n_datasets,
                          segment_fn=_segment_fn(backend))
        results = eng.run()
        mode = "lanes"
    else:
        results = [scalar_lane(spec, seed, label, scale, n_datasets)
                   for spec, seed, label in lanes]
        mode, backend = "scalar", "numpy"
    return EnsembleResult(name=espec.name, n_lanes=len(results), engine=mode,
                          backend=backend, lanes=results,
                          bands=quantile_bands(results, metrics=metrics))
