"""The array lanes engine: N perturbed campaign worlds in lockstep.

One ``LanesEngine`` holds L independent campaign worlds as dense
``[lane, row]`` numpy arrays (rows are the transfer table's (dataset,
destination) pairs in canonical sorted order — exactly ``TransferTable.all()``
order) and advances all of them together: one lockstep outer iteration of the
engine performs, for every live lane, precisely the work one iteration of the
scalar event-driven driver (``repro.scenarios.events.run_world``) performs for
one world.  Each lane advances by its OWN next-event ``dt`` on its own clock,
so lane ``l``'s iteration count, event times, and trajectory equal a solo
scalar run of the same spec/seed — the lockstep is over iteration *index*,
not simulated time.

Bit-identity by construction: every arithmetic expression in the hot path is
the SAME code the scalar engine runs —

* ``consume_stall`` / ``advance_segment`` (``core.transport``) advance the
  mover pool;
* ``fair_share_rates`` (``core.routes``) prices routes (here over
  ``[lane, route]`` arrays instead of scalars);
* ``FaultInjector.transient_marks`` (``core.faults``) is called on a real
  per-lane injector at each submission, in the exact submission order the
  scalar scheduler produces;
* ``retry_disposition`` (``core.scheduler``) maps FAILED polls to
  retry-vs-quarantine.

The scalar scheduler's lazily-validated heaps are replaced by eligibility
masks + prefix-sum first-k selection over the sorted row order — equivalent
because heap pops are validated against the live row and (with ≤ 2 replicas)
relay donors are pure functions of table state.  The engine therefore
*refuses* specs it cannot reproduce exactly (see ``lane_capable``): control
plane, demand, scrub, top-ups, or > 2 replicas fall back to scalar replays
in ``repro.ensemble.engine``.

Deliberate omissions (documented, trajectory-neutral): per-day timeline
snapshots, notification message lists, and flow telemetry are not maintained
— none of them feed the trajectory, the bit-identity tuple, or the band
metrics.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import FaultInjector
from repro.core.campaign import build_catalog
from repro.core.pause import DAY
from repro.core.routes import fair_share_rates
from repro.core.scheduler import retry_disposition
from repro.core.snapshot import trajectory_summary  # noqa: F401  (format ref)
from repro.core.transport import (UNREADABLE_HALT_FRACTION, advance_segment,
                                  consume_stall)
from repro.scenarios.events import MAX_STEP_S, MIN_STEP_S

import hashlib

# row / transfer status codes (array-friendly mirror of transfer_table.Status)
NULL, QUEUED, ACTIVE, PAUSED, SUCCEEDED, FAILED, QUARANTINED, PAD = range(8)
_STATUS_NAME = {NULL: "NULL", QUEUED: "QUEUED", ACTIVE: "ACTIVE",
                PAUSED: "PAUSED", SUCCEEDED: "SUCCEEDED", FAILED: "FAILED",
                QUARANTINED: "QUARANTINED", PAD: "PAD"}
_OUTSTANDING = (NULL, QUEUED, ACTIVE, PAUSED, FAILED)
_OCCUPYING = (ACTIVE, QUEUED, PAUSED)
_RETRYABLE = (NULL, FAILED)
_TERMINAL = (SUCCEEDED, FAILED)


def _status_lut(codes) -> np.ndarray:
    """[8] bool lookup table: ``lut[status]`` == ``status in codes`` — the
    hot-path replacement for ``np.isin`` over the tiny status alphabet."""
    lut = np.zeros(8, dtype=bool)
    lut[list(codes)] = True
    return lut


_OUTSTANDING_LUT = _status_lut(_OUTSTANDING)
_OCCUPYING_LUT = _status_lut(_OCCUPYING)
_RETRYABLE_LUT = _status_lut(_RETRYABLE)
_TERMINAL_LUT = _status_lut(_TERMINAL)

_BIG = np.int64(2 ** 62)


def lane_capable(spec) -> Tuple[bool, str]:
    """Can ``spec`` run on the array lanes engine bit-identically?  Returns
    ``(ok, reason)``; the reason names the first disqualifying feature.

    The limits are exactness limits, not laziness: the control plane, demand
    and scrub engines mutate scheduling state through event-driven Python
    the array engine does not model, and with > 2 replicas the scalar
    scheduler's relay-donor bucketing is historical (donor chosen at enqueue
    time), not a pure function of table state."""
    if not hasattr(spec, "replicas"):
        return False, "not a single-campaign ScenarioSpec"
    if getattr(spec, "members", None) is not None:
        return False, "federations need the shared-transport scalar path"
    if len(spec.replicas) != 2:
        return False, "relay donor bucketing is only pure for 2 replicas"
    if spec.policy.enabled:
        return False, "control plane (bundling/tuning) is event-driven"
    if spec.demand.enabled:
        return False, "demand engine is event-driven"
    if spec.scrub.enabled:
        return False, "scrub engine is event-driven"
    if spec.obs.enabled:
        return False, "flight recorder traces scalar row transitions"
    if spec.top_ups:
        return False, "incremental top-ups mutate the catalog mid-run"
    return True, ""


# A segment-step backend: (t, bytes_done, rate, bound) -> (t_left, new_bytes,
# adv, moved, hit) over [lane, row] float64 arrays.  numpy default is the
# bit-exact reference; repro.ensemble.batch provides jax.vmap and Pallas
# implementations validated against it.
SegmentFn = Callable[[np.ndarray, np.ndarray, np.ndarray, np.ndarray],
                     Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                           np.ndarray]]


def numpy_segment(t, bytes_done, rate, bound):
    return advance_segment(t, bytes_done, rate, bound)


@dataclass
class LaneResult:
    """One lane's outcome in the scalar report vocabulary."""
    seed: int
    label: Dict[str, object]
    iterations: int
    sim_days: float
    faults_total: int
    quarantined: int
    bytes_at: Dict[str, int]
    succeeded_digest: str
    timed_out: bool

    def trajectory(self) -> dict:
        """The bit-identity tuple, field-for-field the dict
        ``repro.core.snapshot.trajectory_summary`` produces."""
        return {"iterations": self.iterations, "sim_days": self.sim_days,
                "faults_total": self.faults_total,
                "quarantined": self.quarantined,
                "bytes_at": dict(self.bytes_at),
                "succeeded_digest": self.succeeded_digest}


class LanesEngine:
    """Build L worlds from ``(spec, seed)`` pairs and run them in lockstep.

    ``lane_specs`` is a sequence of ``(ScenarioSpec, seed, label)`` tuples;
    every spec must share the base spec's topology (site names, route pairs,
    source, replicas) — perturbation axes change *numbers*, never shape.
    """

    def __init__(self, lane_specs: Sequence[Tuple[object, int, dict]],
                 scale: float = 1.0, n_datasets: Optional[int] = None,
                 segment_fn: SegmentFn = numpy_segment):
        if not lane_specs:
            raise ValueError("no lanes")
        for spec, _, _ in lane_specs:
            ok, why = lane_capable(spec)
            if not ok:
                raise ValueError(f"spec {spec.name!r} not lane-capable: {why}")
        self.segment_fn = segment_fn
        self.lane_specs = list(lane_specs)
        base = lane_specs[0][0]
        self.site_names = [s.name for s in base.sites]
        self.site_id = {n: i for i, n in enumerate(self.site_names)}
        self.route_pairs = [(r.source, r.destination) for r in base.routes]
        self.source_name = base.source
        self.replicas = tuple(base.replicas)          # policy priority order
        self.dst_names = sorted(self.replicas)        # row (table) order
        for spec, _, _ in lane_specs:
            if ([s.name for s in spec.sites] != self.site_names
                    or [(r.source, r.destination) for r in spec.routes]
                    != self.route_pairs
                    or spec.source != self.source_name
                    or tuple(spec.replicas) != self.replicas):
                raise ValueError("lane specs must share the base topology")
        self._build(scale, n_datasets)

    # ------------------------------------------------------------------ build
    def _build(self, scale: float, n_datasets: Optional[int]) -> None:
        L = len(self.lane_specs)
        nS, nRt = len(self.site_names), len(self.route_pairs)
        src_id = self.site_id[self.source_name]
        n_rep = 2

        # per-lane worlds: catalogs (jagged), graph numbers, calendars
        self.injectors: List[FaultInjector] = []
        self.row_paths: List[List[str]] = []          # [L][R_l]
        self.ds_paths: List[List[str]] = []           # [L][D_l]
        lane_rows: List[list] = []
        self.seeds = np.empty(L, dtype=np.int64)
        self.max_retries = np.empty(L, dtype=np.int64)
        self.backoff_s = np.empty(L)
        self.fault_cost = np.empty(L)
        self.human_fix_s = np.empty(L)
        self.task_setup = np.empty(L)
        self.deadline = np.empty(L)
        self.max_active = np.empty(L, dtype=np.int64)
        self.route_bw = np.empty((L, nRt))
        self.read_bw = np.empty((L, nS))
        self.write_bw = np.empty((L, nS))
        self.knee = np.full((L, nS), np.inf)
        self.scan_rate_site = np.empty((L, nS))
        self.scan_limit = np.empty((L, nS), dtype=np.int64)
        win_s: List[List[List[float]]] = []           # [L][site][window]
        win_e: List[List[List[float]]] = []

        # seed sweeps reuse ONE spec across every lane: build its graph and
        # maintenance calendar once, not per lane (pure functions of the spec)
        graph_cache: Dict[int, object] = {}
        wins_cache: Dict[int, Tuple[list, list]] = {}

        for l, (spec, seed, _) in enumerate(self.lane_specs):
            self.seeds[l] = seed
            f = spec.faults
            self.injectors.append(FaultInjector(
                seed, transient_per_tb=f.transient_per_tb,
                fragility_tail=f.fragility_tail))
            self.max_retries[l] = f.max_retries
            self.backoff_s[l] = f.backoff_s
            self.fault_cost[l] = f.fault_retry_cost_s
            self.human_fix_s[l] = spec.human_fix_days * DAY
            self.task_setup[l] = float(spec.task_setup_s)
            self.deadline[l] = spec.max_days * DAY
            self.max_active[l] = spec.max_active_per_route
            graph = graph_cache.get(id(spec))
            if graph is None:
                graph = graph_cache[id(spec)] = spec.build_graph()
            for j, name in enumerate(self.site_names):
                s = graph.sites[name]
                self.read_bw[l, j] = s.read_bw
                self.write_bw[l, j] = s.write_bw
                if s.concurrency_knee is not None:
                    self.knee[l, j] = s.concurrency_knee
                self.scan_rate_site[l, j] = s.scan_files_per_s
                self.scan_limit[l, j] = s.scan_mem_limit_files
            for j, pair in enumerate(self.route_pairs):
                self.route_bw[l, j] = graph.routes[pair].bandwidth
            cfg = spec.to_campaign_config(scale=scale, seed=seed,
                                          n_datasets=n_datasets)
            catalog = build_catalog(cfg, graph)
            paths = sorted(catalog)
            self.ds_paths.append(paths)
            rows = [(p, d) for p in paths for d in self.dst_names]
            lane_rows.append([(p, d, catalog[p]) for p, d in rows])
            self.row_paths.append([p for p, _ in rows])
            wins = wins_cache.get(id(spec))
            if wins is None:
                pause = spec.build_pause()
                wins = wins_cache[id(spec)] = (
                    [[w.start for w in pause.windows(n)]
                     for n in self.site_names],
                    [[w.end for w in pause.windows(n)]
                     for n in self.site_names])
            win_s.append(wins[0])
            win_e.append(wins[1])

        self.L = L
        self.n_rep = n_rep
        self.R = R = max(len(rows) for rows in lane_rows)
        self.D = D = R // n_rep
        self.src_site = src_id
        # route id lookup: (src site, dst site) -> route index, -1 if absent
        self.route_id = np.full((nS, nS), -1, dtype=np.int64)
        for j, (a, b) in enumerate(self.route_pairs):
            self.route_id[self.site_id[a], self.site_id[b]] = j
        self.route_src = np.array([self.site_id[a]
                                   for a, _ in self.route_pairs])
        self.route_dst = np.array([self.site_id[b]
                                   for _, b in self.route_pairs])
        # [route, site] 0/1 indicators: a route's mover count contributes to
        # exactly its endpoint sites' loads, so per-site loads are an exact
        # integer matmul away from per-route counts
        self.src_ind = np.zeros((nRt, nS), dtype=np.int64)
        self.dst_ind = np.zeros((nRt, nS), dtype=np.int64)
        self.src_ind[np.arange(nRt), self.route_src] = 1
        self.dst_ind[np.arange(nRt), self.route_dst] = 1

        # static per-row arrays (PAD-padded to the widest lane)
        self.pad = np.ones((L, R), dtype=bool)
        self.nbytes = np.zeros((L, R), dtype=np.int64)
        self.files = np.zeros((L, R), dtype=np.int64)
        self.unreadable = np.zeros((L, R), dtype=bool)
        self.dst_id = np.zeros((L, R), dtype=np.int64)
        self.ds_idx = np.zeros((L, R), dtype=np.int64)
        for l, rows in enumerate(lane_rows):
            for r, (p, dname, ds) in enumerate(rows):
                self.pad[l, r] = False
                self.nbytes[l, r] = ds.bytes
                self.files[l, r] = ds.files
                self.unreadable[l, r] = ds.unreadable
                self.dst_id[l, r] = self.site_id[dname]
                self.ds_idx[l, r] = r // n_rep
        self.nbytes_f = self.nbytes.astype(np.float64)
        # sibling row (the dataset's other replica row): 2 replicas -> r ^ 1
        self.sib_idx = np.arange(R) ^ 1
        # pause calendars, padded with inf (a window at inf never matches)
        W = max((len(w) for lw in win_s for w in lw), default=0) or 1
        self.win_start = np.full((L, nS, W), np.inf)
        self.win_end = np.full((L, nS, W), np.inf)
        for l in range(L):
            for j in range(nS):
                ws, we = win_s[l][j], win_e[l][j]
                self.win_start[l, j, :len(ws)] = ws
                self.win_end[l, j, :len(we)] = we
        self.bounds = np.sort(
            np.concatenate([self.win_start, self.win_end], axis=2)
            .reshape(L, -1), axis=1)

        # ---- dynamic state -------------------------------------------------
        # table level
        self.rstatus = np.where(self.pad, PAD, NULL).astype(np.int8)
        self.rsource = np.full((L, R), src_id, dtype=np.int64)
        self.retries = np.zeros((L, R), dtype=np.int64)
        self.rfaults = np.zeros((L, R), dtype=np.int64)
        self.rbytes = np.zeros((L, R), dtype=np.int64)
        self.rrate = np.zeros((L, R))
        self.backoff_until = np.zeros((L, R))
        # transport level (the row's current transfer)
        self.live = np.zeros((L, R), dtype=bool)
        self.phase_move = np.zeros((L, R), dtype=bool)
        self.setup = np.zeros((L, R))
        self.scanleft = np.zeros((L, R))
        self.xbytes = np.zeros((L, R))
        self.actives = np.zeros((L, R))
        self.xfaults = np.zeros((L, R), dtype=np.int64)
        self.stall = np.zeros((L, R))
        self.xstatus = np.full((L, R), ACTIVE, dtype=np.int8)
        self.live_seq = np.full((L, R), _BIG, dtype=np.int64)
        self.marks: List[List[List[float]]] = [
            [[] for _ in range(R)] for _ in range(L)]
        self.marks_head = np.full((L, R), np.inf)
        self.marks_len = np.zeros((L, R), dtype=np.int64)
        # human-fix state per (lane, dataset)
        self.notified = np.zeros((L, D), dtype=bool)
        self.fixedd = np.zeros((L, D), dtype=bool)
        self.fix_at = np.full((L, D), np.nan)
        # loop state
        self.now = np.zeros(L)
        self.last_tick = np.zeros(L)
        self.iterations = np.zeros(L, dtype=np.int64)
        self.alive = np.ones(L, dtype=bool)
        self.finished_at = np.full(L, np.nan)
        self.timed_out = np.zeros(L, dtype=bool)
        self._seq = np.zeros(L, dtype=np.int64)
        self._lanes = np.arange(L)
        # per-row route id, maintained incrementally on submit (rsource only
        # changes there); rows never submitted keep the source route
        self.rid_rows = self.route_id[self.rsource, self.dst_id]
        # event-gate flags: each guards work that is provably a no-op until
        # the corresponding state first appears
        self._any_backoff = False             # no FAILED poll outcome yet
        self._has_notices = False             # no human-fix notification yet
        self._no_unread = not bool(self.unreadable.any())
        self._halt_inf = np.full((L, self.R), np.inf)
        # pause state is a pure function of (now, static windows): refresh
        # whenever the clocks move instead of recomputing per consumer
        self.next_change = None
        self._refresh_pause()

    def _refresh_pause(self) -> None:
        # pause state is constant until some lane's clock reaches its
        # next window boundary (next_change is the EARLIEST bound strictly
        # ahead, so no boundary can fall inside the skipped interval)
        if (self.next_change is not None
                and bool((self.now < self.next_change).all())):
            return
        self.paused_site = self._paused_sites(self.now)
        self.next_change = self._next_pause_change(self.now)

    # ------------------------------------------------------------ small tools
    def _paused_sites(self, now: np.ndarray) -> np.ndarray:
        """[L, site] bool: is each site inside a maintenance window at each
        lane's own clock?  (``start <= now < end``, any window.)"""
        t = now[:, None, None]
        return np.any((self.win_start <= t) & (t < self.win_end), axis=2)

    def _next_pause_change(self, now: np.ndarray) -> np.ndarray:
        """[L]: earliest window boundary strictly after each lane's clock
        (``PauseManager.next_change`` semantics); inf when none remain."""
        later = np.where(self.bounds > now[:, None], self.bounds, np.inf)
        return later.min(axis=1)

    def _paused_rows(self, paused_site: np.ndarray) -> np.ndarray:
        lane = self._lanes[:, None]
        return (paused_site[lane, self.rsource]
                | paused_site[lane, self.dst_id])

    def _notify(self, l: int, r: int) -> None:
        """``Notifier.notify(msg, dataset)``: registers the dataset as
        needing a human fix unless it is already known (fixed or pending)."""
        d = self.ds_idx[l, r]
        if not self.notified[l, d]:
            self.notified[l, d] = True
            self.fixedd[l, d] = False
            self._has_notices = True

    def _halt_bytes(self) -> np.ndarray:
        """[L, R]: the permission-halt byte position, inf when the row is
        readable or its dataset has been fixed."""
        if self._no_unread:
            return self._halt_inf                # shared, read-only
        lane = self._lanes[:, None]
        active = self.unreadable & ~self.fixedd[lane, self.ds_idx]
        return np.where(active, UNREADABLE_HALT_FRACTION * self.nbytes_f,
                        np.inf)

    def _counts_by(self, mask: np.ndarray, idx: np.ndarray,
                   n: int) -> np.ndarray:
        """[L, n] int: per-lane counts of ``mask`` rows bucketed by ``idx``
        (values ≥ n or masked-out rows are dropped)."""
        safe = np.where(mask, idx, n)
        flat = (self._lanes[:, None] * (n + 1) + safe).ravel()
        return (np.bincount(flat, minlength=self.L * (n + 1))
                .reshape(self.L, n + 1)[:, :n])

    def _route_rates(self, movers: np.ndarray) -> np.ndarray:
        """[L, route] float: the tick's fair-share rate per route, the exact
        arithmetic of ``RouteGraph.effective_rate`` via the shared
        ``fair_share_rates``.  Only routes with movers are ever read."""
        nRt = len(self.route_pairs)
        n_route = self._counts_by(movers, self.rid_rows, nRt)
        # site loads: total movers touching each site (readers: none —
        # lane-capable specs have no demand engine); every mover sits on
        # exactly one route, so site loads are the route counts summed per
        # endpoint — an exact integer matmul
        src_load = n_route @ self.src_ind
        dst_load = n_route @ self.dst_ind
        rs, rd = self.route_src, self.route_dst
        return fair_share_rates(
            self.route_bw, self.read_bw[:, rs], self.write_bw[:, rd],
            n_route, src_load[:, rs], dst_load[:, rd],
            self.knee[:, rs], self.knee[:, rd])

    # ---------------------------------------------------------------- submit
    def _submit(self, l: int, r: int, src: int) -> None:
        """``transport.submit`` + table start for one row: the ONLY place the
        lane's fault stream is consumed, in scalar submission order."""
        self.rsource[l, r] = src
        self.rid_rows[l, r] = self.route_id[src, self.dst_id[l, r]]
        self.rstatus[l, r] = ACTIVE
        self.live[l, r] = True
        self.phase_move[l, r] = False
        self.setup[l, r] = self.task_setup[l]
        self.scanleft[l, r] = float(self.files[l, r])
        self.xbytes[l, r] = 0.0
        self.actives[l, r] = 0.0
        self.xfaults[l, r] = 0
        self.stall[l, r] = 0.0
        self.xstatus[l, r] = ACTIVE
        self.live_seq[l, r] = self._seq[l]
        self._seq[l] += 1
        m = self.injectors[l].transient_marks(self.row_paths[l][r],
                                              int(self.nbytes[l, r]))
        self.marks[l][r] = m
        self.marks_head[l, r] = m[0] if m else np.inf
        self.marks_len[l, r] = len(m)

    # ------------------------------------------------------------- scheduler
    def _poll(self, act: np.ndarray) -> None:
        """Scheduler poll pass: map transfer outcomes onto table rows with
        the shared ``retry_disposition`` rule."""
        polled = act[:, None] & _OCCUPYING_LUT[self.rstatus]
        if not polled.any():
            return
        succ = polled & (self.xstatus == SUCCEEDED)
        fail = polled & (self.xstatus == FAILED)
        if succ.any():
            self.rstatus[succ] = SUCCEEDED
            self._record_outcome(succ)
        if fail.any():
            nret, quar = retry_disposition(self.retries,
                                           self.max_retries[:, None])
            quar &= fail
            soft = fail & ~quar
            self.retries[fail] = nret[fail]
            self._record_outcome(fail)
            if quar.any():
                self.rstatus[quar] = QUARANTINED
                for l, r in zip(*np.nonzero(quar)):
                    self._notify(l, r)
            if soft.any():
                self.rstatus[soft] = FAILED
                until = self.now[:, None] + self.backoff_s[:, None]
                self.backoff_until[soft] = np.broadcast_to(
                    until, soft.shape)[soft]
                self._any_backoff = True
        rest = polled & ~succ & ~fail
        if rest.any():
            self.rstatus[rest] = self.xstatus[rest]

    def _record_outcome(self, mask: np.ndarray) -> None:
        """The poll's row update: final byte count, achieved rate over active
        time (``_state_of`` semantics), and the transfer's fault count."""
        self.rbytes[mask] = self.xbytes[mask].astype(np.int64)
        self.rrate[mask] = (self.xbytes[mask]
                            / np.maximum(1e-9, self.actives[mask]))
        self.rfaults[mask] = self.xfaults[mask]

    def _start_batch(self, act: np.ndarray, elig: np.ndarray,
                     slots: np.ndarray, src: int) -> np.ndarray:
        """Start the first-k eligible rows per lane (row order == dataset
        order, the heap's pop order) and return the per-lane count started.
        Field updates are bulk masked stores; only the fault draws walk rows
        one by one (per-lane RNG streams consumed in submission order, the
        bit-identity invariant)."""
        elig = elig & act[:, None]
        if not elig.any():
            return np.zeros(self.L, dtype=np.int64)
        ranks = np.cumsum(elig, axis=1)
        sel = elig & (ranks <= slots[:, None])
        n = sel.sum(axis=1)
        if not n.any():
            return n
        np.copyto(self.rsource, src, where=sel)
        self.rid_rows[sel] = self.route_id[src, self.dst_id[sel]]
        np.copyto(self.rstatus, ACTIVE, where=sel)
        self.live |= sel
        np.copyto(self.phase_move, False, where=sel)
        np.copyto(self.setup, self.task_setup[:, None], where=sel)
        np.copyto(self.scanleft, self.files, where=sel, casting="unsafe")
        np.copyto(self.xbytes, 0.0, where=sel)
        np.copyto(self.actives, 0.0, where=sel)
        np.copyto(self.xfaults, 0, where=sel)
        np.copyto(self.stall, 0.0, where=sel)
        np.copyto(self.xstatus, ACTIVE, where=sel)
        np.copyto(self.live_seq, self._seq[:, None] + ranks - 1, where=sel)
        self._seq += n
        for l, r in zip(*np.nonzero(sel)):
            l, r = int(l), int(r)
            m = self.injectors[l].transient_marks(self.row_paths[l][r],
                                                  int(self.nbytes[l, r]))
            self.marks[l][r] = m
            self.marks_head[l, r] = m[0] if m else np.inf
            self.marks_len[l, r] = len(m)
        return n

    def _retryable_mask(self) -> np.ndarray:
        return _RETRYABLE_LUT[self.rstatus]

    def _readmit(self, act: np.ndarray, dst: int, src_for_start: int,
                 slots_left: np.ndarray, fresh_slots: bool) -> None:
        """Re-admit fixed quarantined rows at ``dst`` (Figure 4 ordering:
        strictly after the pass's ordinary eligibles).  ``fresh_slots``
        mirrors the scalar code: the direct pass decrements a local slot
        counter, the relay pass re-counts occupancy per row."""
        lane = self._lanes[:, None]
        quar = (act[:, None] & (self.rstatus == QUARANTINED)
                & (self.dst_id == dst) & self.fixedd[lane, self.ds_idx])
        if not quar.any():
            return
        self.rstatus[quar] = FAILED
        self.retries[quar] = 0
        for l, r in zip(*np.nonzero(quar)):
            l, r = int(l), int(r)
            if fresh_slots:
                # relay readmission: donor must hold the dataset, and slots
                # are re-counted against the current table
                if self.rstatus[l, self.sib_idx[r]] != SUCCEEDED:
                    continue
                donor = int(self.dst_id[l, self.sib_idx[r]])
                occ = int(np.count_nonzero(
                    _OCCUPYING_LUT[self.rstatus[l]]
                    & (self.rsource[l] == donor) & (self.dst_id[l] == dst)))
                if (self.max_active[l] - occ > 0
                        and not self.backoff_until[l, r] > self.now[l]):
                    self._submit(l, r, donor)
            else:
                if (slots_left[l] > 0
                        and self.rsource[l, r] == src_for_start
                        and not self.backoff_until[l, r] > self.now[l]):
                    self._submit(l, r, src_for_start)
                    slots_left[l] -= 1

    def _sched_step(self, act: np.ndarray) -> None:
        """One Figure-4 pass for every live lane: poll, direct starts
        (primary, then secondaries while the primary has paused rows),
        relays, quarantine re-admissions — in scalar submission order."""
        self._poll(act)
        src = self.src_site
        primary = self.site_id[self.replicas[0]]
        # backoff only changes in the poll above, so one mask serves every
        # pass of this step; readmission needs a fixed quarantined row
        # somewhere, which almost no iteration has
        not_backing = (~(self.backoff_until > self.now[:, None])
                       if self._any_backoff else True)
        fixable = bool((self.rstatus == QUARANTINED).any()
                       and self.fixedd.any())
        # every pass below queries a distinct route, and no submission in an
        # earlier pass lands on a later pass's route — one occupancy count
        # taken here serves them all
        occ_rt = self._counts_by(act[:, None] & _OCCUPYING_LUT[self.rstatus],
                                 self.rid_rows, len(self.route_pairs))

        def slots_for(s: int, d: int) -> np.ndarray:
            return np.maximum(0, self.max_active
                              - occ_rt[:, int(self.route_id[s, d])])
        # 2a: source -> primary.  Re-admissions only happen in a pass that
        # had a slot to begin with (the scalar _start_route returns before
        # its readmit scan when slots <= 0).
        elig = (self._retryable_mask() & (self.rsource == src)
                & (self.dst_id == primary) & not_backing & ~self.pad)
        slots = slots_for(src, primary)
        started = self._start_batch(act, elig, slots, src)
        if fixable:
            self._readmit(act & (slots > 0), primary, src, slots - started,
                          fresh_slots=False)
        # 2c: secondaries while any primary-bound row is paused
        any_paused = (act[:, None] & (self.rstatus == PAUSED)
                      & (self.dst_id == primary)).any(axis=1)
        if any_paused.any():
            for name in self.replicas[1:]:
                sec = self.site_id[name]
                elig = (self._retryable_mask() & (self.rsource == src)
                        & (self.dst_id == sec) & not_backing & ~self.pad)
                slots = slots_for(src, sec)
                started = self._start_batch(any_paused, elig, slots, src)
                if fixable:
                    self._readmit(any_paused & (slots > 0), sec, src,
                                  slots - started, fresh_slots=False)
        # 2d/2e: relays, destination priority order; donor = the sibling
        # replica (unique with 2 replicas).  The scalar relay pass always
        # reaches its readmit scan, so no slot gate here.
        lane = self._lanes[:, None]
        # sibling successes can only appear in the poll, so one mask serves
        # both relay passes
        sib_ok = self.rstatus[lane, self.sib_idx] == SUCCEEDED
        for name in self.replicas:
            dst = self.site_id[name]
            elig = (self._retryable_mask() & (self.dst_id == dst) & sib_ok
                    & not_backing & ~self.pad)
            # all relay rows to dst share one donor site (the other replica)
            donor = int(self.site_id[self.replicas[0]
                                     if name != self.replicas[0]
                                     else self.replicas[1]])
            slots = slots_for(donor, dst)
            self._start_batch(act, elig, slots, donor)
            if fixable:
                self._readmit(act, dst, donor, None, fresh_slots=True)

    # ------------------------------------------------------------ human fixes
    def _apply_human_fixes(self, act: np.ndarray) -> None:
        if not self._has_notices:
            return
        a = act[:, None]
        sched = a & self.notified & ~self.fixedd & np.isnan(self.fix_at)
        if sched.any():
            due = self.now[:, None] + self.human_fix_s[:, None]
            self.fix_at[sched] = np.broadcast_to(due, sched.shape)[sched]
        fix = (a & ~np.isnan(self.fix_at)
               & (self.now[:, None] >= self.fix_at) & ~self.fixedd)
        self.fixedd[fix] = True

    # ------------------------------------------------------------- next event
    def _next_event_dt(self, act: np.ndarray) -> np.ndarray:
        # min over positive candidates; absent state (no backoffs, no fix
        # schedule) contributes inf, so its candidate is skipped outright
        inf = np.inf
        hint = self._transport_hint()
        dt = np.where(hint > 0, hint, inf)
        nc = self.next_change - self.now
        dt = np.minimum(dt, np.where(nc > 0, nc, inf))
        if self._any_backoff:
            nb = (np.where(self.backoff_until > self.now[:, None],
                           self.backoff_until, inf).min(axis=1) - self.now)
            dt = np.minimum(dt, np.where(nb > 0, nb, inf))
        if self._has_notices:
            fx = (np.where(np.isnan(self.fix_at)
                           | (self.fix_at <= self.now[:, None]),
                           inf, self.fix_at).min(axis=1) - self.now)
            dt = np.minimum(dt, np.where(fx > 0, fx, inf))
        return np.maximum(MIN_STEP_S, np.minimum(dt, MAX_STEP_S))

    def _transport_hint(self) -> np.ndarray:
        """Vectorized ``SimulatedTransport.next_event_hint`` — including its
        two early returns: a pending scan OOM pins the hint to 1.0, and the
        FIRST at-halt mover (submission order) pins it to
        ``max(stall_left, 1.0)``, discarding every other candidate."""
        L = self.L
        lane = self._lanes[:, None]
        row_np = self.live & ~self._paused_rows(self.paused_site)
        scanners = row_np & ~self.phase_move
        movers = row_np & self.phase_move
        best = np.full(L, np.inf)
        # scanners
        if scanners.any():
            n_scan = self._counts_by(scanners, self.rsource,
                                     len(self.site_names))
            srate = self.scan_rate_site / np.maximum(1, n_scan)
            rate_row = srate[lane, self.rsource]
            cand = np.where(scanners & (rate_row > 0),
                            self.setup + np.maximum(0.0,
                                                    self.scanleft / rate_row),
                            np.inf)
            best = cand.min(axis=1)
            oom = (scanners
                   & (self.files > self.scan_limit[lane, self.rsource]))
            oom_lane = oom.any(axis=1)
        else:
            oom_lane = np.zeros(L, dtype=bool)
        # movers
        halt = self._halt_bytes()
        if movers.any():
            rr = self._route_rates(movers)
            rid = self.rid_rows
            rate_row = np.where(movers & (rid >= 0),
                                rr[lane, np.clip(rid, 0, None)], 0.0)
            mv = movers & (rate_row > 0)
            halt_active = np.isfinite(halt)
            target = np.where(halt_active, halt, self.nbytes_f)
            at_halt = mv & (target <= self.xbytes)
            # pending stall: every fault mark before the target costs one
            # retry stall (marks are all < bytes; only an active halt needs
            # a per-row prefix count)
            n_below = self.marks_len.astype(np.float64)
            special = mv & halt_active & ~at_halt & (self.marks_len > 0)
            for l, r in zip(*np.nonzero(special)):
                n_below[l, r] = bisect.bisect_left(self.marks[int(l)][int(r)],
                                                   target[l, r])
            cand = np.where(mv & ~at_halt,
                            self.stall + self.fault_cost[:, None] * n_below
                            + (target - self.xbytes) / rate_row, np.inf)
            best = np.minimum(best, cand.min(axis=1))
            halt_lane = at_halt.any(axis=1)
            if halt_lane.any():
                seqs = np.where(at_halt, self.live_seq, _BIG)
                first = seqs.argmin(axis=1)
                halt_hint = np.maximum(self.stall[self._lanes, first], 1.0)
                best = np.where(halt_lane, halt_hint, best)
        best = np.where(oom_lane, 1.0, best)
        return best

    # ------------------------------------------------------------------ tick
    def _tick(self, act: np.ndarray) -> None:
        dt = self.now - self.last_tick
        self.last_tick = self.now.copy()
        act = act & (dt > 0)
        if not act.any():
            return
        lane = self._lanes[:, None]
        live = self.live & act[:, None]
        paused_row = self._paused_rows(self.paused_site)
        self.xstatus[live & paused_row] = PAUSED
        running = live & ~paused_row
        self.xstatus[running] = ACTIVE
        scanners = running & ~self.phase_move
        movers = running & self.phase_move        # pre-scan classification
        # --- metadata scans ------------------------------------------------
        if scanners.any():
            n_scan = self._counts_by(scanners, self.rsource,
                                     len(self.site_names))
            srate = self.scan_rate_site / np.maximum(1, n_scan)
            rate_row = srate[lane, self.rsource]
            oom = (scanners
                   & (self.files > self.scan_limit[lane, self.rsource]))
            if oom.any():
                self.xstatus[oom] = FAILED
                self.xfaults[oom] += 1
                for l, r in zip(*np.nonzero(oom)):
                    self._notify(int(l), int(r))
            ok = scanners & ~oom
            dtc = np.broadcast_to(dt[:, None], ok.shape)
            used = np.minimum(self.setup, dtc)
            avail = dtc - used
            np.subtract(self.setup, used, out=self.setup, where=ok)
            adv = ok & (avail > 0)
            np.subtract(self.scanleft, rate_row * avail,
                        out=self.scanleft, where=adv)
            self.phase_move |= adv & (self.scanleft <= 0)
        # --- data movement -------------------------------------------------
        if movers.any():
            rr = self._route_rates(movers)
            rid = self.rid_rows
            rate_row = np.where(movers & (rid >= 0),
                                rr[lane, np.clip(rid, 0, None)], 0.0)
            halt = self._halt_bytes()
            bound = np.minimum(self.nbytes_f, halt)
            bound = np.where(self.marks_head < bound, self.marks_head, bound)
            rem, new_stall = consume_stall(dt[:, None], self.stall)
            _, new_bd, adv, _moved, hit = self.segment_fn(
                rem, self.xbytes, rate_row, bound)
            fast = movers & ((rem <= 1e-9)
                             | ((rate_row > 0) & (self.xbytes < halt) & ~hit))
            # bulk completion: a boundary hit whose bound is the row's full
            # byte count with no pending mark is the walk's one-iteration
            # SUCCEEDED exit — same expressions, no per-row python
            done = (movers & ~fast & hit & (rem > 1e-9)
                    & (bound == self.nbytes_f) & (self.xbytes < halt))
            # bulk fault absorption: a hit on a mark boundary whose retry
            # stall swallows the rest of the tick is the walk's
            # pop-mark/add-stall/consume-stall exit — closed form, same ops
            t_left = rem - adv
            cost = self.fault_cost[:, None]
            mark1 = (movers & ~fast & ~done & hit & (rem > 1e-9)
                     & (self.marks_head == bound) & (self.xbytes < halt)
                     & (self.marks_head < np.minimum(self.nbytes_f, halt))
                     & ((t_left <= 1e-9) | (cost >= t_left)))
            slow = movers & ~fast & ~done & ~mark1
            fast |= done
            np.copyto(self.stall, new_stall, where=fast)
            upd = (fast & (rem > 1e-9)) | mark1
            np.copyto(self.xbytes, new_bd, where=upd)
            np.add(self.actives, adv, out=self.actives, where=upd)
            self.xstatus[done] = SUCCEEDED
            if mark1.any():
                np.add(self.xfaults, 1, out=self.xfaults, where=mark1)
                np.copyto(self.stall,
                          np.where(t_left <= 1e-9, cost, cost - t_left),
                          where=mark1)
                for l, r in zip(*np.nonzero(mark1)):
                    m = self.marks[int(l)][int(r)]
                    m.pop(0)
                    self.marks_head[l, r] = m[0] if m else np.inf
                    self.marks_len[l, r] -= 1
            for l, r in zip(*np.nonzero(slow)):
                self._walk(int(l), int(r), float(dt[l]),
                           float(rate_row[l, r]))
        # --- evict terminal transfers ---------------------------------------
        self.live &= ~_TERMINAL_LUT[self.xstatus]

    def _walk(self, l: int, r: int, dt: float, rate: float) -> None:
        """Per-row mirror of ``SimulatedTransport._advance_mover`` — the
        segment-exact walk for movers that cross a byte boundary this tick.
        Same statements, same order, python-float arithmetic."""
        marks = self.marks[l][r]
        halt: Optional[float] = None
        d = self.ds_idx[l, r]
        if self.unreadable[l, r] and not self.fixedd[l, d]:
            halt = UNREADABLE_HALT_FRACTION * int(self.nbytes[l, r])
        nbytes = int(self.nbytes[l, r])
        bytes_done = float(self.xbytes[l, r])
        active_s = float(self.actives[l, r])
        stall = float(self.stall[l, r])
        faults = int(self.xfaults[l, r])
        cost = float(self.fault_cost[l])
        t = dt
        while t > 1e-9:
            if stall > 0:
                used = min(stall, t)
                stall -= used
                t -= used
                continue
            if halt is not None and bytes_done >= halt:
                bytes_done = halt
                self.xstatus[l, r] = FAILED
                faults += 1
                self._notify(l, r)
                break
            if rate <= 0:
                break
            nxt = float(nbytes)
            if halt is not None:
                nxt = min(nxt, halt)
            if marks and marks[0] < nxt:
                nxt = marks[0]
            need = max(0.0, nxt - bytes_done) / rate
            if need > t:
                bytes_done += rate * t
                active_s += t
                t = 0.0
                break
            bytes_done = nxt
            active_s += need
            t -= need
            if marks and marks[0] <= nxt:
                marks.pop(0)
                faults += 1
                stall += cost
                continue
            if halt is not None and nxt >= halt:
                continue
            if nxt >= nbytes:
                bytes_done = float(nbytes)
                self.xstatus[l, r] = SUCCEEDED
                break
        self.xbytes[l, r] = bytes_done
        self.actives[l, r] = active_s
        self.stall[l, r] = stall
        self.xfaults[l, r] = faults
        self.marks_head[l, r] = marks[0] if marks else np.inf
        self.marks_len[l, r] = len(marks)

    # ------------------------------------------------------------------- run
    def _table_done(self, act: np.ndarray) -> np.ndarray:
        outstanding = (_OUTSTANDING_LUT[self.rstatus]
                       & ~self.pad).any(axis=1)
        return act & ~outstanding

    def _finish(self, mask: np.ndarray, timed_out: bool) -> None:
        if not mask.any():
            return
        self.finished_at[mask] = self.now[mask]
        self.timed_out[mask] |= timed_out
        self.alive &= ~mask

    def run(self, max_iterations: int = 1_000_000) -> List[LaneResult]:
        """Drive every lane to completion (events-engine semantics) and
        return per-lane results in lane order."""
        it = 0
        with np.errstate(divide="ignore", invalid="ignore"):
            while self.alive.any():
                it += 1
                if it > max_iterations:
                    raise RuntimeError("lanes engine failed to converge")
                self._finish(self.alive & (self.now >= self.deadline),
                             timed_out=True)
                act = self.alive
                if not act.any():
                    break
                self.iterations[act] += 1
                self._sched_step(act)
                self._apply_human_fixes(act)
                self._finish(self._table_done(act), timed_out=False)
                act = self.alive
                if not act.any():
                    break
                dt = self._next_event_dt(act)
                self.now = np.where(act, self.now + dt, self.now)
                self._refresh_pause()
                self._tick(act)
        return [self._result(l) for l in range(self.L)]

    # ---------------------------------------------------------------- results
    def _result(self, l: int) -> LaneResult:
        succ = (self.rstatus[l] == SUCCEEDED) & ~self.pad[l]
        faults = self.rfaults[l][succ]
        bytes_at = {}
        for name in self.replicas:
            m = succ & (self.dst_id[l] == self.site_id[name])
            bytes_at[name] = int(self.rbytes[l][m].sum())
        spec, seed, label = self.lane_specs[l]
        return LaneResult(
            seed=int(seed), label=dict(label),
            iterations=int(self.iterations[l]),
            sim_days=float(self.finished_at[l]) / DAY,
            faults_total=int(np.sum(faults)) if faults.size else 0,
            quarantined=int(np.count_nonzero(
                (self.rstatus[l] == QUARANTINED) & ~self.pad[l])),
            bytes_at=bytes_at,
            succeeded_digest=self._digest(l),
            timed_out=bool(self.timed_out[l]))

    def _digest(self, l: int) -> str:
        """``repro.core.snapshot.succeeded_digest`` over the lane's rows —
        identical format, identical (dataset, destination) order."""
        h = hashlib.sha256()
        paths = self.row_paths[l]
        for r in range(len(paths)):
            if self.rstatus[l, r] != SUCCEEDED:
                continue
            h.update((f"{paths[r]}|{self.site_names[self.dst_id[l, r]]}|"
                      f"{self.site_names[self.rsource[l, r]]}|"
                      f"{int(self.rfaults[l, r])}|{int(self.retries[l, r])}|"
                      f"{int(self.rbytes[l, r])}|"
                      f"{float(self.rrate[l, r])!r}\n").encode())
        return h.hexdigest()
