"""Parameter search over an ensemble, with progress checkpointing.

``SearchDriver`` evaluates an ``EnsembleSpec``'s lanes in chunks and keeps
a JSON checkpoint of every finished lane, so an interrupted sweep resumes
where it stopped instead of replaying hundreds of worlds.  Lane order is
fixed by ``EnsembleSpec.combos()`` (deterministic in the spec), which is
what makes "skip the first *k* lanes" a sound resume protocol.

The winner is the lane minimizing (or maximizing) one scalar objective —
default ``sim_days``, the campaign-duration metric the paper optimizes —
with ties broken by lane index, so a search is a pure function of
``(espec, scale, n_datasets, objective)``.  ``SearchOutcome.bench_entry``
packages the winner for ``BENCH_scenarios.json`` so CI's regression gate
can hold the line on it.
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.ensemble.engine import EnsembleResult, _segment_fn, scalar_lane
from repro.ensemble.lanes import LaneResult, LanesEngine, lane_capable
from repro.ensemble.reduce import DEFAULT_METRICS, quantile_bands
from repro.ensemble.spec import EnsembleSpec


def _lane_row(idx: int, r: LaneResult) -> dict:
    return {"lane": idx, "seed": r.seed, "label": dict(r.label),
            "iterations": r.iterations, "sim_days": r.sim_days,
            "faults_total": r.faults_total, "quarantined": r.quarantined,
            "timed_out": r.timed_out,
            "succeeded_digest": r.succeeded_digest}


@dataclass
class SearchOutcome:
    """A finished (or resumed-to-finished) search."""
    name: str
    objective: str
    minimize: bool
    rows: List[dict]                    # lane order, one dict per lane
    bands: Dict[str, Dict[str, float]]

    @property
    def winner(self) -> dict:
        sign = 1.0 if self.minimize else -1.0
        return min(self.rows, key=lambda r: (sign * r[self.objective],
                                             r["lane"]))

    def ranking(self) -> List[dict]:
        sign = 1.0 if self.minimize else -1.0
        return sorted(self.rows, key=lambda r: (sign * r[self.objective],
                                                r["lane"]))

    def to_json(self) -> dict:
        return {"name": self.name, "objective": self.objective,
                "minimize": self.minimize, "n_lanes": len(self.rows),
                "winner": self.winner, "bands": self.bands,
                "lanes": self.rows}

    def bench_entry(self) -> dict:
        """The winner as a BENCH_scenarios.json block: the objective value
        plus the band around it, for ``check_regression.py`` to gate."""
        w = self.winner
        return {f"ensemble_{self.name}_{self.objective}":
                float(w[self.objective]),
                f"ensemble_{self.name}_{self.objective}_p95":
                float(self.bands[self.objective]["p95"])}


class SearchDriver:
    """Chunked, resumable evaluation of one ensemble.

    Each chunk of lanes runs through the array lanes engine when every lane
    in it is lane-capable (one lockstep pass), else through scalar replays.
    After every chunk the checkpoint file — ``{"name", "n_total", "done":
    [lane rows]}`` — is atomically rewritten; a fresh driver pointed at the
    same file skips the recorded prefix.  A checkpoint whose ``name`` or
    ``n_total`` disagrees with the spec is ignored (stale file), never
    merged."""

    def __init__(self, espec: EnsembleSpec, scale: float = 1.0,
                 n_datasets: Optional[int] = None, backend: str = "numpy",
                 objective: str = "sim_days", minimize: bool = True,
                 checkpoint: Optional[str] = None, chunk: int = 16,
                 metrics: Sequence[str] = DEFAULT_METRICS):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.espec = espec
        self.scale = scale
        self.n_datasets = n_datasets
        self.backend = backend
        self.objective = objective
        self.minimize = minimize
        self.checkpoint = checkpoint
        self.chunk = chunk
        self.metrics = tuple(metrics)

    # ------------------------------------------------------------ checkpoint
    def _load_done(self) -> List[dict]:
        if not self.checkpoint or not os.path.exists(self.checkpoint):
            return []
        try:
            with open(self.checkpoint) as f:
                state = json.load(f)
        except (OSError, ValueError):
            return []
        if (state.get("name") != self.espec.name
                or state.get("n_total") != self.espec.n_lanes):
            return []
        return list(state.get("done", []))

    def _save_done(self, done: List[dict]) -> None:
        if not self.checkpoint:
            return
        state = {"name": self.espec.name, "n_total": self.espec.n_lanes,
                 "objective": self.objective, "done": done}
        d = os.path.dirname(os.path.abspath(self.checkpoint))
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".ckpt")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(state, f, indent=1)
            os.replace(tmp, self.checkpoint)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # ------------------------------------------------------------------- run
    def _eval_chunk(self, lanes) -> List[LaneResult]:
        if all(lane_capable(spec)[0] for spec, _, _ in lanes):
            eng = LanesEngine(lanes, scale=self.scale,
                              n_datasets=self.n_datasets,
                              segment_fn=_segment_fn(self.backend))
            return eng.run()
        return [scalar_lane(spec, seed, label, self.scale, self.n_datasets)
                for spec, seed, label in lanes]

    def run(self, progress=None) -> SearchOutcome:
        """Evaluate every not-yet-checkpointed lane; return the outcome over
        ALL lanes (checkpointed + fresh).  ``progress`` is an optional
        callable ``(n_done, n_total) -> None``."""
        lanes = self.espec.lane_specs()
        done = self._load_done()
        if done and progress is not None:
            progress(len(done), len(lanes))
        while len(done) < len(lanes):
            lo = len(done)
            batch = lanes[lo:lo + self.chunk]
            results = self._eval_chunk(batch)
            done.extend(_lane_row(lo + i, r) for i, r in enumerate(results))
            self._save_done(done)
            if progress is not None:
                progress(len(done), len(lanes))
        return SearchOutcome(
            name=self.espec.name, objective=self.objective,
            minimize=self.minimize, rows=done,
            bands=quantile_bands(done, metrics=self.metrics))


def run_search(espec: EnsembleSpec, **kw) -> SearchOutcome:
    """One-call convenience wrapper around ``SearchDriver``."""
    return SearchDriver(espec, **kw).run()
