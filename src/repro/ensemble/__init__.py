"""Batched ensemble engine: N seed- or parameter-perturbed campaign worlds
advanced in lockstep by one process over dense ``[lane, row]`` arrays.

Public surface:

* ``EnsembleSpec`` / ``AxisSpec`` (``repro.ensemble.spec``) — declare a base
  ``ScenarioSpec`` plus perturbation axes (seed, fault rates, route
  bandwidths, AIMD constants, ...).
* ``run_ensemble`` (``repro.ensemble.engine``) — run every lane and reduce
  to per-metric quantile bands.  Lane-capable specs run on the array
  engine (``repro.ensemble.lanes``); anything else falls back to per-lane
  scalar replays of the exact same trajectories.
* ``quantile_bands`` (``repro.ensemble.reduce``) — permutation-invariant
  band reduction.
* ``SearchDriver`` (``repro.ensemble.search``) — grid/randomized
  configuration search with progress checkpointing.

Determinism contract: lane 0 of any ensemble whose first lane carries the
base spec/seed reproduces the scalar events-engine trajectory bit-for-bit
(same iteration count, float-exact sim days, identical succeeded-set
digest).  The numpy backend is the reference; the jax/vmap and Pallas
backends are validated against it to float tolerance (XLA may contract
``a*b + c`` to an FMA, so cross-backend bit-identity is not promised).
"""
from repro.ensemble.engine import EnsembleResult, run_ensemble
from repro.ensemble.lanes import LanesEngine, lane_capable
from repro.ensemble.reduce import quantile_bands
from repro.ensemble.search import SearchDriver, SearchOutcome, run_search
from repro.ensemble.spec import AxisSpec, EnsembleSpec

__all__ = ["AxisSpec", "EnsembleSpec", "EnsembleResult", "LanesEngine",
           "SearchDriver", "SearchOutcome", "lane_capable", "quantile_bands",
           "run_ensemble", "run_search"]
