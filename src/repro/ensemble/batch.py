"""Batched backends for the lanes engine's segment step, plus batched fault
draws.

The lanes engine's hot inner operation is ``advance_segment`` over
``[lane, row]`` float64 arrays.  Three interchangeable implementations:

* ``numpy`` — the bit-exact reference (``repro.core.transport``'s own
  module function; the scalar engine runs the same expressions).
* ``jax``   — ``jax.jit(jax.vmap(...))`` of an elementwise per-lane step,
  run under a scoped x64 context (``jax.experimental.enable_x64`` — the
  global flag is never touched, so f32 model code elsewhere is unaffected).
* ``pallas`` — the ``repro.kernels.lane_step`` kernel (interpret mode on
  CPU; set ``interpret=False`` on a real TPU).

The jax/Pallas backends agree with numpy to float64 round-off but NOT
necessarily bit-for-bit: XLA may contract ``bytes_done + rate * t`` into an
FMA.  The determinism contract therefore names numpy the reference backend
— the lane-0 bit-identity gate always runs it — while the accelerated
backends are validated by ``tests/test_ensemble.py`` elementwise against
the reference.

``BatchedFaultInjector`` wraps N independent per-lane ``FaultInjector``
streams behind one dense-array call.  This is deliberately NOT a vmapped
RNG: the scalar engine's stream is a stateful ``numpy.random.Generator``
whose consumption order is part of the trajectory, so the batch must be N
real streams — the property test asserts draw-for-draw equality with N
solo injectors."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.faults import FaultInjector
from repro.core.transport import advance_segment


def numpy_segment_fn(t, bytes_done, rate, bound):
    return advance_segment(t, bytes_done, rate, bound)


def _lane_segment_jnp(t, bytes_done, rate, bound):
    """One lane's segment step in jax.numpy — the same expression tree as
    ``transport.advance_segment`` (vmapped over the lane axis by the
    caller)."""
    import jax.numpy as jnp
    inf = jnp.inf
    need = jnp.where(rate > 0,
                     jnp.maximum(0.0, bound - bytes_done)
                     / jnp.where(rate > 0, rate, 1.0), inf)
    hit = need <= t
    adv = jnp.where(hit, need, t)
    new_bytes = jnp.where(hit, bound, bytes_done + rate * t)
    moved = rate * adv
    t_left = jnp.where(hit, t - need, 0.0)
    return t_left, new_bytes, adv, moved, hit


_JAX_FN = None


def jax_segment_fn(t, bytes_done, rate, bound):
    """jit(vmap) backend.  Inputs/outputs are host numpy float64; x64 is
    enabled only inside this call."""
    global _JAX_FN
    import jax
    with jax.experimental.enable_x64():
        if _JAX_FN is None:
            _JAX_FN = jax.jit(jax.vmap(_lane_segment_jnp))
        t = np.broadcast_to(np.asarray(t, np.float64), bytes_done.shape)
        out = _JAX_FN(jnp_f64(t), jnp_f64(bytes_done), jnp_f64(rate),
                      jnp_f64(bound))
        t_left, new_bytes, adv, moved, hit = (np.asarray(o) for o in out)
    return t_left, new_bytes, adv, moved, hit


def jnp_f64(x):
    import jax.numpy as jnp
    return jnp.asarray(x, jnp.float64)


def pallas_segment_fn(t, bytes_done, rate, bound):
    """Pallas kernel backend (interpret mode; see repro.kernels.lane_step)."""
    from repro.kernels.lane_step.ops import lane_segment_step
    t = np.broadcast_to(np.asarray(t, np.float64), bytes_done.shape)
    return lane_segment_step(t, bytes_done, rate, bound)


def make_segment_fn(backend: str):
    if backend == "numpy":
        return numpy_segment_fn
    if backend == "jax":
        return jax_segment_fn
    if backend == "pallas":
        return pallas_segment_fn
    raise ValueError(f"unknown segment backend {backend!r}")


class BatchedFaultInjector:
    """N per-lane fault streams behind one dense-array draw.

    ``transient_marks(paths, nbytes)`` performs exactly one scalar
    ``FaultInjector.transient_marks`` call per lane — same draw order, same
    stream — and packs the jagged results into ``(marks[L, M], len[L])``
    with ``inf`` padding (``inf`` never matches a byte boundary)."""

    def __init__(self, seeds: Sequence[int], transient_per_tb: float = 0.15,
                 fragility_tail: float = 2.5):
        self.injectors = [FaultInjector(int(s),
                                        transient_per_tb=transient_per_tb,
                                        fragility_tail=fragility_tail)
                          for s in seeds]

    def __len__(self) -> int:
        return len(self.injectors)

    def transient_marks(self, paths: Sequence[str], nbytes: Sequence[int]
                        ) -> Tuple[np.ndarray, np.ndarray]:
        draws: List[List[float]] = [
            inj.transient_marks(p, int(b))
            for inj, p, b in zip(self.injectors, paths, nbytes)]
        lens = np.array([len(d) for d in draws], dtype=np.int64)
        m = int(lens.max()) if len(lens) else 0
        out = np.full((len(draws), max(1, m)), np.inf)
        for i, d in enumerate(draws):
            out[i, :len(d)] = d
        return out, lens
