"""Ensemble reductions: per-metric quantile bands over lane results.

The reduction is permutation-invariant by construction — every statistic
(quantiles, mean, min/max) sorts or sums over the lane axis, so shuffling
lane order cannot change a single output bit (summation order is fixed by
the sort, not by lane arrival)."""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

import numpy as np

#: metrics pulled from a lane result (attribute or mapping key) by default
DEFAULT_METRICS = ("sim_days", "faults_total", "quarantined")
DEFAULT_QUANTILES = (5.0, 50.0, 95.0)


def _metric(row, name: str):
    if isinstance(row, Mapping):
        return row[name]
    return getattr(row, name)


def quantile_bands(rows: Sequence, metrics: Sequence[str] = DEFAULT_METRICS,
                   quantiles: Sequence[float] = DEFAULT_QUANTILES
                   ) -> Dict[str, Dict[str, float]]:
    """Per-metric confidence bands over ``rows`` (lane results: objects or
    mappings).  Returns ``{metric: {"p5": ..., "p50": ..., "p95": ...,
    "mean": ..., "min": ..., "max": ..., "n": ...}}``.  Values are sorted
    before every reduction, so the result is invariant under any
    permutation of ``rows``."""
    if not rows:
        raise ValueError("no lane results to reduce")
    out: Dict[str, Dict[str, float]] = {}
    for m in metrics:
        v = np.sort(np.asarray([float(_metric(r, m)) for r in rows]))
        band = {f"p{q:g}": float(np.percentile(v, q)) for q in quantiles}
        band.update(mean=float(v.mean()), min=float(v[0]), max=float(v[-1]),
                    n=int(v.size))
        out[m] = band
    return out
