"""falcon-mamba-7b — attention-free Mamba1 [arXiv:2410.05355; unverified].

64L d_model=4096 d_state=16 vocab=65024; expand 2 (d_inner 8192).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2, chunk=256),
    subquadratic=True,
    max_seq_len=1048576,
)
