"""qwen2-vl-7b — VLM backbone with M-RoPE [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  Vision tower is a
stub: input_specs provides merged patch+text embeddings and 3-stream M-RoPE
position ids (see models/frontends.py).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    rope_theta=1000000.0, mrope=True, mrope_sections=(16, 24, 24),
    embed_inputs=False,
    max_seq_len=32768,
)
