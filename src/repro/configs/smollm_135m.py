"""smollm-135m — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab_size=49152,
    rope_theta=10000.0, tie_embeddings=True,
    max_seq_len=32768,
)
