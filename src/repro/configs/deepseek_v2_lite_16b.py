"""deepseek-v2-lite-16b — MLA + MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400; MLA kv_lora_rank=512,
qk_rope 64 / qk_nope 128 / v 128; MoE 64 routed top-6 + 2 shared; first layer
dense (d_ff 10944).  (The assignment line also mentions "160 routed" — that is
full V2; the Lite config per the paper is 64 routed.  See DESIGN.md §5.)
"""
from repro.models.config import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  first_dense_layers=1, d_ff_dense=10944),
    max_seq_len=32768,
)
