"""Architecture config registry.

``get_config(arch_id)`` returns the exact assigned configuration;
``get_config(arch_id).smoke()`` the reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "smollm-135m": "smollm_135m",
    "starcoder2-15b": "starcoder2_15b",
    "gemma3-27b": "gemma3_27b",
    "qwen3-14b": "qwen3_14b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "musicgen-large": "musicgen_large",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


# ---------------------------------------------------------------- input shapes
SHAPES: Dict[str, dict] = {
    "train_4k":    dict(seq_len=4096,   global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768,  global_batch=32,  mode="prefill"),
    "decode_32k":  dict(seq_len=32768,  global_batch=128, mode="decode"),
    "long_500k":   dict(seq_len=524288, global_batch=1,   mode="decode"),
}


def shape_applicable(arch: str, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (see DESIGN.md §5)."""
    if shape != "long_500k":
        return True
    return get_config(arch).subquadratic
