"""musicgen-large — decoder-only LM over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H d_ff=8192 vocab=2048 x 4 codebooks (delay pattern handled
by the data pipeline; the LM embeds the 4 books additively and predicts 4
parallel heads).  EnCodec itself is a stub (frontends.py).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    rope_theta=10000.0, n_codebooks=4,
    max_seq_len=32768,
)
