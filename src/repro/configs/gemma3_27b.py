"""gemma3-27b — dense, 5:1 local:global attention [hf:google/gemma-3; unverified].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; sliding window 1024
on local layers; qk-norm; 128k context.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    head_dim=128,
    d_ff=21504, vocab_size=262144,
    rope_theta=1000000.0, qk_norm=True,
    sliding_window=1024, local_global_ratio=5,
    tie_embeddings=True,
    max_seq_len=131072,
)
