"""qwen3-moe-30b-a3b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768 vocab=151936; qk-norm.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    head_dim=128,
    d_ff=768, vocab_size=151936,
    rope_theta=1000000.0, qk_norm=True,
    moe=MoEConfig(n_routed=128, top_k=8, n_shared=0, d_ff_expert=768),
    max_seq_len=40960,
)
