"""zamba2-1.2b — Mamba2 + weight-shared attention blocks [arXiv:2411.15242; hf].

38 mamba2 layers (d_model=2048, ssm_state=64, headdim=64) with one shared
attention+MLP block (32H, d_ff=8192) invoked every 6 layers; vocab 32000.
The HF model concatenates raw embeddings into the shared block (2x width) and
adds per-call-site LoRA on it; we keep the shared block at d_model and share
it exactly (DESIGN.md §5).
"""
from repro.models.config import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2, headdim=64,
                  n_groups=1, chunk=256),
    hybrid=HybridConfig(shared_attn_every=6),
    subquadratic=True,
    max_seq_len=1048576,
)
