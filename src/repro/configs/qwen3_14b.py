"""qwen3-14b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family; hf].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    head_dim=128,
    d_ff=17408, vocab_size=151936,
    rope_theta=1000000.0, qk_norm=True,
    max_seq_len=40960,
)
