"""Campaign post-mortem reports from a recorded flight-recorder stream.

    python -m repro.obs.report RUN.ndjson [--top 5] [--perfetto OUT.json]

Reads the NDJSON stream a run wrote via ``--obs`` (or an ``ObsSpec`` with a
sink) and renders what an operator wants after a campaign: the days-vs-bytes
curve per destination, the fault/outage timeline (per-interval fault counts
and paused transfers), the top-N slowest routes by achieved throughput, and
the most-retried datasets.  ``--perfetto`` additionally converts the trace
records to Chrome trace-event JSON for https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List

from repro.obs.trace import to_chrome

PB = 1e15
TB = 1e12


def load_stream(path: str) -> Dict[str, List[dict]]:
    """Split one NDJSON stream into its record kinds."""
    out: Dict[str, List[dict]] = {"meta": [], "metrics": [], "trace": []}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            out.setdefault(rec.get("k", "?"), []).append(rec)
    return out


def _fmt_bytes(n: float) -> str:
    if n >= PB:
        return f"{n / PB:.2f} PB"
    if n >= TB:
        return f"{n / TB:.2f} TB"
    return f"{n / 1e9:.1f} GB"


def _bar(frac: float, width: int = 40) -> str:
    frac = min(max(frac, 0.0), 1.0)
    n = int(round(frac * width))
    return "#" * n + "." * (width - n)


def _thin(rows: List, limit: int) -> List:
    """At most ``limit`` rows, evenly spaced, always keeping the last."""
    if len(rows) <= limit:
        return rows
    step = (len(rows) - 1) / (limit - 1)
    return [rows[round(i * step)] for i in range(limit)]


def progress_curve(metrics: List[dict], width: int = 40,
                   rows: int = 20) -> List[str]:
    """Days-vs-bytes: one bar per sampled day, summed over destinations
    (federations render per-campaign curves separately)."""
    by_campaign: Dict[str, List[dict]] = defaultdict(list)
    for m in metrics:
        by_campaign[m.get("campaign", "")].append(m)
    lines: List[str] = []
    for camp in sorted(by_campaign):
        samples = by_campaign[camp]
        total = [(m["t_day"], sum(m.get("bytes_at", {}).values()))
                 for m in samples]
        peak = max((b for _, b in total), default=0) or 1
        lines.append(f"[{camp}] days vs bytes landed "
                     f"(peak {_fmt_bytes(peak)})")
        for t, b in _thin(total, rows):
            lines.append(f"  d{t:8.2f} |{_bar(b / peak, width)}| "
                         f"{_fmt_bytes(b)}")
    return lines


def fault_timeline(metrics: List[dict], trace: List[dict],
                   rows: int = 30) -> List[str]:
    """Per-interval fault counts from the metrics stream, merged with
    pause/quarantine instants from the trace — the outage view."""
    lines: List[str] = ["fault / outage timeline"]
    ticks: List[tuple] = []
    for m in metrics:
        faults = sum(r.get("faults", 0) for r in m.get("routes", {}).values())
        paused = m.get("status", {}).get("PAUSED", 0)
        if faults or paused:
            ticks.append((m["t_day"], m.get("campaign", ""), faults, paused))
    if not ticks:
        lines.append("  (no faults or paused transfers recorded)")
    peak = max((f for _, _, f, _ in ticks), default=0) or 1
    for t, camp, faults, paused in _thin(ticks, rows):
        tag = f" paused={paused}" if paused else ""
        lines.append(f"  d{t:8.2f} [{camp}] |{_bar(faults / peak, 20)}| "
                     f"{faults} faults{tag}")
    quarantined = [e for e in trace if e.get("event") == "quarantined"]
    if quarantined:
        lines.append(f"  quarantined datasets ({len(quarantined)}):")
        for e in quarantined[:10]:
            lines.append(f"    d{e['t'] / 86400.0:8.2f} {e.get('dataset')} "
                         f"-> {e.get('dest')} after "
                         f"{e.get('faults', '?')} faults")
    return lines


def slowest_routes(metrics: List[dict], top: int = 5) -> List[str]:
    """Mean achieved Gb/s per route over the intervals it was moving."""
    acc: Dict[str, List[float]] = defaultdict(list)
    for m in metrics:
        for route, r in m.get("routes", {}).items():
            if r.get("gbps", 0.0) > 0.0:
                acc[route].append(r["gbps"])
    ranked = sorted(((sum(v) / len(v), route) for route, v in acc.items()))
    lines = [f"top {top} slowest routes (mean active Gb/s)"]
    if not ranked:
        lines.append("  (no route throughput recorded)")
    for gbps, route in ranked[:top]:
        lines.append(f"  {route:24s} {gbps:8.3f} Gb/s "
                     f"over {len(acc[route])} active intervals")
    return lines


def most_retried(trace: List[dict], top: int = 5) -> List[str]:
    """Datasets by failed-attempt count (from trace ``failed`` events)."""
    fails: Dict[str, int] = defaultdict(int)
    for e in trace:
        if e.get("event") == "failed" and e.get("dataset"):
            fails[e["dataset"]] += 1
    ranked = sorted(fails.items(), key=lambda kv: (-kv[1], kv[0]))
    lines = [f"top {top} most-retried datasets"]
    if not ranked:
        lines.append("  (no failures recorded in trace window)")
    for ds, n in ranked[:top]:
        lines.append(f"  {ds:32s} {n} failed attempts")
    return lines


def render(stream: Dict[str, List[dict]], top: int = 5) -> str:
    metrics, trace = stream.get("metrics", []), stream.get("trace", [])
    meta = stream.get("meta", [])
    head = ["campaign post-mortem"]
    for m in meta:
        if "scenario" in m:
            head.append(f"  scenario={m.get('scenario')} "
                        f"campaign={m.get('campaign')} "
                        f"trace={m.get('trace')} metrics={m.get('metrics')}")
        elif "end_day" in m:
            head.append(f"  [{m.get('campaign')}] "
                        f"ended day {m['end_day']:.2f}")
    head.append(f"  records: {len(metrics)} metrics samples, "
                f"{len(trace)} trace events")
    sections = [head,
                progress_curve(metrics),
                fault_timeline(metrics, trace),
                slowest_routes(metrics, top=top),
                most_retried(trace, top=top)]
    return "\n".join("\n".join(s) for s in sections if s)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a campaign post-mortem from an obs NDJSON "
                    "stream.")
    ap.add_argument("stream", help="NDJSON file written via --obs")
    ap.add_argument("--top", type=int, default=5,
                    help="rows in the slowest-routes / most-retried tables")
    ap.add_argument("--perfetto", metavar="OUT.json", default=None,
                    help="also write Chrome trace-event JSON for Perfetto")
    ap.add_argument("--json", action="store_true",
                    help="emit the parsed stream stats as JSON instead of "
                         "text")
    args = ap.parse_args(argv)
    stream = load_stream(args.stream)
    if args.perfetto:
        with open(args.perfetto, "w") as f:
            json.dump(to_chrome(stream.get("trace", [])), f)
        print(f"wrote Perfetto trace: {args.perfetto} "
              f"({len(stream.get('trace', []))} trace records)",
              file=sys.stderr)
    if args.json:
        print(json.dumps({k: len(v) for k, v in stream.items()},
                         sort_keys=True))
    else:
        print(render(stream, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
