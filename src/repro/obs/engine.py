"""The observability runtime: one ``Observability`` per observed campaign.

``attach`` hangs a listener off the campaign's ``TransferTable`` (trace +
lifecycle counters), binds the scrub/demand ``obs_hook`` seams, and arms the
metrics sampler; ``run_world`` then drives ``step``/``next_action``/
``finalize`` exactly like the demand and scrub engines.  The engine is
strictly read-only with respect to world state: it consumes no RNG, mutates
nothing it observes, and is excluded from snapshots (a resumed campaign
rebuilds observability fresh), which is what makes the obs-on/obs-off
bit-identity contract hold.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.obs.sink import ObsSink
from repro.obs.spec import ObsSpec
from repro.obs.trace import TraceRecorder, lifecycle_event, to_chrome

DAY = 86400.0


class Observability:
    """Flight recorder for one campaign runtime."""

    def __init__(self, spec: ObsSpec, label: str = ""):
        spec.validate()
        self.spec = spec
        self.label = label
        self.trace: Optional[TraceRecorder] = (
            TraceRecorder(spec.trace_budget_bytes, campaign=label)
            if spec.trace else None)
        self.metrics: Optional[MetricsRegistry] = (
            MetricsRegistry() if spec.metrics else None)
        self.samples: List[dict] = []
        self.sink: Optional[ObsSink] = None
        self._rt = None
        self._clock = None
        self._next_sample = math.inf     # absolute sim time of next boundary
        self._anchored = False
        # last route-telemetry reading, for per-interval differencing
        self._last_route: Dict[Tuple[str, str], Tuple[float, int]] = {}
        self._last_sample_t = 0.0
        # dispatch time per in-flight (dataset, dest), for duration histograms
        self._dispatched_at: Dict[Tuple[str, str], float] = {}

    # ------------------------------------------------------------ wiring
    def attach(self, runtime, shared) -> None:
        """Bind to a built campaign.  Called after ``build_campaign`` has
        populated the table, so the initial NULL-row flood never reaches
        the trace."""
        self._rt = runtime
        self._clock = shared.clock
        runtime.table.add_listener(self._on_row)
        if runtime.scrub is not None:
            runtime.scrub.obs_hook = self._on_scrub_pass
        if runtime.demand is not None:
            runtime.demand.obs_hook = self._on_demand_wave

    def attach_sink(self, sink: ObsSink) -> None:
        self.sink = sink
        if self.trace is not None:
            self.trace.sink = sink
        sink.emit("meta", {
            "campaign": self.label,
            "scenario": self._rt.spec.name if self._rt is not None else "",
            "trace": self.spec.trace,
            "metrics": self.spec.metrics,
            "sample_interval_days": self.spec.sample_interval_days,
        })

    # ------------------------------------------------------------ driver
    def next_action(self, now: float) -> float:
        """Absolute sim time this engine wants the world to visit — only
        finite under ``strict_cadence`` (the default lazy sampler rides on
        iterations the physics already produces, keeping the iteration
        count bit-identical to an obs-off run)."""
        if self.metrics is None or not self.spec.strict_cadence:
            return math.inf
        return self._next_sample

    def step(self, now: float) -> None:
        if self.metrics is None:
            return
        if not self._anchored:
            self._anchored = True
            self._last_sample_t = now
            self._sample(now)
            self._next_sample = now + self.spec.sample_interval_days * DAY
            return
        if now >= self._next_sample:
            self._sample(now)
            while self._next_sample <= now:
                self._next_sample += self.spec.sample_interval_days * DAY

    def finalize(self, now: float) -> None:
        """Campaign end: one closing sample plus an end-of-stream marker."""
        if self.metrics is not None and self._anchored \
                and now > self._last_sample_t:
            self._sample(now)
        self._next_sample = math.inf
        if self.sink is not None:
            self.sink.emit("meta", {"campaign": self.label, "end_day":
                                    round(now / DAY, 6)})

    # ------------------------------------------------------------ hooks
    def _on_row(self, rec, old_status, old_source) -> None:
        # progress-only updates are the hot path's overwhelming majority
        # (every poll of every ACTIVE row): bail before any further work
        if old_status is rec.status and old_source == rec.source:
            return
        evt = lifecycle_event(rec, old_status, old_source)
        if evt is None:
            return
        event, fields = evt
        now = self._clock.now
        if self.metrics is not None:
            self.metrics.counter(f"lifecycle.{event}").inc()
            key = (rec.dataset, rec.destination)
            if event in ("dispatched", "resumed", "relay-hop"):
                self._dispatched_at.setdefault(key, now)
            elif event in ("succeeded", "failed", "quarantined", "paused"):
                t0 = self._dispatched_at.pop(key, None)
                if t0 is not None and event == "succeeded":
                    self.metrics.histogram("transfer_s").observe(now - t0)
        if self.trace is not None:
            self.trace.record(now, event, **fields)

    def _on_scrub_pass(self, now: float, stats: dict) -> None:
        if self.metrics is not None:
            self.metrics.counter("scrub.passes").inc()
        if self.trace is not None:
            self.trace.record(now, "scrub-pass", **stats)

    def _on_demand_wave(self, now: float, stats: dict) -> None:
        if self.metrics is not None:
            self.metrics.counter("demand.waves").inc()
        if self.trace is not None:
            self.trace.record(now, "demand-wave", **stats)

    # ------------------------------------------------------------ sampling
    def _sample(self, now: float) -> None:
        rt, transport = self._rt, self._rt and self._rt.sched.transport
        dt = max(now - self._last_sample_t, 1e-9)
        sample: dict = {
            "campaign": self.label,
            "t_day": round(now / DAY, 6),
            "bytes_at": {d: rt.table.bytes_at(d)
                         for d in rt.cfg.replicas},
            "status": rt.table.status_counts(),
            "queue_depth": rt.sched.queue_depth(),
            "backoff_depth": rt.sched.backoff_depth(),
        }
        tele = transport.route_telemetry()
        routes: dict = {}
        for route, (nbytes, faults) in tele.items():
            b0, f0 = self._last_route.get(route, (0.0, 0))
            routes[f"{route[0]}->{route[1]}"] = {
                "gbps": round((nbytes - b0) * 8.0 / dt / 1e9, 6),
                "faults": faults - f0,
            }
        self._last_route = tele
        self._last_sample_t = now
        sample["routes"] = routes
        sample["live"] = transport.live_route_counts()
        if rt.scrub is not None:
            s = rt.scrub.summary()
            sample["scrub"] = {k: s[k] for k in
                               ("detected", "repaired", "at_risk_replicas",
                                "data_at_risk_bytes")}
        if rt.demand is not None:
            d = rt.demand.summary()
            sample["demand"] = {k: d[k] for k in
                                ("requests", "hits", "hit_rate",
                                 "cache_hit_rate", "p99_s")}
        sample.update(self.metrics.snapshot())
        self.samples.append(sample)
        if self.sink is not None:
            self.sink.emit("metrics", sample)

    # ------------------------------------------------------------ exports
    def export_chrome(self) -> dict:
        """Chrome trace-event JSON of the retained trace window."""
        if self.trace is None:
            return {"traceEvents": [], "displayTimeUnit": "ms"}
        return to_chrome(self.trace.records())

    def summary(self) -> dict:
        out: dict = {"campaign": self.label,
                     "sample_interval_days": self.spec.sample_interval_days}
        if self.trace is not None:
            out["trace"] = self.trace.summary()
        if self.metrics is not None:
            out.update(self.metrics.snapshot())
            out["samples"] = len(self.samples)
            out["series"] = self.samples
        return out
