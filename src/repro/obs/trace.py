"""Per-transfer lifecycle tracing.

``TraceRecorder`` turns ``TransferTable`` row transitions (via the table's
listener seam) plus scrub-pass and demand-wave hooks into a stream of
timestamped lifecycle events:

    queued → dispatched → (paused ⇄ resumed) → succeeded
                        ↘ failed (retry) ↘ quarantined / readmitted
    relay-hop              (source rewritten to a replica donor)
    scrub-detected         (a landed replica flipped back for repair)
    scrub-pass / demand-wave (subsystem instants)

Events are ring-buffered pre-serialized (one NDJSON line each) under a byte
budget, so in-memory retention is O(active window), never O(campaign
history); a streaming ``ObsSink`` receives every event regardless of ring
eviction.  ``to_chrome`` converts a stream into Chrome trace-event JSON
(load it at https://ui.perfetto.dev): **1 trace microsecond == 1 sim
second**, one process per campaign, one thread lane per (dataset,
destination) transfer, spans named by their closing transition.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.transfer_table import Status, TransferRecord
from repro.obs.sink import ObsSink, json_line

# events that open an activity span / close one, for the Chrome exporter
_OPENING = ("dispatched", "resumed")
_CLOSING = ("paused", "succeeded", "failed", "scrub-detected", "quarantined")


def lifecycle_event(rec: TransferRecord, old_status: Optional[Status],
                    old_source: Optional[str]
                    ) -> Optional[Tuple[str, Dict]]:
    """Map one table row transition to a ``(event, fields)`` pair, or None
    for transitions that carry no lifecycle information (progress-only
    updates, which the hot path fires for every poll)."""
    if old_status is rec.status and old_source == rec.source:
        return None                      # progress-only update
    fields: Dict = {"dataset": rec.dataset, "dest": rec.destination,
                    "src": rec.source}
    s = rec.status
    if s is Status.NULL:
        return "created", fields         # a top-up row entering the table
    if s is Status.QUEUED:
        return "queued", fields
    if s is Status.ACTIVE:
        if old_status is Status.PAUSED:
            return "resumed", fields
        if old_source is not None and old_source != rec.source:
            fields["relay_from"] = old_source
            return "relay-hop", fields
        return "dispatched", fields
    if s is Status.PAUSED:
        return "paused", fields
    if s is Status.SUCCEEDED:
        fields["bytes"] = rec.bytes_transferred
        fields["faults"] = rec.faults
        return "succeeded", fields
    if s is Status.FAILED:
        if old_status is Status.SUCCEEDED:
            return "scrub-detected", fields   # repair re-admission
        if old_status is Status.QUARANTINED:
            return "readmitted", fields
        fields["retries"] = rec.retries
        fields["faults"] = rec.faults
        return "failed", fields
    if s is Status.QUARANTINED:
        fields["faults"] = rec.faults
        return "quarantined", fields
    return None


class TraceRecorder:
    """Byte-budgeted ring of pre-serialized trace events."""

    def __init__(self, budget_bytes: int, campaign: str = "",
                 sink: Optional[ObsSink] = None):
        self.budget_bytes = int(budget_bytes)
        self.campaign = campaign
        self.sink = sink
        self._ring: deque = deque()
        self._bytes = 0
        self.recorded = 0               # events seen (ring + stream)
        self.dropped = 0                # ring evictions (stream keeps all)

    def record(self, t: float, event: str, **fields) -> None:
        rec = {"t": round(t, 6), "campaign": self.campaign,
               "event": event, "k": "trace"}
        rec.update(fields)
        line = json_line(rec)
        self._ring.append(line)
        self._bytes += len(line)
        self.recorded += 1
        while self._bytes > self.budget_bytes and len(self._ring) > 1:
            self._bytes -= len(self._ring.popleft())
            self.dropped += 1
        if self.sink is not None:
            self.sink.emit_line(line)

    def on_row(self, t: float, rec: TransferRecord,
               old_status: Optional[Status],
               old_source: Optional[str]) -> None:
        """The ``TransferTable`` listener body (the engine binds the sim
        clock and forwards here)."""
        evt = lifecycle_event(rec, old_status, old_source)
        if evt is not None:
            self.record(t, evt[0], **evt[1])

    def lines(self) -> List[str]:
        """The retained window, oldest first (NDJSON lines)."""
        return list(self._ring)

    def records(self) -> List[Dict]:
        return [json.loads(s) for s in self._ring]

    def summary(self) -> dict:
        return {
            "events": self.recorded,
            "retained": len(self._ring),
            "dropped": self.dropped,
            "ring_bytes": self._bytes,
            "budget_bytes": self.budget_bytes,
        }


# ------------------------------------------------------------ Chrome export
def to_chrome(records: Iterable[Dict]) -> Dict:
    """Chrome trace-event JSON from a stream of parsed obs records (trace
    records are used, others ignored).  Timestamps map 1 trace µs == 1 sim
    second, so Perfetto's "1.234 ms" reads as 1234 sim seconds; spans cover
    a transfer's active periods and are named by the transition that closed
    them; everything else lands as an instant on the transfer's lane."""
    events: List[Dict] = []
    pids: Dict[str, int] = {}
    tids: Dict[Tuple[int, str, str], int] = {}
    open_at: Dict[Tuple[int, int], float] = {}      # (pid, tid) -> span start

    def pid_of(campaign: str) -> int:
        pid = pids.get(campaign)
        if pid is None:
            pid = pids[campaign] = len(pids) + 1
            events.append({"ph": "M", "pid": pid, "tid": 0,
                           "name": "process_name",
                           "args": {"name": campaign or "campaign"}})
        return pid

    def tid_of(pid: int, dataset: str, dest: str) -> int:
        key = (pid, dataset, dest)
        tid = tids.get(key)
        if tid is None:
            tid = tids[key] = sum(1 for k in tids if k[0] == pid) + 1
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": f"{dataset} -> {dest}"}})
        return tid

    trace = sorted((r for r in records if r.get("k") == "trace"),
                   key=lambda r: r.get("t", 0.0))
    for r in trace:
        event = r.get("event", "?")
        t = float(r.get("t", 0.0))
        pid = pid_of(r.get("campaign", ""))
        ds, dest = r.get("dataset"), r.get("dest")
        if ds is None or dest is None:          # subsystem instants
            events.append({"ph": "i", "s": "p", "pid": pid, "tid": 0,
                           "ts": t, "name": event,
                           "args": {k: v for k, v in r.items()
                                    if k not in ("k", "t", "campaign",
                                                 "event")}})
            continue
        tid = tid_of(pid, ds, dest)
        args = {k: v for k, v in r.items()
                if k not in ("k", "t", "campaign", "event",
                             "dataset", "dest")}
        if event in _OPENING:
            open_at.setdefault((pid, tid), t)
        elif event in _CLOSING and (pid, tid) in open_at:
            start = open_at.pop((pid, tid))
            events.append({"ph": "X", "pid": pid, "tid": tid, "ts": start,
                           "dur": max(0.0, t - start), "name": event,
                           "cat": "transfer", "args": args})
            continue
        events.append({"ph": "i", "s": "t", "pid": pid, "tid": tid,
                       "ts": t, "name": event, "cat": "transfer",
                       "args": args})
    # close dangling spans at their last event time (kill mid-campaign)
    for (pid, tid), start in sorted(open_at.items()):
        events.append({"ph": "X", "pid": pid, "tid": tid, "ts": start,
                       "dur": 0.0, "name": "unterminated",
                       "cat": "transfer", "args": {}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"timebase": "1 trace us == 1 sim second"}}
