"""Observability: the campaign flight recorder.

The paper's replication succeeded because operators could *see* what 29 M
files were doing — Globus event logs plus the progress database let them
diagnose DTN outages, a 2.5-day network failure, and checksum faults
mid-campaign.  This package gives the simulator the same layer:

  * ``TraceRecorder`` (``repro.obs.trace``) — per-transfer lifecycle spans
    off the ``TransferTable`` row-transition listener, ring-buffered with a
    byte budget, exportable to NDJSON and Chrome trace-event JSON
    (Perfetto-viewable, sim-clock timestamps);
  * ``MetricsRegistry`` (``repro.obs.metrics``) — counters / gauges /
    histograms sampled on a sim-clock cadence: per-route throughput and
    occupancy, queue/backoff depths, fault rates, scrub data-at-risk,
    demand hit-rate;
  * ``Observability`` (``repro.obs.engine``) — the runtime wiring both onto
    a campaign, driven by ``run_world``;
  * ``PhaseProfiler`` (``repro.obs.profile``) — per-phase wall-time buckets
    over the scheduler/transport/table seams;
  * ``python -m repro.obs.report`` — the post-mortem CLI: days-vs-bytes
    curve, fault/outage timeline, slowest routes, most-retried datasets.

Declared via ``ObsSpec`` on a ``ScenarioSpec``; the default ``NO_OBS``
compiles to **zero hooks**, and the hard contract is bit-identical
trajectories and snapshots with obs on or off.
"""
from repro.obs.spec import FULL_OBS, NO_OBS, ObsSpec

__all__ = ["ObsSpec", "NO_OBS", "FULL_OBS"]
