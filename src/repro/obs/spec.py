"""Declarative observability configuration.

``ObsSpec`` rides on ``ScenarioSpec`` exactly like ``DemandSpec`` and
``ScrubSpec``: the default ``NO_OBS`` compiles to no engine at all — zero
listeners, zero event candidates, zero per-iteration work — so a scenario
that does not opt in replays its trajectory bit-identically, and a scenario
that *does* opt in must too (observation never mutates world state or
consumes RNG; the CI gate pins this).

Cadence semantics: metrics are sampled every ``sample_interval_days`` of
sim time.  By default (``strict_cadence=False``) samples are taken lazily
at the first driver iteration at or past each boundary, so the iteration
count — part of the trajectory bit-identity tuple — is untouched.  With
``strict_cadence=True`` the sampler registers each boundary as a
``run_world`` next-event candidate: samples land exactly on the cadence at
the cost of extra iterations (the physical trajectory — digest, faults,
bytes landed — is still identical, because the transport is segment-exact
under any time slicing).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ObsSpec:
    """Flight-recorder configuration for one campaign."""
    trace: bool = False             # record per-transfer lifecycle events
    metrics: bool = False           # sample the metrics registry on cadence
    sample_interval_days: float = 1.0
    # in-memory trace retention: oldest events are evicted once the ring
    # exceeds this many (approximate, serialized) bytes.  A streaming NDJSON
    # sink is unbounded — the budget bounds memory, not the file.
    trace_budget_bytes: int = 4 * 1024 * 1024
    # False: sample lazily at existing iterations (full trajectory-tuple
    # bit-identity, iterations included).  True: inject cadence boundaries
    # as next-event candidates (exact sample times, extra iterations).
    strict_cadence: bool = False

    @property
    def enabled(self) -> bool:
        """True when this spec needs a live observability engine."""
        return self.trace or self.metrics

    def validate(self) -> None:
        if not self.enabled:
            return
        if self.metrics and self.sample_interval_days <= 0:
            raise ValueError(
                f"sample_interval_days must be > 0, "
                f"got {self.sample_interval_days}")
        if self.trace and self.trace_budget_bytes <= 0:
            raise ValueError(
                f"trace_budget_bytes must be > 0, "
                f"got {self.trace_budget_bytes}")


NO_OBS = ObsSpec()

# the everything-on preset the CLI's --obs flag applies to scenarios that
# did not declare their own observability
FULL_OBS = ObsSpec(trace=True, metrics=True)
