"""Per-phase wall-time profiling of the replay hot path.

``PhaseProfiler`` splits a run's wall clock into exclusive per-phase
buckets by temporarily wrapping class methods (scheduler step, transport
tick, table churn, ...).  Promoted out of ``benchmarks/campaign_replay.py``
so the scenario CLI's ``--profile`` and the bench's ``--profile`` share one
implementation; use it as a context manager:

    with PhaseProfiler() as prof:
        prof.instrument_standard()
        run_scenario(...)
    print(prof.report(wall_s))

Instrumentation only *times* the original calls — trajectories are
untouched — but the measured run is slower than a bare one, so profile
numbers belong alongside, never instead of, benchmark walls.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple


class PhaseProfiler:
    """Per-phase wall-time buckets via temporary class-method wrappers.

    Exclusive-time accounting: a stack tracks the active bucket, and time
    spent in a nested instrumented call (``TransferTable`` work inside
    ``ReplicationScheduler.step``, say) is charged to the inner bucket and
    subtracted from the outer one, so the buckets sum to at most the run's
    wall clock and never double-count.  Wrapping happens at class level so
    federation members (N schedulers over one transport) are all captured.
    """

    def __init__(self):
        self.buckets: Dict[str, float] = {}
        self._stack: List[list] = []
        self._patched: List[Tuple[type, str, object]] = []

    def wrap(self, cls, name: str, bucket: str) -> None:
        orig = getattr(cls, name)

        def timed(s, *a, _orig=orig, _b=bucket, **kw):
            t0 = time.perf_counter()
            self._stack.append([_b, 0.0])
            try:
                return _orig(s, *a, **kw)
            finally:
                dt = time.perf_counter() - t0
                b, child = self._stack.pop()
                self.buckets[b] = self.buckets.get(b, 0.0) + (dt - child)
                if self._stack:
                    self._stack[-1][1] += dt

        setattr(cls, name, timed)
        self._patched.append((cls, name, orig))

    def instrument_standard(self) -> "PhaseProfiler":
        """Wrap the canonical hot-path seams: sched (dispatch/poll),
        transport (tick + next-event hints), table (row/index churn),
        and the opt-in control/demand/scrub planes."""
        from repro.control.plane import ControlPlane
        from repro.core.scheduler import ReplicationScheduler
        from repro.core.scrub import ScrubEngine
        from repro.core.transfer_table import TransferTable
        from repro.core.transport import SimulatedTransport
        from repro.demand.engine import DemandEngine

        self.wrap(ReplicationScheduler, "step", "sched")
        self.wrap(SimulatedTransport, "tick", "transport")
        self.wrap(SimulatedTransport, "next_event_hint", "transport")
        self.wrap(TransferTable, "update_many", "table")
        self.wrap(TransferTable, "by_status", "table")
        self.wrap(ControlPlane, "step", "control")
        self.wrap(DemandEngine, "step", "demand")
        self.wrap(ScrubEngine, "step", "scrub")
        return self

    def restore(self) -> None:
        for cls, name, orig in self._patched:
            setattr(cls, name, orig)
        self._patched.clear()

    def __enter__(self) -> "PhaseProfiler":
        return self

    def __exit__(self, *exc) -> None:
        self.restore()

    def report(self, wall_s: float) -> dict:
        """Bucket seconds and percentages, with the unattributed remainder
        of ``wall_s`` charged to a ``driver`` bucket."""
        phases = {b: round(t, 3) for b, t in sorted(self.buckets.items())}
        phases["driver"] = round(
            max(0.0, wall_s - sum(self.buckets.values())), 3)
        return {
            "wall_s": round(wall_s, 3),
            "phases_s": phases,
            "phases_pct": {b: round(100.0 * t / max(wall_s, 1e-9), 1)
                           for b, t in phases.items()},
        }
