"""Counters, gauges, and histograms for the flight recorder.

Deliberately tiny and dependency-free: metrics must never perturb the
simulation, so every instrument is a plain Python accumulator with O(1)
updates and a deterministic, sorted snapshot.  The ``Observability`` engine
samples a registry on the sim-clock cadence and streams each sample as one
``{"k": "metrics", ...}`` NDJSON record.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, List, Sequence, Tuple


class Counter:
    """Monotonic event count."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written level (queue depth, data at risk, ...)."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = v


# default duration buckets, in sim seconds: 1 min .. 32 days, powers of two
_DEF_BOUNDS = tuple(60.0 * 2 ** i for i in range(0, 16))


class Histogram:
    """Fixed-bound bucket histogram with quantile estimates (upper-bound of
    the covering bucket, which is exact enough for p50/p99 reporting and —
    unlike a sample reservoir — needs no RNG)."""
    __slots__ = ("bounds", "counts", "total", "sum")

    def __init__(self, bounds: Sequence[float] = _DEF_BOUNDS):
        self.bounds: Tuple[float, ...] = tuple(sorted(bounds))
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v

    def quantile(self, q: float) -> float:
        if self.total == 0:
            return 0.0
        rank = q * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return (self.bounds[i] if i < len(self.bounds)
                        else math.inf)
        return math.inf

    def summary(self) -> dict:
        return {
            "count": self.total,
            "sum": round(self.sum, 6),
            "mean": round(self.sum / self.total, 6) if self.total else 0.0,
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Named instruments, created on first use, snapshotted in sorted
    order so every float reduction over a snapshot is process-stable."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  bounds: Sequence[float] = _DEF_BOUNDS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(bounds)
        return h

    def snapshot(self) -> dict:
        out: dict = {}
        if self._counters:
            out["counters"] = {k: self._counters[k].value
                               for k in sorted(self._counters)}
        if self._gauges:
            out["gauges"] = {k: self._gauges[k].value
                             for k in sorted(self._gauges)}
        if self._histograms:
            out["histograms"] = {k: self._histograms[k].summary()
                                 for k in sorted(self._histograms)}
        return out
