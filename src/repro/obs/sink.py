"""NDJSON sink for the flight recorder's streams.

One file carries every record kind, discriminated by ``"k"`` (``meta`` /
``trace`` / ``metrics``); federation members share one sink and are told
apart by each record's ``campaign`` label.  Every line is serialized with
sorted keys, compact separators, and ``allow_nan=False`` after a
non-finite-float sweep, so the stream is byte-identical across processes
for identical (scenario, scale, seed, n_datasets) runs — the cross-process
determinism test diffs the raw bytes.  Timestamps are **sim-clock**
seconds; no wall clock, uuid, or pid ever reaches the stream.
"""
from __future__ import annotations

import json
import math
from typing import IO, Union


def sanitize(obj):
    """A copy of ``obj`` with every non-finite float replaced by ``None``
    (JSON has no NaN/inf; ``allow_nan=False`` would otherwise raise)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    return obj


def json_line(obj: dict) -> str:
    """The canonical one-line serialization: sorted keys, compact, NaN-free.
    Stable byte-for-byte across processes for equal inputs."""
    return json.dumps(sanitize(obj), sort_keys=True,
                      separators=(",", ":"), allow_nan=False)


class ObsSink:
    """Append-only NDJSON writer shared by every obs engine of a run."""

    def __init__(self, target: Union[str, IO[str]]):
        if hasattr(target, "write"):
            self._f: IO[str] = target
            self._own = False
        else:
            self._f = open(target, "w")
            self._own = True
        self.records = 0

    def emit(self, kind: str, payload: dict) -> None:
        rec = dict(payload)
        rec["k"] = kind
        self._f.write(json_line(rec) + "\n")
        self.records += 1

    def emit_line(self, line: str) -> None:
        """Write an already-serialized record (the trace ring stores its
        events pre-serialized; re-encoding would only burn time)."""
        self._f.write(line + "\n")
        self.records += 1

    def close(self) -> None:
        self._f.flush()
        if self._own:
            self._f.close()
