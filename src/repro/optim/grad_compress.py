"""Gradient compression for the slow cross-pod hop (DCN).

Same insight as the paper's relay routing: treat the slow link specially.
Within a pod, gradients reduce over fast ICI in full precision; across pods
(2× slower DCN at best) we quantize to int8 with a per-tensor scale before the
exchange, cutting cross-pod bytes 4×, then dequantize and average.

Implemented as a psum-compatible transform usable inside shard_map or under
pjit (the quantize/dequantize are elementwise and partition cleanly; the int8
all-gather over the tiny ``pod`` axis of size P costs P×N bytes vs 4N for an
f32 all-reduce — a win for P ≤ 4, i.e. exactly the cross-pod regime).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def psum_compressed(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8 mean-reduce over ``axis_name`` (call inside shard_map).

    all_gather int8 shards + per-source scales, dequantize, average locally.
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)            # (P, ...) int8
    ss = jax.lax.all_gather(scale, axis_name)        # (P,)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
    return jnp.mean(deq, axis=0).astype(x.dtype)


def compress_tree(grads: PyTree, axis_name: str) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: psum_compressed(g, axis_name), grads)


def compression_error(x: jnp.ndarray) -> jnp.ndarray:
    q, s = quantize_int8(x)
    return jnp.max(jnp.abs(dequantize_int8(q, s) - x.astype(jnp.float32)))
