"""LR schedules (pure functions of the int step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, s / max(1, warmup))
    prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, peak_lr * cos)


def constant(step, lr: float):
    return jnp.full_like(step, lr, dtype=jnp.float32)
