"""AdamW in pure JAX with mixed precision and ZeRO-1-ready state layout.

Params are bf16; optimizer keeps f32 master params and f32 (m, v) moments —
the classic mixed-precision recipe.  State tensors mirror param shapes, so the
ZeRO-1 sharding in ``launch/shardings.py`` (optimizer state sharded over the
``data`` axis) applies transparently: the update is elementwise and therefore
valid under any sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray          # int32 scalar
    master: PyTree             # f32 master params
    m: PyTree                  # f32 first moment
    v: PyTree                  # f32 second moment


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init(params: PyTree) -> AdamWState:
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), master=f32(params),
                      m=zeros(params), v=zeros(params))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads: PyTree, state: AdamWState, lr: jnp.ndarray,
           cfg: AdamWConfig = AdamWConfig()
           ) -> Tuple[PyTree, AdamWState, Dict[str, jnp.ndarray]]:
    """Returns (new bf16 params, new state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p, m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = treedef.flatten_up_to(state.master)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    bf16_params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16), new_p)
    return bf16_params, AdamWState(step, new_p, new_m, new_v), {
        "grad_norm": gnorm, "clip_scale": scale}
