"""Batched serving engine (wave-scheduled static batching).

Requests are admitted in waves of up to B: prompts are left-padded to a
common length, prefilled in one batched call, then decoded greedily one
token/step for the whole wave; finished requests exit the wave, and when the
wave drains the next one is admitted.  Prefill is jitted per (bucketed)
prompt length; decode is jitted once.

The decode step this engine drives is exactly what the ``decode_32k`` /
``long_500k`` dry-run cells lower.  (True continuous batching needs per-slot
position vectors in the cache-update path — noted as future work in
DESIGN.md; wave scheduling keeps the cache math exact.)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import LM


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) or (T, K) int32
    max_new_tokens: int = 16
    out_tokens: List = field(default_factory=list)
    done: bool = False


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.model = LM(cfg, remat=False)
        self.params = params
        self.B = max_batch
        self.S = max_seq
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill)
        self._queue: List[Request] = []
        self._next_rid = 0
        self.waves = 0

    # ------------------------------------------------------------------- api
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new_tokens))
        return rid

    def run_to_completion(self) -> List[Request]:
        done: List[Request] = []
        while self._queue:
            done.extend(self._run_wave())
        return done

    # ------------------------------------------------------------------ wave
    def _run_wave(self) -> List[Request]:
        wave = [self._queue.pop(0) for _ in range(min(self.B, len(self._queue)))]
        self.waves += 1
        B = self.B
        lens = [r.prompt.shape[0] for r in wave]
        T = _bucket(max(lens))
        multik = self.cfg.n_codebooks > 1
        shape = (B, T, self.cfg.n_codebooks) if multik else (B, T)
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(wave):
            toks[i, T - lens[i]:T] = r.prompt     # left-pad
        cache = self.model.init_cache(B, self.S)
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                      cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).reshape(B, -1)
        t = T
        active = {i: r for i, r in enumerate(wave)}
        for i, r in active.items():
            r.out_tokens.append(_tok_out(nxt[i], multik))
        finished: List[Request] = []
        while active and t < self.S - 1:
            cur = np.zeros((B, 1, self.cfg.n_codebooks) if multik else (B, 1),
                           np.int32)
            for i, r in active.items():
                cur[i, 0] = r.out_tokens[-1]
            lg, cache = self._decode(self.params, cache, jnp.asarray(cur),
                                     jnp.int32(t))
            nxt = np.asarray(jnp.argmax(lg, axis=-1)).reshape(B, -1)
            t += 1
            for i, r in list(active.items()):
                r.out_tokens.append(_tok_out(nxt[i], multik))
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    finished.append(r)
                    del active[i]
        for r in active.values():
            r.done = True
            finished.append(r)
        return finished


def _tok_out(row: np.ndarray, multik: bool):
    return [int(v) for v in row] if multik else int(row[0])
