"""Fault-tolerant training loop.

Wires together: model (any of the 10 archs), AdamW, data pipeline (synthetic
or sharded files), periodic checkpointing with integrity manifests, optional
cross-site checkpoint replication (the paper's scheduler), restart-from-
manifest, and failure injection for tests.

Designed so that a process crash at ANY step resumes bit-compatibly:
  * params/opt state from the last committed checkpoint (verified);
  * data pipeline from its serialized IterState (exact delivery state);
  * step counter from the checkpoint metadata.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.checkpoint.replicate import CheckpointReplicator
from repro.data.synthetic import for_model
from repro.models.config import ModelConfig
from repro.models.model import LM
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine


@dataclass
class TrainConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    microbatches: int = 1            # gradient accumulation factor
    peak_lr: float = 3e-4
    warmup: int = 20
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    replicator: Optional[CheckpointReplicator] = None
    seed: int = 0
    log_every: int = 10
    fail_at_step: Optional[int] = None      # fault injection (tests)
    remat: bool = False


@dataclass
class TrainResult:
    losses: List[float]
    final_step: int
    restarts: int
    restored_from: Optional[str] = None
    wall_s: float = 0.0


def make_train_step(model: LM, opt_cfg: adamw.AdamWConfig,
                    train_cfg: TrainConfig):
    """Builds the jitted (params, opt_state, batch) -> ... step with
    microbatch gradient accumulation."""
    mb = train_cfg.microbatches

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if mb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            parts = jax.tree_util.tree_map(split, batch)

            def body(carry, mb_batch):
                acc, loss_acc = carry
                (l, _), g = grad_fn(params, mb_batch)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(body, (zero, 0.0), parts)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = {}
        lr = warmup_cosine(opt_state.step, train_cfg.peak_lr,
                           train_cfg.warmup, train_cfg.steps)
        params, opt_state, opt_metrics = adamw.update(
            grads, opt_state, lr, opt_cfg)
        return params, opt_state, loss, opt_metrics

    return jax.jit(step, donate_argnums=(0, 1))


class SimulatedFailure(RuntimeError):
    pass


def train(cfg: ModelConfig, tc: TrainConfig,
          data_iter_factory: Optional[Callable] = None) -> TrainResult:
    """Run training with automatic restart on (injected) failures."""
    t0 = time.time()
    losses: List[float] = []
    restarts = 0
    restored_from = None
    fail_at = tc.fail_at_step

    while True:
        try:
            model = LM(cfg, remat=tc.remat)
            key = jax.random.PRNGKey(tc.seed)
            params = model.init(key)
            opt_state = adamw.init(params)
            start_step = 0

            if tc.ckpt_dir:
                got = restore_checkpoint(
                    tc.ckpt_dir, {"params": params, "opt": opt_state})
                if got is not None:
                    start_step, tree, d = got
                    params, opt_state = tree["params"], tree["opt"]
                    restored_from = d

            data = (data_iter_factory(cfg, tc) if data_iter_factory
                    else for_model(cfg, tc.batch_size, tc.seq_len, tc.seed))
            step_fn = make_train_step(model, adamw.AdamWConfig(), tc)

            for step in range(start_step, tc.steps):
                batch_np = data.batch_at(step)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                if fail_at is not None and step == fail_at:
                    fail_at = None   # fail exactly once
                    raise SimulatedFailure(f"injected failure at step {step}")
                params, opt_state, loss, _ = step_fn(params, opt_state, batch)
                losses.append(float(loss))
                if tc.log_every and step % tc.log_every == 0:
                    print(f"[train] step {step} loss {float(loss):.4f}")
                next_step = step + 1
                if tc.ckpt_dir and next_step % tc.ckpt_every == 0:
                    d = save_checkpoint(
                        tc.ckpt_dir, next_step,
                        {"params": params, "opt": opt_state})
                    if tc.replicator is not None:
                        rel = os.path.relpath(
                            d, tc.replicator.site_dir(tc.replicator.primary))
                        tc.replicator.replicate(rel)
            return TrainResult(losses, tc.steps, restarts, restored_from,
                               time.time() - t0)
        except SimulatedFailure as e:
            print(f"[train] FAILURE: {e}; restarting from checkpoint")
            restarts += 1
            if not tc.ckpt_dir:
                raise
