"""Version compatibility shims for the host framework.

The repo targets the modern ``jax.shard_map`` entry point (with its
``check_vma`` keyword); older jax releases ship the same functionality as
``jax.experimental.shard_map.shard_map`` with ``check_rep``.  ``shard_map``
below papers over the difference so library and test code can use one
spelling everywhere.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Dispatch to ``jax.shard_map`` or the legacy experimental API.

    ``check`` maps to ``check_vma`` (new) / ``check_rep`` (old); both default
    off because the relay collectives intentionally hold different values per
    slice mid-chain.
    """
    new = getattr(jax, "shard_map", None)
    if new is not None:
        try:
            return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=check)
        except TypeError:
            return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=check)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)
