"""Public op: checksum arbitrary-size byte/array payloads.

Handles padding to the kernel's (BLOCK_ROWS × 512)-word granularity.  Padding
with zero words is safe because each word's hash is position-mixed and the
true byte length is folded into the finalizer — identical to the reference.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.checksum.checksum import BLOCK_ROWS, checksum_words_pallas
from repro.kernels.checksum.ref import ROW, bytes_to_words, checksum_bytes_np


def _pad_words(words: jnp.ndarray) -> jnp.ndarray:
    gran = BLOCK_ROWS * ROW
    n = words.size
    padded = max(gran, ((n + gran - 1) // gran) * gran)
    if padded != n:
        words = jnp.concatenate(
            [words, jnp.zeros((padded - n,), jnp.uint32)])
    return words


def checksum_array(x: jax.Array, interpret: bool = True) -> jax.Array:
    """Hash a jax array's raw contents (uint32 view, zero-padded)."""
    raw = jnp.asarray(x).reshape(-1)
    if raw.dtype != jnp.uint32:
        b = np.asarray(raw).tobytes()
        nbytes = len(b)
        words = jnp.asarray(bytes_to_words(b))
    else:
        nbytes = raw.size * 4
        words = raw
    n_words = words.size
    words = _pad_words(words)
    return checksum_words_pallas(words, jnp.uint32(n_words),
                                 jnp.uint32(nbytes & 0xFFFFFFFF),
                                 interpret=interpret)


def checksum_bytes(data: bytes, interpret: bool = True) -> int:
    words = jnp.asarray(bytes_to_words(data))
    n_words = words.size
    words = _pad_words(words)
    return int(checksum_words_pallas(
        words, jnp.uint32(n_words), jnp.uint32(len(data) & 0xFFFFFFFF),
        interpret=interpret))
