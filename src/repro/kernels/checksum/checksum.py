"""Pallas TPU kernel: streaming integrity hash over uint32 words.

Tiling: input reshaped to (R, 512) words (4 sublanes × 128 lanes per row).
The grid walks row-blocks sequentially; each step XOR-accumulates its block's
mixed words into a (8, 512) VMEM accumulator (the output block, revisited at
every grid step — TPU grid steps execute in order, so accumulation is safe).
Position mixing uses the global word index derived from the grid coordinate,
so the result is bit-identical to ``ref.checksum_words_np`` for any tiling.

This is the DTN-checksum hot loop of the paper mapped to TPU: bandwidth-bound
streaming over HBM with a tiny VMEM-resident state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.checksum.ref import PHI, ROW

BLOCK_ROWS = 256          # rows of 512 words per grid step (512 KB per block)
ACC_ROWS = 8


def _mix32(x):
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _checksum_kernel(nw_ref, x_ref, acc_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = x_ref[...].astype(jnp.uint32)                  # (BLOCK_ROWS, ROW)
    r, c = blk.shape
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (r, c), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (r, c), 1)
    base = (step * BLOCK_ROWS).astype(jnp.uint32) * jnp.uint32(ROW)
    idx = base + row_ids * jnp.uint32(ROW) + col_ids     # global word index
    g = _mix32(blk ^ (idx * jnp.uint32(PHI)))
    # zero-padding beyond the true word count must not contribute
    nw = nw_ref[0, 0]
    g = jnp.where(idx < nw, g, jnp.uint32(0))
    # fold BLOCK_ROWS -> ACC_ROWS so the accumulator stays tiny
    g = g.reshape(ACC_ROWS, r // ACC_ROWS, c)
    part = jax.lax.reduce(g, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
    acc_ref[...] ^= part


@functools.partial(jax.jit, static_argnames=("interpret",))
def checksum_words_pallas(words: jax.Array, n_words: jax.Array,
                          nbytes: jax.Array, interpret: bool = True) -> jax.Array:
    """words: uint32[N] with N % (BLOCK_ROWS*ROW) == 0 (pre-padded by ops.py);
    n_words: true (unpadded) word count; nbytes: true byte length.

    Returns the uint32 scalar hash (bit-identical to the numpy reference).
    """
    n = words.size
    rows = n // ROW
    grid = rows // BLOCK_ROWS
    x2 = words.reshape(rows, ROW)
    nw = jnp.reshape(n_words.astype(jnp.uint32), (1, 1))
    acc = pl.pallas_call(
        _checksum_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((BLOCK_ROWS, ROW), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ACC_ROWS, ROW), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ACC_ROWS, ROW), jnp.uint32),
        interpret=interpret,
    )(nw, x2)
    h = jax.lax.reduce(acc.reshape(-1), jnp.uint32(0),
                       jax.lax.bitwise_xor, (0,))
    h = h ^ nbytes.astype(jnp.uint32)
    return _mix32(h)
