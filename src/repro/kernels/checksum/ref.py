"""Reference (oracle) implementation of the streaming integrity hash.

Construction (exact uint32 arithmetic, order-sensitive, fully parallel):

    g[i] = mix32(word[i] ^ (i * PHI))        # position baked into each word
    H    = finalize32( XOR_i g[i]  ^  nbytes )

``mix32``/``finalize32`` are xorshift-multiply avalanches.  XOR-reduction is
associative+commutative, so the hash can be computed in any tiling/order —
ideal for a Pallas grid accumulating lane partials in VMEM — while position
mixing keeps it order-*sensitive* over the data.

Three implementations, all bit-identical:
  * ``checksum_bytes_np``  — numpy, used by core.integrity on real files;
  * ``checksum_words_jnp`` — pure-jnp oracle for kernel tests;
  * Pallas kernel in ``checksum.py`` (tiled, VMEM-resident blocks).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

PHI = np.uint32(0x9E3779B1)
LANES = 128
ROW = 512          # words per kernel row (4 sublanes x 128 lanes)


# ------------------------------------------------------------------- mix/fin
def _mix32_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x ^= x >> np.uint32(16)
    x = (x * np.uint32(0x7FEB352D)).astype(np.uint32)
    x ^= x >> np.uint32(15)
    x = (x * np.uint32(0x846CA68B)).astype(np.uint32)
    x ^= x >> np.uint32(16)
    return x


def finalize32_np(h: int, nbytes: int) -> int:
    x = np.uint32(h) ^ np.uint32(nbytes & 0xFFFFFFFF)
    x = _mix32_np(np.array([x], np.uint32))[0]
    return int(x)


def _mix32_jnp(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


# ------------------------------------------------------------------ word prep
def bytes_to_words(data: bytes) -> np.ndarray:
    pad = (-len(data)) % 4
    if pad:
        data = data + b"\0" * pad
    return np.frombuffer(data, dtype="<u4").astype(np.uint32)


# ------------------------------------------------------------------- hashers
def fold_words_np(words: np.ndarray, start_word: int = 0) -> int:
    """XOR-fold a word slice whose first element sits at global word offset
    ``start_word``.  Because the reduction is associative+commutative and the
    position is baked into each word, partial folds over consecutive slices
    XOR together to the whole-buffer fold — the basis of the streaming
    (chunked) hasher in ``core.integrity``."""
    words = words.astype(np.uint32)
    if not words.size:
        return 0
    idx = np.arange(words.size, dtype=np.uint32) + np.uint32(
        start_word & 0xFFFFFFFF)
    g = _mix32_np(words ^ (idx * PHI))
    return int(np.bitwise_xor.reduce(g))


def checksum_words_np(words: np.ndarray, nbytes: int) -> int:
    return finalize32_np(fold_words_np(words), nbytes)


def checksum_bytes_np(data: bytes) -> int:
    return checksum_words_np(bytes_to_words(data), len(data))


def checksum_words_jnp(words: jnp.ndarray, nbytes: int) -> jnp.ndarray:
    """Pure-jnp oracle; words: uint32[N] (already padded)."""
    import jax
    idx = jnp.arange(words.size, dtype=jnp.uint32)
    g = _mix32_jnp(words.astype(jnp.uint32) ^ (idx * jnp.uint32(PHI)))
    h = jax.lax.reduce(g, jnp.uint32(0), jax.lax.bitwise_xor, (0,))
    h = h ^ jnp.uint32(np.uint32(nbytes & 0xFFFFFFFF))
    return _mix32_jnp(h)
