"""Public op: GQA-aware flash attention wrapper.

Maps (B, T, H, hd) GQA layouts onto the (B, H, T, hd) kernel, repeating KV
heads per group.  ``use_pallas=False`` routes to the jnp oracle (the path the
dry-run lowers, so cost analysis sees real HLO; see DESIGN.md §8).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: Optional[int] = None,
                    use_pallas: bool = True,
                    interpret: bool = True) -> jax.Array:
    """q: (B, T, H, hd); k, v: (B, T, Hkv, hd) with H % Hkv == 0 -> (B,T,H,hd)."""
    B, T, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
    if use_pallas:
        out = flash_attention_pallas(qt, kt, vt, window=window,
                                     interpret=interpret)
    else:
        out = attention_ref(qt, kt, vt, window=window)
    return out.transpose(0, 2, 1, 3)
