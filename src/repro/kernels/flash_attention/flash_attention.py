"""Pallas TPU kernel: blocked causal flash attention with online softmax.

Tiling: grid (B*H, Tq/BQ, Tk/BK) with the key axis innermost.  Each grid step
loads a (BQ, d) query block and a (BK, d) key/value block into VMEM, updates
the running max/denominator (online softmax) and the (BQ, d) accumulator held
in VMEM scratch.  The causal structure is exploited two ways:

  * blocks strictly above the diagonal contribute nothing — ``pl.when``
    skips their compute entirely (half the FLOPs of a naive masked kernel);
  * the diagonal blocks apply the elementwise causal (and optional sliding
    window) mask.

BQ = BK = 128 aligns with the MXU (128×128) and lane width.  bf16 inputs are
upcast to f32 for the softmax math, matching the jnp reference.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, window: Optional[int], bq: int, bk: int,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: key block strictly above the diagonal is dead
    q_start = qi * bq
    k_start = ki * bk

    @pl.when(k_start <= q_start + bq - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale           # (BQ, d)
        k = k_ref[0].astype(jnp.float32)                   # (BK, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)                    # (BQ, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * alpha
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           window: Optional[int] = None,
                           interpret: bool = True) -> jax.Array:
    """q, k, v: (B, H, T, d), T % 128 == 0.  Causal; optional sliding window."""
    B, H, T, d = q.shape
    bq, bk = min(BQ, T), min(BK, T)
    assert T % bq == 0 and T % bk == 0, (T, bq, bk)
    scale = d ** -0.5
    qf = q.reshape(B * H, T, d)
    kf = k.reshape(B * H, T, d)
    vf = v.reshape(B * H, T, d)
    n_k = T // bk
    kern = functools.partial(_flash_kernel, scale=scale, window=window,
                             bq=bq, bk=bk, n_k=n_k)
    out = pl.pallas_call(
        kern,
        grid=(B * H, T // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T, d)
