"""Pure-jnp oracle for blocked causal attention (optionally sliding-window)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  window: Optional[int] = None) -> jnp.ndarray:
    """q, k, v: (B, H, T, d) — causal softmax attention in f32."""
    B, H, T, d = q.shape
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    mask = j <= i
    if window is not None:
        mask &= (i - j) < window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", w, v.astype(jnp.float32)).astype(q.dtype)
