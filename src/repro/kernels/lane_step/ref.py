"""Numpy reference for the batched lane segment step.

This is definitionally ``repro.core.transport.advance_segment`` — the exact
expressions the scalar engine and the numpy lanes backend run — re-exported
so the kernel package is self-describing: ``lane_step`` must reproduce THIS
function (to float64 round-off; see the FMA note in
``repro.ensemble.batch``)."""
from __future__ import annotations

import numpy as np

from repro.core.transport import advance_segment


def lane_segment_step_np(t, bytes_done, rate, bound):
    """(t_left, new_bytes, adv, moved, hit) over [lane, row] float64."""
    t = np.broadcast_to(np.asarray(t, np.float64), np.shape(bytes_done))
    return advance_segment(t, np.asarray(bytes_done, np.float64),
                           np.asarray(rate, np.float64),
                           np.asarray(bound, np.float64))
