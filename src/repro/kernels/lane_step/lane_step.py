"""Pallas kernel: the batched ensemble segment step over [lane, row].

One fused elementwise pass computes, for every (lane, row) mover slot, the
walk's branch-free first iteration: seconds to the next byte boundary at the
row's fair-share rate, whether the boundary lands inside the tick (``hit``),
and the resulting byte/active-time/flow updates.  This is the inner loop of
the ensemble engine's lockstep tick — thousands of perturbed worlds advance
through this one kernel call.

Shapes are pre-padded by ``ops.py`` to (8, 128) tile multiples; the grid
walks 8-lane blocks.  Padding rows carry ``rate = 0`` and ``bound =
bytes_done``, which the engine masks out anyway (``hit`` on a PAD row is
never read).

Runs in interpret mode by default so CPU CI exercises the identical program;
on a real TPU pass ``interpret=False`` (float64 stays supported on TPU only
via interpret mode — compiled mode would need an f32 split-hi/lo scheme, a
deliberate non-goal while the trajectory contract is float64)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 8          # sublane tile for f32/f64 interpret mode
ROW_TILE = 128          # last-dim tile


def _lane_step_kernel(t_ref, bd_ref, rate_ref, bound_ref,
                      tl_ref, nb_ref, adv_ref, mv_ref, hit_ref):
    t = t_ref[...]
    bd = bd_ref[...]
    rate = rate_ref[...]
    bound = bound_ref[...]
    pos = rate > 0
    need = jnp.where(pos,
                     jnp.maximum(0.0, bound - bd)
                     / jnp.where(pos, rate, 1.0),
                     jnp.inf)
    hit = need <= t
    adv = jnp.where(hit, need, t)
    tl_ref[...] = jnp.where(hit, t - need, 0.0)
    nb_ref[...] = jnp.where(hit, bound, bd + rate * t)
    adv_ref[...] = adv
    mv_ref[...] = rate * adv
    hit_ref[...] = hit


@functools.partial(jax.jit, static_argnames=("interpret",))
def lane_step_pallas(t: jax.Array, bytes_done: jax.Array, rate: jax.Array,
                     bound: jax.Array, interpret: bool = True):
    """All inputs float64 [L, R] with L % 8 == 0 and R % 128 == 0 (pre-padded
    by ops.py).  Returns (t_left, new_bytes, adv, moved, hit[bool])."""
    L, R = bytes_done.shape
    grid = (L // LANE_BLOCK,)
    spec = pl.BlockSpec((LANE_BLOCK, R), lambda i: (i, 0))
    f64 = jax.ShapeDtypeStruct((L, R), jnp.float64)
    return pl.pallas_call(
        _lane_step_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec, spec, spec],
        out_shape=[f64, f64, f64, f64,
                   jax.ShapeDtypeStruct((L, R), jnp.bool_)],
        interpret=interpret,
    )(t, bytes_done, rate, bound)
