"""Public op: batched segment step with shape padding + x64 scoping.

Pads [L, R] inputs to the kernel's (8, 128) tile granularity, runs the
Pallas kernel under a scoped x64 context (the global flag is never
touched), and slices the padding back off.  Pad slots get ``rate = 0`` and
``bound = bytes_done = 0`` so they compute ``hit = False`` harmlessly."""
from __future__ import annotations

import numpy as np

from repro.kernels.lane_step.lane_step import (LANE_BLOCK, ROW_TILE,
                                               lane_step_pallas)


def _pad2(x: np.ndarray, Lp: int, Rp: int) -> np.ndarray:
    L, R = x.shape
    if (L, R) == (Lp, Rp):
        return x
    out = np.zeros((Lp, Rp), dtype=np.float64)
    out[:L, :R] = x
    return out


def lane_segment_step(t, bytes_done, rate, bound, interpret: bool = True):
    """(t_left, new_bytes, adv, moved, hit) over [lane, row] float64 host
    arrays — the Pallas-backed ensemble segment step."""
    import jax
    t = np.asarray(t, np.float64)
    bytes_done = np.asarray(bytes_done, np.float64)
    rate = np.asarray(rate, np.float64)
    bound = np.asarray(bound, np.float64)
    L, R = bytes_done.shape
    Lp = ((L + LANE_BLOCK - 1) // LANE_BLOCK) * LANE_BLOCK
    Rp = ((R + ROW_TILE - 1) // ROW_TILE) * ROW_TILE
    with jax.experimental.enable_x64():
        out = lane_step_pallas(
            *(jax.numpy.asarray(_pad2(a, Lp, Rp), jax.numpy.float64)
              for a in (t, bytes_done, rate, bound)),
            interpret=interpret)
        t_left, new_bytes, adv, moved, hit = (np.asarray(o)[:L, :R]
                                              for o in out)
    return t_left, new_bytes, adv, moved, hit
