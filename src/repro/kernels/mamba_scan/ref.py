"""Pure-jnp oracle for the selective scan (Mamba1 S6 recurrence).

    h[t] = exp(dt[t] * A) * h[t-1] + (dt[t] * u[t]) * B[t]
    y[t] = <h[t], C[t]> + D * u[t]        (D applied by the caller)

Shapes: u, dt (B, T, D); Bm, Cm (B, T, N); A (D, N); h0 (B, D, N), all f32.
Sequential reference — the unambiguous semantics the kernel must match.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def selective_scan_ref(u: jnp.ndarray, dt: jnp.ndarray, Bm: jnp.ndarray,
                       Cm: jnp.ndarray, A: jnp.ndarray, h0: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs                     # (B,D), (B,D), (B,N), (B,N)
        a = jnp.exp(dt_t[..., None] * A)             # (B, D, N)
        h = a * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), hT
