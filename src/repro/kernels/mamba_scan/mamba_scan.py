"""Pallas TPU kernel: chunked selective scan (Mamba1 S6).

TPU adaptation of the CUDA selective-scan kernel (DESIGN.md §2): instead of a
warp-level scan, we tile (T × D) into (CHUNK_T × BLOCK_D) VMEM blocks.  The
grid is (batch, D-blocks, T-chunks) with the T axis innermost: TPU grid steps
execute sequentially, so the carried state ``h`` lives in a VMEM scratch
accumulator across T-chunks of the same (batch, D-block) and is re-initialized
from ``h0`` whenever a new (batch, D-block) begins.  Within a chunk the
recurrence is a ``lax.fori_loop`` over rows — VPU elementwise work over
(BLOCK_D, N) lanes, which is MXU-free and bandwidth-bound, matching the op's
roofline.

Block sizes: BLOCK_D a multiple of 128 (lane width), CHUNK_T sized so
u/dt/B/C blocks (~4 × CHUNK_T × BLOCK_D × 4B) fit comfortably in VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK_T = 128
BLOCK_D = 256


def _scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                 y_ref, hT_ref, h_scr):
    tc = pl.program_id(2)

    @pl.when(tc == 0)
    def _init():
        h_scr[...] = h0_ref[0]                        # (BLOCK_D, N)

    A = a_ref[...]                                    # (BLOCK_D, N)
    h = h_scr[...]

    def row(t, h):
        dt_t = dt_ref[0, t, :]                        # (BLOCK_D,)
        u_t = u_ref[0, t, :]
        b_t = b_ref[0, t, :]                          # (N,)
        c_t = c_ref[0, t, :]
        a = jnp.exp(dt_t[:, None] * A)                # (BLOCK_D, N)
        h = a * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1)
        return h

    h = jax.lax.fori_loop(0, u_ref.shape[1], row, h)
    h_scr[...] = h
    hT_ref[0] = h


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan_pallas(u: jax.Array, dt: jax.Array, Bm: jax.Array,
                          Cm: jax.Array, A: jax.Array, h0: jax.Array,
                          interpret: bool = True
                          ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as ``ref.selective_scan_ref`` (all f32).

    Requires T % CHUNK_T == 0 and D % BLOCK_D == 0 when larger than the block
    (callers pad; the assigned arch shapes satisfy this natively:
    falcon-mamba D=8192, T ∈ {4096, 32768}).
    """
    B, T, D = u.shape
    N = A.shape[1]
    ct = min(CHUNK_T, T)
    bd = min(BLOCK_D, D)
    assert T % ct == 0 and D % bd == 0, (T, D, ct, bd)
    grid = (B, D // bd, T // ct)

    y, hT = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, bd), lambda b, d, t: (b, t, d)),   # u
            pl.BlockSpec((1, ct, bd), lambda b, d, t: (b, t, d)),   # dt
            pl.BlockSpec((1, ct, N), lambda b, d, t: (b, t, 0)),    # B
            pl.BlockSpec((1, ct, N), lambda b, d, t: (b, t, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, t: (d, 0)),          # A
            pl.BlockSpec((1, bd, N), lambda b, d, t: (b, d, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, ct, bd), lambda b, d, t: (b, t, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, t: (b, d, 0)),    # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, Bm, Cm, A, h0)
    return y, hT
