"""Public op: selective scan with automatic padding to kernel granularity."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.mamba_scan import (BLOCK_D, CHUNK_T,
                                                 selective_scan_pallas)
from repro.kernels.mamba_scan.ref import selective_scan_ref


def selective_scan(u, dt, Bm, Cm, A, h0, use_pallas: bool = True,
                   interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """u, dt: (B,T,D); Bm, Cm: (B,T,N); A: (D,N); h0: (B,D,N)."""
    if not use_pallas:
        return selective_scan_ref(u, dt, Bm, Cm, A, h0)
    B, T, D = u.shape
    ct = min(CHUNK_T, T)
    bd = min(BLOCK_D, D)
    pt = (-T) % ct
    pd = (-D) % bd
    if pt or pd:
        padT = lambda x: jnp.pad(x, ((0, 0), (0, pt), (0, 0)))
        u2, dt2 = padT(u), padT(dt)
        Bm2, Cm2 = padT(Bm), padT(Cm)
        if pd:
            u2 = jnp.pad(u2, ((0, 0), (0, 0), (0, pd)))
            dt2 = jnp.pad(dt2, ((0, 0), (0, 0), (0, pd)))
            A = jnp.pad(A, ((0, pd), (0, 0)))
            h0 = jnp.pad(h0, ((0, 0), (0, pd), (0, 0)))
        y, hT = selective_scan_pallas(u2, dt2, Bm2, Cm2, A, h0,
                                      interpret=interpret)
        return y[:, :T, :D], hT[:, :D]
    return selective_scan_pallas(u, dt, Bm, Cm, A, h0, interpret=interpret)
