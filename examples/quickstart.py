"""Quickstart: train a small LM end-to-end on CPU with checkpoint/restart.

    PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m]
        [--steps 200] [--preset full|small]

``--preset small`` (default) trains the reduced same-family config
(~1M params, runs in a couple of minutes on CPU); ``--preset full`` uses the
real architecture config (use on actual accelerators).  A failure is injected
halfway to demonstrate restart-from-checkpoint.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCH_IDS, get_config
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="small", choices=["small", "full"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (demo of restart)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "small":
        cfg = cfg.smoke()
    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2

    with tempfile.TemporaryDirectory() as td:
        tc = TrainConfig(steps=args.steps, batch_size=args.batch,
                         seq_len=args.seq, peak_lr=1e-3, warmup=20,
                         ckpt_every=max(10, args.steps // 8),
                         ckpt_dir=os.path.join(td, "ckpts"),
                         fail_at_step=fail_at, log_every=10)
        res = train(cfg, tc)
        print(f"\narch={cfg.name} steps={res.final_step} "
              f"restarts={res.restarts} wall={res.wall_s:.1f}s")
        print(f"loss: {res.losses[0]:.4f} -> {res.losses[-1]:.4f} "
              f"({'improved' if res.losses[-1] < res.losses[0] else 'flat'})")


if __name__ == "__main__":
    main()
