"""Batched serving: submit a stream of requests to the wave-scheduled engine.

    PYTHONPATH=src python examples/serve_batched.py [--arch smollm-135m]
        [--requests 8] [--max-new 12]

Uses the reduced same-family config so it runs on CPU; the decode step the
engine drives is exactly what the decode_32k dry-run cells lower for the
production mesh.
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ARCH_IDS, get_config
from repro.models.model import LM
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = LM(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=args.batch, max_seq=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        if cfg.n_codebooks > 1:
            prompt = rng.integers(0, cfg.vocab_size, (plen, cfg.n_codebooks))
        else:
            prompt = rng.integers(0, cfg.vocab_size, plen)
        eng.submit(prompt, max_new_tokens=args.max_new)
    done = eng.run_to_completion()
    wall = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"arch={cfg.name}: served {len(done)} requests in {eng.waves} waves,"
          f" {toks} tokens in {wall:.1f}s ({toks/wall:.1f} tok/s on CPU)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}{'...' if len(r.out_tokens) > 8 else ''}")


if __name__ == "__main__":
    main()
