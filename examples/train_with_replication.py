"""End-to-end driver: training + the paper's replication machinery together.

    PYTHONPATH=src python examples/train_with_replication.py [--steps 60]

What it shows, in one run:
  1. dataset staged from a slow "STORE" site to two pod staging areas via the
     Figure-4 scheduler over real files (LocalFSTransport + checksums);
  2. training on the pod-local copy with periodic checkpoints;
  3. every committed checkpoint replicated cross-site (POD1 + STORE);
  4. a simulated pod loss (primary checkpoint tree destroyed) and recovery
     from the nearest replica — the paper's reliability story as a training
     framework feature.
"""
import argparse
import os
import shutil
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint.ckpt import save_checkpoint
from repro.checkpoint.replicate import CheckpointReplicator
from repro.configs import get_config
from repro.data.sharded import ShardedDataset, write_shards
from repro.data.staging import StagingArea
from repro.models.model import LM
from repro.optim import adamw
from repro.train.loop import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    cfg = get_config("smollm-135m").smoke()

    with tempfile.TemporaryDirectory() as td:
        # -- 1. stage the dataset from the slow store to both pods ----------
        staging = StagingArea(td, store="STORE", pods=("POD0", "POD1"))
        store_ds = os.path.join(td, "STORE", "datasets", "tokens")
        rng = np.random.default_rng(0)
        write_shards(store_ds, rng.integers(0, cfg.vocab_size, 200_000
                                            ).astype(np.int32), 4096)
        staging.register("datasets/tokens")
        steps = staging.run_until_staged()
        print(f"[stage] dataset staged to both pods in {steps} scheduler steps; "
              f"verified={staging.staged_ok('datasets/tokens')}")

        # -- 2. train from the pod-local copy -------------------------------
        data = ShardedDataset(staging.pod_path("POD0", "datasets/tokens"))
        model = LM(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.init(params)
        tc = TrainConfig(steps=args.steps, batch_size=4, seq_len=128)
        step_fn = make_train_step(model, adamw.AdamWConfig(), tc)

        rep = CheckpointReplicator(td, primary="POD0",
                                   replicas=("POD1", "STORE"))
        ckpt_root = os.path.join(rep.site_dir("POD0"), "ckpts")
        it = data.batches(tc.batch_size, tc.seq_len)
        losses = []
        import jax.numpy as jnp
        for step in range(args.steps):
            batch_np, state = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt, loss, _ = step_fn(params, opt, batch)
            losses.append(float(loss))
            if (step + 1) % 20 == 0:
                d = save_checkpoint(ckpt_root, step + 1,
                                    {"params": params, "opt": opt})
                ok = rep.replicate(os.path.relpath(d, rep.site_dir("POD0")))
                print(f"[train] step {step+1} loss {float(loss):.4f} "
                      f"ckpt replicated={ok}")

        # -- 3. pod loss + recovery from replica -----------------------------
        shutil.rmtree(ckpt_root)
        print("[failure] POD0 checkpoint tree destroyed (simulated pod loss)")
        got = rep.restore_anywhere("ckpts", {"params": params, "opt": opt})
        assert got is not None
        step0, tree, _, site = got
        print(f"[recover] restored step {step0} from {site}; "
              f"loss trace {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
