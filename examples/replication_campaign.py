"""The paper, end to end: replicate a catalog from a slow source to two
replica sites with the Figure-4 scheduler — simulated WAN + live dashboard.

    PYTHONPATH=src python examples/replication_campaign.py
        [--datasets 120] [--scale 0.05] [--dashboard]

Watch for the paper's phases: LLNL->ALCF primary flow, re-route to OLCF
during ALCF maintenance, ALCF->OLCF relay traffic, permission-failure
quarantine + human fix, and termination with both replicas complete.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.campaign import CampaignConfig, build_campaign
from repro.core.dashboard import render_text
from repro.core.pause import DAY


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", type=int, default=120)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--dashboard", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = CampaignConfig(n_datasets=args.datasets, scale=args.scale,
                         seed=args.seed, step_s=3600.0)
    (graph, catalog, clock, pause, transport, table, sched,
     notifier) = build_campaign(cfg)
    total = sum(d.bytes for d in catalog.values())
    fix_at = {}
    day_printed = -1
    while clock.now < cfg.max_days * DAY and not sched.done():
        actions = sched.step(clock.now)
        for ds_path, fixed in list(notifier.fixed.items()):
            if not fixed and ds_path not in fix_at:
                fix_at[ds_path] = clock.now + cfg.human_fix_days * DAY
        for ds_path, t in list(fix_at.items()):
            if clock.now >= t and not notifier.is_fixed(ds_path):
                notifier.fix(ds_path)
                print(f"[day {clock.now/DAY:5.1f}] admin fixed {ds_path}")
        clock.advance(cfg.step_s)
        transport.tick()
        day = int(clock.now / DAY)
        if day != day_printed and day % 2 == 0:
            day_printed = day
            if args.dashboard:
                print(render_text(table, ["ALCF", "OLCF"], total, clock.now))
            else:
                from repro.core.transfer_table import Status
                done_a = len(table.by_status(Status.SUCCEEDED, destination="ALCF"))
                done_o = len(table.by_status(Status.SUCCEEDED, destination="OLCF"))
                print(f"[day {day:3d}] ALCF {done_a}/{len(catalog)}  "
                      f"OLCF {done_o}/{len(catalog)}  "
                      f"paused={'yes' if pause.paused('ALCF', clock.now) else 'no '}"
                      f" notifications={len(notifier.notifications)}")
    print(f"\ncampaign finished in {clock.now/DAY:.1f} simulated days "
          f"(floor {total/graph.sites['LLNL'].read_bw/DAY:.1f} d); "
          f"done={sched.done()}")


if __name__ == "__main__":
    main()
