"""The paper, end to end: replicate a catalog from a slow source to replica
sites with the Figure-4 scheduler — now driven through a *named scenario*
from ``repro.scenarios`` (simulated WAN + live dashboard).  Federation
names run N campaigns over one shared world.

    PYTHONPATH=src python examples/replication_campaign.py
        [--scenario paper-2022 | --scenario federation-paper-twice]
        [--datasets 120] [--scale 0.05]
        [--engine events|step] [--dashboard]

Watch for the paper's phases: LLNL->ALCF primary flow, re-route to OLCF
during ALCF maintenance, ALCF->OLCF relay traffic, permission-failure
quarantine + human fix, and termination with all replicas complete — or,
for a federation, two campaigns contending for the same source egress.
Demand scenarios (``--scenario esgf-serving``) additionally report the
serving hit-rate and p99 read latency as user traffic rides the campaign.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.campaign import FederationReport
from repro.core.dashboard import (render_demand_text, render_federation_text,
                                  render_text)
from repro.core.pause import DAY
from repro.scenarios.events import run_world
from repro.scenarios.registry import (get_scenario, list_federations,
                                      list_scenarios)
from repro.scenarios.spec import FederationWorld


def _observer(world, args, total, state):
    """Single-campaign progress printer (the original example view)."""
    def observer(world, now):
        for ds, ok in world.notifier.fixed.items():
            if ok and ds not in state["fixed_seen"]:
                state["fixed_seen"].add(ds)
                print(f"[day {now/DAY:5.1f}] admin fixed {ds}")
        day = int(now / DAY)
        if day == state["day_printed"] or day % 2:
            return
        state["day_printed"] = day
        if args.dashboard:
            print(render_text(world.table, list(world.cfg.replicas), total,
                              now, campaign=world.spec.name))
            if world.demand is not None:
                print(render_demand_text(world.demand, now))
            return
        done_by = {r: len(world.table.succeeded_set(r))
                   for r in world.cfg.replicas}
        paused = " ".join(
            f"{s}:{'P' if world.pause.paused(s, now) else '-'}"
            for s in world.graph.sites)
        serving = ""
        if world.demand is not None:
            s = world.demand.summary()
            serving = (f"  hit={s['hit_rate']*100:.0f}%"
                       f" p99={s['p99_s']:.1f}s")
        print(f"[day {day:3d}] "
              + "  ".join(f"{r} {n}/{len(world.catalog)}"
                          for r, n in done_by.items())
              + f"  [{paused}]"
              f"  notifications={len(world.notifier.notifications)}"
              + serving)
    return observer


def _federation_observer(args, state):
    """Per-member progress rows, side by side."""
    def observer(world, now):
        day = int(now / DAY)
        if day == state["day_printed"] or day % 2:
            return
        state["day_printed"] = day
        if args.dashboard:
            print(render_federation_text(world, now))
            return
        parts = []
        for rt in world.runtimes:
            done = {r: len(rt.table.succeeded_set(r))
                    for r in rt.cfg.replicas}
            parts.append(f"{rt.label} " + "/".join(
                f"{r}:{n}" for r, n in done.items()))
        print(f"[day {day:3d}] " + "  ".join(parts))
    return observer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="paper-2022",
                    help="one of: "
                         f"{', '.join(list_scenarios() + list_federations())}")
    ap.add_argument("--datasets", type=int, default=120)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--engine", choices=("events", "step"), default="events")
    ap.add_argument("--dashboard", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_scenario(args.scenario)
    print(f"# {spec.name}: {spec.description}\n")
    world = spec.build(scale=args.scale, seed=args.seed,
                       n_datasets=args.datasets)
    state = {"day_printed": -1, "fixed_seen": set()}
    if isinstance(world, FederationWorld):
        observer = _federation_observer(args, state)
    else:
        total = sum(d.bytes for d in world.catalog.values())
        observer = _observer(world, args, total, state)

    rep = run_world(world, engine=args.engine, on_iteration=observer)
    if isinstance(rep, FederationReport):
        print(f"\nfederation finished: span {rep.span_days:.1f} simulated "
              "days")
        for label, m in rep.members.items():
            print(f"  {label:12} started day {rep.started_day[label]:6.1f}  "
                  f"finished day {rep.finished_day[label]:6.1f}  "
                  f"faults={m.faults_total}")
    else:
        print(f"\ncampaign finished in {rep.duration_days:.1f} simulated "
              f"days (floor {rep.floor_days:.1f} d); "
              f"done={world.sched.done()}")
        if world.demand is not None:
            s = world.demand.summary()
            day90 = "-" if s["day90"] is None else f"day {s['day90']}"
            print(f"served {s['requests']:,} user requests: "
                  f"hit-rate {s['hit_rate']*100:.1f}% "
                  f"(90% reached {day90}), p99 {s['p99_s']:.1f}s, "
                  f"{s['bytes_served_tb']:.1f} TB from replicas")


if __name__ == "__main__":
    main()
